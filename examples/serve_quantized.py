"""Quantized serving example: batched requests across ggml formats.

Serves the same synthetic request batch with f32 weights and with each
quantization format, comparing (a) measured CPU tokens/s, (b) output
agreement vs the f32 reference, (c) the capability model's predicted
speedup on the paper's hardware.

Run:  PYTHONPATH=src python examples/serve_quantized.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import CMP_170HX_NOFMA, InferencePerfModel
from repro.models import build_model
from repro.serving import Request, ServeEngine, dequantize_params, \
    quantize_params


def serve_once(cfg, params, prompts, gen=8, lanes=2):
    engine = ServeEngine(cfg, params, n_lanes=lanes,
                         max_len=prompts.shape[1] + gen + 4)
    reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=gen)
            for i in range(prompts.shape[0])]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    toks = [tuple(r.generated) for r in reqs]
    n = sum(len(t) for t in toks)
    return toks, n / dt


def main():
    cfg = get_config("qwen2.5-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (4, 12)).astype(np.int32)

    ref_toks, ref_tps = serve_once(cfg, params, prompts)
    print(f"f32 reference: {ref_tps:.1f} tok/s (CPU)")

    m = InferencePerfModel(CMP_170HX_NOFMA)
    base = m.decode("f32").tokens_per_s
    for fmt in ("q8_0", "q6_k", "q4_k", "q2_k"):
        qp, stats = quantize_params(params, fmt)
        toks, tps = serve_once(cfg, dequantize_params(qp), prompts)
        agree = np.mean([
            np.mean([a == b for a, b in zip(t1, t2)])
            for t1, t2 in zip(ref_toks, toks)])
        pred = m.decode(fmt).tokens_per_s
        print(f"{fmt:5s}: {tps:6.1f} tok/s CPU | token-agreement vs f32 "
              f"{agree:4.0%} | modeled CMP-170HX decode {pred:7.1f} t/s "
              f"({pred/base:.1f}x vs f32)")
    print("OK")


if __name__ == "__main__":
    main()
