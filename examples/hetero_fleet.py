"""Heterogeneous fleet planning: the paper's SS6.2 recommendation, run.

Sweeps fleet compositions (A100s + reclaimed CMP 170HX boards) and
prints the optimal prefill/decode disaggregation for each, showing when
adding e-waste mining boards beats buying another datacenter GPU.

Run:  PYTHONPATH=src python examples/hetero_fleet.py
"""

from repro.serving.disaggregation import (Workload, homogeneous_baseline,
                                          plan_fleet)

WL = Workload(prompt_len=512, gen_len=128, fmt="q8_0")


def show(tag, plan):
    roles = ", ".join(f"{a.count}x{a.profile}->{a.role}"
                      for a in plan.assignments)
    print(f"  {tag:28s} {plan.requests_per_s:7.2f} req/s  "
          f"${plan.usd_per_mtok:7.3f}/Mtok  [{roles}]")


def main():
    print(f"workload: prompt={WL.prompt_len} gen={WL.gen_len} fmt={WL.fmt}\n")
    print("homogeneous baselines:")
    show("4x A100", homogeneous_baseline("a100-40g", 4, WL))
    show("16x CMP-170HX(noFMA)", homogeneous_baseline(
        "cmp-170hx-nofma", 16, WL))
    print("\nmixed fleets (optimal role assignment):")
    for a100s, cmps in [(1, 4), (2, 8), (2, 16), (4, 16)]:
        plan = plan_fleet({"a100-40g": a100s,
                           "cmp-170hx-nofma": cmps}, WL)
        show(f"{a100s}x A100 + {cmps}x CMP", plan)
    print("\nreading: the planner sends compute-bound prefill to the "
          "A100s and\nbandwidth-bound decode to the mining boards -- "
          "the paper's SS6.2 thesis.")


if __name__ == "__main__":
    main()
