"""Quickstart: the paper's pipeline in five steps on CPU.

1. characterize a device (capability table / C1),
2. let the path policy reroute compute (C2, the -fmad=false analogue),
3. quantize a model ggml-style (C4),
4. predict prefill/decode throughput + energy (C3/C5),
5. run a real quantized decode with the serving engine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CMP_170HX, CMP_170HX_NOFMA, TPU_V5E,
                        InferencePerfModel, PathPolicy, matmul_descriptor)
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServeEngine, dequantize_params, \
    quantize_params

print("=" * 70)
print("1) capability characterization (paper C1)")
for prof in (CMP_170HX, CMP_170HX_NOFMA, TPU_V5E):
    f32 = max(v for (p, _), v in prof.peak.items() if p == "f32")
    print(f"  {prof.name:18s} best-f32={f32:6.1f}TF "
          f"hbm={prof.hbm_bw_gbps:.0f}GB/s tdp={prof.tdp_watts:.0f}W")

print("\n2) compute-path policy (paper C2: reroute around the throttle)")
desc = matmul_descriptor(512, 512, 4096, "f32")
for prof in (CMP_170HX, TPU_V5E):
    d = PathPolicy(prof).decide(desc)
    print(f"  {prof.name:18s} -> variant={d.variant:8s} "
          f"modeled={d.modeled_seconds*1e6:7.1f}us ({d.bound}-bound)")

print("\n3) quantize a model (paper C4)")
cfg = get_config("qwen2.5-1.5b", smoke=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
qp, stats = quantize_params(params, "q4_k")
print(f"  {stats['quantized']} matrices -> q4_k "
      f"({stats['quantized_bytes']/1e6:.1f}MB; "
      f"{stats['dense_bytes']/1e6:.1f}MB kept dense)")

print("\n4) throughput + energy prediction (paper C3/C5, Graphs 4-1..4-3)")
for prof in (CMP_170HX, CMP_170HX_NOFMA):
    m = InferencePerfModel(prof)
    for fmt in ("f16", "q4_k"):
        d = m.decode(fmt)
        print(f"  {prof.name:18s} {fmt:5s} decode={d.tokens_per_s:7.1f}t/s "
              f"({d.bound}-bound) {d.tokens_per_joule:5.2f} tok/J")

print("\n5) serve with the quantized weights (continuous batching)")
engine = ServeEngine(cfg, dequantize_params(qp), n_lanes=2, max_len=48)
rng = np.random.default_rng(0)
reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 12,
                                           dtype=np.int32),
                max_new_tokens=8) for i in range(3)]
engine.run(reqs)
for r in reqs:
    print(f"  request {r.uid}: generated {r.generated}")
print("\nOK")
