"""Trace-driven fleet simulation demo: SS6.2 with queues and bursts.

Simulates a bursty day of traffic against the planner's disaggregated
mixed fleet (A100 prefill + reclaimed CMP 170HX decode) and its
homogeneous baselines, then lets a queue-depth autoscaler grow the CMP
decode pool through a diurnal rush -- the dynamics the static planner
(`examples/hetero_fleet.py`) cannot show.

Run:  PYTHONPATH=src python examples/fleet_sim_demo.py
"""

from repro.fleet import (FleetSim, NodeSpec, QueueDepthAutoscaler,
                         bursty_trace, diurnal_trace, fleet_from_plan)
from repro.serving import Workload, plan_fleet

WL = Workload(prompt_len=512, gen_len=128, fmt="q8_0")
SLO = dict(ttft_slo_s=2.0, tpot_slo_s=0.05)
LANES = 4


def show(tag, rep):
    print(f"  {tag:26s} goodput={rep.goodput_rps:6.2f} req/s  "
          f"ttft p50/p99={rep.ttft_p50_s * 1e3:6.0f}/"
          f"{rep.ttft_p99_s * 1e3:6.0f} ms  "
          f"tpot p99={rep.tpot_p99_s * 1e3:5.2f} ms  "
          f"{rep.avg_watts:5.0f} W  ${rep.usd_per_mtok:6.3f}/Mtok")


def main():
    plan = plan_fleet({"a100-40g": 2, "cmp-170hx-nofma": 8}, WL)
    roles = ", ".join(f"{a.count}x{a.profile}->{a.role}"
                      for a in plan.assignments)
    print(f"planner roles: [{roles}]  "
          f"steady-state {plan.requests_per_s:.2f} req/s\n")

    trace = bursty_trace(rate_on_rps=60.0, duration_s=120.0, seed=0)
    print(f"bursty trace: {len(trace)} requests over 120 s "
          f"(ON/OFF Poisson, seed 0)")
    show("mixed 2xA100+8xCMP", FleetSim(
        fleet_from_plan(plan, decode_lanes=LANES), trace,
        fmt=WL.fmt, **SLO).run())
    show("homogeneous 2xA100", FleetSim(
        [NodeSpec("a100-40g", 2, "both", LANES)], trace,
        fmt=WL.fmt, **SLO).run())
    show("homogeneous 8xCMP", FleetSim(
        [NodeSpec("cmp-170hx-nofma", 8, "both", LANES)], trace,
        fmt=WL.fmt, **SLO).run())

    print("\ndiurnal rush with a queue-depth autoscaler over the CMP pool:")
    rush = diurnal_trace(base_rps=5.0, peak_rps=60.0, duration_s=240.0,
                         seed=3, period_s=240.0)
    base = [NodeSpec("a100-40g", 2, "prefill", 1),
            NodeSpec("cmp-170hx-nofma", 2, "decode", LANES)]
    asc = QueueDepthAutoscaler(
        template=NodeSpec("cmp-170hx-nofma", 1, "decode", LANES),
        interval_s=10.0, min_nodes=2, max_nodes=16, cold_start_s=15.0)
    show("fixed 2xCMP decode", FleetSim(base, rush, fmt=WL.fmt,
                                        **SLO).run())
    scaled = FleetSim(base, rush, fmt=WL.fmt, autoscaler=asc, **SLO)
    show("autoscaled CMP decode", scaled.run())
    for ev in scaled.scale_events:
        print(f"    scale: {ev}")
    print("\nreading: burst tails, not steady-state throughput, are where "
          "the\ndisaggregated reclaimed-board fleet earns its keep -- and "
          "where the\nqueue-depth autoscaler absorbs the rush.")


if __name__ == "__main__":
    main()
