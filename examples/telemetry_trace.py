"""Serving telemetry demo: span traces, metrics, and the drift gate.

Serves a small workload through the real paged ``ServeEngine`` with the
``repro.obs`` tracer attached, then a deterministic fleet simulation
with sim-clock spans, and writes:

* ``telemetry_serve_trace.json`` / ``telemetry_fleet_trace.json`` --
  Chrome-trace files; open either at https://ui.perfetto.dev to see
  admit/prefill/dispatch spans per lane (host clock) and
  prefill/decode/swap spans per simulated board (sim clock);
* ``telemetry_metrics.prom`` -- the registry's Prometheus text
  exposition (counters, occupancy gauges, span-duration summaries);

and finishes by running the sim-to-real calibration gate: the pure-host
scheduling model of :func:`repro.obs.predict_replay` vs the measured
replay, plus a deliberately perturbed model that must FAIL.

Run:  PYTHONPATH=src python examples/telemetry_trace.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.fleet import FleetSim, NodeSpec, poisson_trace
from repro.fleet.execution import run_trace_on_engine
from repro.fleet.workload import FleetRequest, LengthDist
from repro.models import build_model
from repro.obs import (MetricsRegistry, SpanTracer, calibrate_replay,
                       predict_replay)
from repro.serving import Request, ServeEngine

ENGINE_KW = dict(n_lanes=2, max_len=64, dispatch_n=4, paged=True,
                 page_size=8)


def main():
    cfg = get_config("qwen2.5-1.5b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    # -- 1. traced engine run (host-clock spans) ----------------------
    registry = MetricsRegistry()
    tracer = SpanTracer(registry=registry)
    eng = ServeEngine(cfg, params, tracer=tracer, registry=registry,
                      **ENGINE_KW)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 5 + i,
                                        dtype=np.int32),
                    max_new_tokens=8) for i in range(4)]
    eng.run(reqs)
    tracer.save("telemetry_serve_trace.json")
    print(f"engine: {dict(eng.stats)}")
    print(f"  {len(tracer.spans)} spans on tracks {tracer.tracks()}"
          f" -> telemetry_serve_trace.json")

    with open("telemetry_metrics.prom", "w") as f:
        f.write(registry.to_prometheus())
    summary = registry["span.decode.dispatch.seconds"].summary()
    print(f"  decode.dispatch p50={summary['p50'] * 1e3:.2f} ms "
          f"p99={summary['p99'] * 1e3:.2f} ms "
          f"-> telemetry_metrics.prom")

    # -- 2. traced fleet sim (sim-clock spans) ------------------------
    fleet_reg = MetricsRegistry()
    fleet_tr = SpanTracer(registry=fleet_reg)
    trace = poisson_trace(10.0, 3.0, seed=3,
                          prompt=LengthDist(256, cv=0.3),
                          gen=LengthDist(64, cv=0.3))
    rep = FleetSim([NodeSpec("cmp-170hx-nofma", 2, "both", 4)], trace,
                   fmt="q8_0", tracer=fleet_tr, registry=fleet_reg).run()
    fleet_tr.save("telemetry_fleet_trace.json")
    print(f"fleet sim: {rep.completed}/{rep.offered} completed, "
          f"{len(fleet_tr.spans)} sim-clock spans "
          f"-> telemetry_fleet_trace.json")

    # -- 3. sim-to-real calibration gate ------------------------------
    cal_reg = MetricsRegistry()
    cal_tr = SpanTracer(registry=cal_reg)
    replay = [FleetRequest(uid=i, arrival_s=0.05 * i,
                           prompt_len=3 + i % 4, gen_len=2 + i % 5)
              for i in range(6)]
    real = run_trace_on_engine(replay, cfg, params, tracer=cal_tr,
                               registry=cal_reg, **ENGINE_KW)
    report = calibrate_replay(real, predict_replay(replay, **ENGINE_KW),
                              spans=cal_tr.spans)
    print("calibration gate (scheduling model vs measured replay):")
    for key, m in report.metrics.items():
        print(f"  {key:18s} real={m['real']:6.0f} sim={m['sim']:6.0f} "
              f"rel_err={m['rel_err']:.3f}")
    print(f"  ok={report.ok} (tolerance {report.tolerance})")
    perturbed = calibrate_replay(
        real, predict_replay(replay, **dict(ENGINE_KW, dispatch_n=1)))
    print(f"  perturbed phase model (dispatch_n=1): "
          f"ok={perturbed.ok} max_rel_err={perturbed.max_rel_err:.2f} "
          f"-- the gate fails loudly, as it must")


if __name__ == "__main__":
    main()
