"""End-to-end training driver: ~100M-class model, a few hundred steps.

Trains a 6-layer / d768 Qwen-style model (~97M params with embeddings)
on the synthetic Zipf+repetition stream, with async checkpointing and a
mid-run simulated preemption + resume -- the fault-tolerance path
exercised for real.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300]
On this 1-core CPU container the full run takes hours; for a quick
functional pass use:
      python examples/train_e2e.py --steps 24 --batch 2 --seq 128
(verified: loss 8.29 -> 6.38 across a simulated preemption + resume).
"""

import argparse
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, restore_latest
from repro.data import DataConfig, synth_batch
from repro.models import build_model
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig
from repro.train import TrainConfig, init_train_state, make_train_step

CFG = ModelConfig(
    name="qwen-100m", family="dense", n_layers=6, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=50304,
    qkv_bias=True, norm="rmsnorm", tie_embeddings=True, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a preemption at this step")
    args = ap.parse_args()
    fail_at = args.fail_at if args.fail_at is not None else args.steps // 2

    model = build_model(CFG)
    print(f"model: {CFG.name} ({CFG.total_params()/1e6:.0f}M params)")
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=20,
                                             total_steps=args.steps),
                       remat=False, microbatches=1)
    step_fn = jax.jit(make_train_step(CFG, tcfg), donate_argnums=(0,))
    dcfg = DataConfig(vocab_size=CFG.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)

    ckdir = tempfile.mkdtemp(prefix="repro_e2e_")
    ck = AsyncCheckpointer(ckdir, keep=2)
    state = init_train_state(model, jax.random.PRNGKey(0))
    losses = []
    step, restarted = 0, False
    t0 = time.time()
    while step < args.steps:
        if step == fail_at and not restarted:
            print(f"-- simulated preemption at step {step}; restarting "
                  "from latest checkpoint --")
            ck.wait()
            got, restored = restore_latest(ckdir,
                                           init_train_state(
                                               model, jax.random.PRNGKey(0)))
            step, state = (got or 0), (restored if got else state)
            restarted = True
            continue
        batch = {k: jnp.asarray(v)
                 for k, v in synth_batch(dcfg, step).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        step += 1
        if step % 20 == 0:
            tput = args.batch * args.seq * 20 / (time.time() - t0)
            t0 = time.time()
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} ({tput:,.0f} tok/s)")
        if step % 50 == 0:
            ck.save(step, state)
    ck.close()
    shutil.rmtree(ckdir, ignore_errors=True)
    first, last = sum(losses[:20]) / 20, sum(losses[-20:]) / 20
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'OK: decreased' if last < first else 'WARN: flat'})")


if __name__ == "__main__":
    main()
