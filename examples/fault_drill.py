"""Fault drill: crash a decode board mid-trace and watch it recover.

A scripted game-day for the reclaimed-GPU fleet.  One deterministic
scenario runs three ways -- fault-free, faulted WITH a recovery policy,
faulted WITHOUT one -- while the fault plan thermally derates one CMP
board, flaps the prefill board's host link, stalls a board briefly, and
kills a decode board outright at t=20.1s:

* with recovery, the dead board's live lanes resume from their last
  host-side checkpoint on a surviving board (pages re-sent over the
  PCIe-1.1-x4 link) and nothing is lost;
* without recovery, everything the crash touched is LOST;
* the training-loop straggler monitor, re-used on the sim clock, flags
  the derated board from its s/token EWMA alone.

The run leaves a Perfetto-loadable trace (``fault_drill_trace.json``,
open at https://ui.perfetto.dev) with the fault windows, the crash
instant, the recovery transfers and the straggler flag on their nodes'
tracks.

Run:  PYTHONPATH=src python examples/fault_drill.py
"""

from repro.fleet import (FaultEvent, FaultPlan, FleetSim, LengthDist,
                         NodeSpec, RecoveryPolicy, RetryPolicy,
                         poisson_trace)
from repro.obs import MetricsRegistry, SpanTracer

SLO = dict(ttft_slo_s=2.0, tpot_slo_s=0.08)


def fleet():
    return [NodeSpec("a100-40g", 1, "prefill"),
            NodeSpec("cmp-170hx-nofma", 3, "decode", decode_lanes=8,
                     kv_pool_pages=512, page_size=16)]


def show(tag, rep):
    print(f"  {tag:18s} completed={rep.completed:3d}/{rep.offered}  "
          f"goodput={rep.goodput_rps:5.2f} req/s  "
          f"tpot p99={rep.tpot_p99_s * 1e3:5.2f} ms  "
          f"lost={rep.requests_lost}")


def main():
    trace = poisson_trace(6.0, 40.0, seed=2,
                          prompt=LengthDist(256, cv=0.3),
                          gen=LengthDist(512, cv=0.5))
    plan = FaultPlan(events=(
        FaultEvent("derate", node="cmp-170hx-nofma/decode#1", at_s=5.0,
                   factor=3.0, duration_s=12.0),
        FaultEvent("crash", node="cmp-170hx-nofma/decode#2", at_s=20.1),
        FaultEvent("transient", node="cmp-170hx-nofma/decode#3",
                   at_s=30.0, duration_s=0.25),
    )) + FaultPlan.flap("a100-40g/prefill#0", t0=8.0, period_s=2.0,
                        n_flaps=3, factor=4.0)
    recovery = RecoveryPolicy(checkpoint_interval_s=0.5,
                              retry=RetryPolicy(max_attempts=4))

    print(f"fault plan ({len(plan.events)} events):")
    for ev in plan.sim_events():
        dur = f" for {ev.duration_s:.2f}s" if ev.duration_s else ""
        fac = f" x{ev.factor:.0f}" if ev.factor > 1 else ""
        print(f"  t={ev.at_s:5.1f}s  {ev.kind:9s} {ev.node}{fac}{dur}")

    print(f"\n{len(trace)} requests over 40 s, 1 prefill + 3 decode "
          f"boards, checkpoint tick every "
          f"{recovery.checkpoint_interval_s}s:")
    base = FleetSim(fleet(), trace, **SLO).run()
    show("fault-free", base)

    registry = MetricsRegistry()
    tracer = SpanTracer(enabled=True, registry=registry)
    rep = FleetSim(fleet(), trace, faults=plan, recovery=recovery,
                   tracer=tracer, registry=registry, **SLO).run()
    show("with recovery", rep)
    norec = FleetSim(fleet(), trace, faults=plan, **SLO).run()
    show("no recovery", norec)

    print(f"\nwith recovery: crashes={rep.crashes} "
          f"recovered_lanes={rep.recovered_lanes} "
          f"replayed_from_prompt={rep.replayed_from_prompt} "
          f"checkpoints={rep.checkpoints} retries={rep.retries} "
          f"goodput_vs_base={rep.goodput_rps / base.goodput_rps:.3f}")
    print("fault log:")
    for line in rep.fault_events:
        print(f"  {line}")
    print("straggler monitor (sim-clock EWMA):")
    for line in rep.derate_detected or ["  (no flags)"]:
        print(f"  {line}")

    assert rep.requests_lost == 0, "recovery drill lost requests"
    assert norec.requests_lost > 0, "no-recovery arm should lose work"

    tracer.save("fault_drill_trace.json")
    n_recover = len(tracer.spans_named("sim.recover"))
    print(f"\nwrote fault_drill_trace.json ({len(tracer.spans)} spans, "
          f"{n_recover} recovery transfers, "
          f"{len(tracer.instants_named('sim.fault.crash'))} crash "
          f"instant) -- open at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
