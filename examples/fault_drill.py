"""Fault drill: crash a decode board mid-trace and watch it recover.

A scripted game-day for the reclaimed-GPU fleet.  One deterministic
scenario runs three ways -- fault-free, faulted WITH a recovery policy,
faulted WITHOUT one -- while the fault plan thermally derates one CMP
board, flaps the prefill board's host link, stalls a board briefly, and
kills a decode board outright at t=20.1s:

* with recovery, the dead board's live lanes resume from their last
  host-side checkpoint on a surviving board (pages re-sent over the
  PCIe-1.1-x4 link) and nothing is lost;
* without recovery, everything the crash touched is LOST;
* the training-loop straggler monitor, re-used on the sim clock, flags
  the derated board from its s/token EWMA alone.

The recovery run also carries the full observability stack: a flight
recorder whose ring the crash dumps to ``flight_<node>.jsonl``, and an
SLO burn-rate controller that walks the degradation ladder while the
derate window burns the tpot budget.  The run leaves a
Perfetto-loadable trace (``fault_drill_trace.json``, open at
https://ui.perfetto.dev) with the fault windows, the crash instant,
the recovery transfers and the straggler flag on their nodes' tracks,
then renders every artifact through ``python -m repro.obs.dump``.

Run:  PYTHONPATH=src python examples/fault_drill.py
"""

import glob
import os

from repro.fleet import (FaultEvent, FaultPlan, FleetSim, LengthDist,
                         NodeSpec, RecoveryPolicy, RetryPolicy,
                         poisson_trace)
from repro.obs import (BurnRateMonitor, FlightRecorder, MetricsRegistry,
                       SLOController, SLOObjective, SpanTracer, dump)
from repro.serving import DegradationLadder

SLO = dict(ttft_slo_s=2.0, tpot_slo_s=0.08)


def fleet():
    return [NodeSpec("a100-40g", 1, "prefill"),
            NodeSpec("cmp-170hx-nofma", 3, "decode", decode_lanes=8,
                     kv_pool_pages=512, page_size=16)]


def show(tag, rep):
    print(f"  {tag:18s} completed={rep.completed:3d}/{rep.offered}  "
          f"goodput={rep.goodput_rps:5.2f} req/s  "
          f"tpot p99={rep.tpot_p99_s * 1e3:5.2f} ms  "
          f"lost={rep.requests_lost}")


def main():
    trace = poisson_trace(6.0, 40.0, seed=2,
                          prompt=LengthDist(256, cv=0.3),
                          gen=LengthDist(512, cv=0.5))
    plan = FaultPlan(events=(
        FaultEvent("derate", node="cmp-170hx-nofma/decode#1", at_s=5.0,
                   factor=3.0, duration_s=12.0),
        FaultEvent("crash", node="cmp-170hx-nofma/decode#2", at_s=20.1),
        FaultEvent("transient", node="cmp-170hx-nofma/decode#3",
                   at_s=30.0, duration_s=0.25),
    )) + FaultPlan.flap("a100-40g/prefill#0", t0=8.0, period_s=2.0,
                        n_flaps=3, factor=4.0)
    recovery = RecoveryPolicy(checkpoint_interval_s=0.5,
                              retry=RetryPolicy(max_attempts=4))

    print(f"fault plan ({len(plan.events)} events):")
    for ev in plan.sim_events():
        dur = f" for {ev.duration_s:.2f}s" if ev.duration_s else ""
        fac = f" x{ev.factor:.0f}" if ev.factor > 1 else ""
        print(f"  t={ev.at_s:5.1f}s  {ev.kind:9s} {ev.node}{fac}{dur}")

    print(f"\n{len(trace)} requests over 40 s, 1 prefill + 3 decode "
          f"boards, checkpoint tick every "
          f"{recovery.checkpoint_interval_s}s:")
    base = FleetSim(fleet(), trace, **SLO).run()
    show("fault-free", base)

    for stale in glob.glob("flight_*.jsonl"):
        os.remove(stale)                  # fresh drill, fresh dumps
    registry = MetricsRegistry()
    tracer = SpanTracer(enabled=True, registry=registry)
    ladder = DegradationLadder()
    # tighter objective than the report SLO: the x3 derate pushes tpot
    # past 3 ms while healthy boards stay under 2 ms, so the burn-rate
    # loop visibly walks the ladder up during the window and back down
    ctl = SLOController(
        BurnRateMonitor(SLOObjective(tpot_s=0.003, error_budget=0.05),
                        short_window_s=4.0, long_window_s=15.0,
                        registry=registry),
        ladder, escalate_every_s=2.0, relax_every_s=3.0)
    rep = FleetSim(fleet(), trace, faults=plan, recovery=recovery,
                   tracer=tracer, registry=registry, slo=ctl,
                   flight=FlightRecorder(name="fleet"), **SLO).run()
    show("with recovery", rep)
    norec = FleetSim(fleet(), trace, faults=plan, **SLO).run()
    show("no recovery", norec)

    print(f"\nwith recovery: crashes={rep.crashes} "
          f"recovered_lanes={rep.recovered_lanes} "
          f"replayed_from_prompt={rep.replayed_from_prompt} "
          f"checkpoints={rep.checkpoints} retries={rep.retries} "
          f"goodput_vs_base={rep.goodput_rps / base.goodput_rps:.3f}")
    print("fault log:")
    for line in rep.fault_events:
        print(f"  {line}")
    print("straggler monitor (sim-clock EWMA):")
    for line in rep.derate_detected or ["  (no flags)"]:
        print(f"  {line}")

    assert rep.requests_lost == 0, "recovery drill lost requests"
    assert norec.requests_lost > 0, "no-recovery arm should lose work"

    obj = ctl.monitor.objective
    print("SLO burn-rate controller (tpot objective "
          f"{obj.tpot_s * 1e3:.0f} ms, budget "
          f"{obj.error_budget:.0%}):")
    for t, action, level in ctl.actions or []:
        print(f"  t={t:5.1f}s  {action:10s} -> {level}")
    if not ctl.actions:
        print("  (no ladder moves)")

    tracer.save("fault_drill_trace.json")
    n_recover = len(tracer.spans_named("sim.recover"))
    print(f"\nwrote fault_drill_trace.json ({len(tracer.spans)} spans, "
          f"{n_recover} recovery transfers, "
          f"{len(tracer.instants_named('sim.fault.crash'))} crash "
          f"instant) -- open at https://ui.perfetto.dev")

    # render every artifact the drill produced through the dump CLI
    artifacts = ["fault_drill_trace.json"] + sorted(
        glob.glob("flight_*.jsonl"))
    print(f"\npython -m repro.obs.dump {' '.join(artifacts)}")
    dump.main(artifacts)


if __name__ == "__main__":
    main()
