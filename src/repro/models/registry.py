"""Model registry: uniform init / loss / decode entry points per family."""

from __future__ import annotations

from typing import Any, Callable, Dict

import jax.numpy as jnp

from repro.models import transformer, whisper
from repro.models.common import ModelConfig


class Model:
    """Thin dispatcher binding a ModelConfig to its family's functions."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ------------------------------------------------------------
    def init(self, rng):
        if self.cfg.is_encdec:
            return whisper.init_whisper(rng, self.cfg)
        return transformer.init_lm(rng, self.cfg)

    # -- training --------------------------------------------------------
    def loss(self, params, batch: Dict[str, jnp.ndarray],
             remat: bool = False) -> jnp.ndarray:
        if self.cfg.is_encdec:
            return whisper.whisper_loss(params, batch, self.cfg, remat=remat)
        return transformer.lm_loss(params, batch, self.cfg, remat=remat)

    def forward(self, params, batch: Dict[str, jnp.ndarray]):
        if self.cfg.is_encdec:
            enc = whisper.encode(params, batch["frames"], self.cfg)
            return whisper.decode_forward(params, batch["tokens"], enc,
                                          self.cfg)
        logits, _ = transformer.lm_forward(
            params, batch["tokens"], self.cfg,
            vision_embeds=batch.get("vision_embeds"))
        return logits

    # -- serving ---------------------------------------------------------
    def init_cache(self, params, batch: int, max_len: int,
                   enc: jnp.ndarray = None):
        if self.cfg.is_encdec:
            assert enc is not None, "whisper cache needs encoder states"
            return whisper.init_whisper_cache(params, enc, self.cfg, batch,
                                              max_len)
        return transformer.init_cache(self.cfg, batch, max_len)

    def init_paged_cache(self, params, batch: int, max_len: int, *,
                         page_size: int = 16, n_pages=None):
        """Page-pool decode cache (see transformer.init_paged_cache);
        enc-dec caches hold cross-attention state and stay dense."""
        assert not self.cfg.is_encdec, "paged cache: decoder-only families"
        return transformer.init_paged_cache(self.cfg, batch, max_len,
                                            page_size=page_size,
                                            n_pages=n_pages)

    def decode_step(self, params, cache, tokens):
        if self.cfg.is_encdec:
            return whisper.whisper_decode_step(params, self.cfg, cache,
                                               tokens)
        return transformer.lm_decode_step(params, self.cfg, cache, tokens)

    def decode_n_steps(self, params, cache, tokens, rng, remaining,
                       lane_seed, tok_idx, *, n_steps, temperature=0.0,
                       len_cap=0):
        """Multi-token decode dispatch (see transformer.lm_decode_n_steps);
        works for every family with a decode step, including enc-dec."""
        if self.cfg.is_encdec:
            step_fn = lambda c, t: whisper.whisper_decode_step(  # noqa: E731
                params, self.cfg, c, t)
        else:
            step_fn = None
        return transformer.lm_decode_n_steps(
            params, self.cfg, cache, tokens, rng, remaining, lane_seed,
            tok_idx, n_steps=n_steps, temperature=temperature,
            len_cap=len_cap, step_fn=step_fn)

    def encode(self, params, frames):
        assert self.cfg.is_encdec
        return whisper.encode(params, frames, self.cfg)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
