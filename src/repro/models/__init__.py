from repro.models.common import (ModelConfig, MoEConfig, SSMConfig,
                                 cross_entropy, pad_vocab)
from repro.models.registry import Model, build_model

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "cross_entropy",
           "pad_vocab", "Model", "build_model"]
