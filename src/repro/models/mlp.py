"""Feed-forward blocks: SwiGLU (LM default) and GELU MLP (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, split_keys
from repro.parallel.sharding import constrain


def init_swiglu(key, d_model: int, d_ff: int):
    kg, ku, kd = split_keys(key, 3)
    return {
        "w_gate": dense_init(kg, (d_model, d_ff)),
        "w_up": dense_init(ku, (d_model, d_ff)),
        "w_down": dense_init(kd, (d_ff, d_model)),
    }


def swiglu(p, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    # pin the hidden activation to d_ff-over-model (Megatron TP): guides
    # the bwd dW dot to reduce-scatter instead of gathering h.
    h = constrain(h, *(("batch",) + (None,) * (h.ndim - 2) + ("tp",)))
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))


def init_gelu_mlp(key, d_model: int, d_ff: int):
    k1, k2 = split_keys(key, 2)
    return {
        "w1": dense_init(k1, (d_model, d_ff)),
        "b1": jnp.zeros((d_ff,), jnp.float32),
        "w2": dense_init(k2, (d_ff, d_model)),
        "b2": jnp.zeros((d_model,), jnp.float32),
    }


def gelu_mlp(p, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, p["w1"].astype(x.dtype))
    h = h + p["b1"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = constrain(h, *(("batch",) + (None,) * (h.ndim - 2) + ("tp",)))
    return jnp.einsum("...f,fd->...d", h, p["w2"].astype(x.dtype)) + \
        p["b2"].astype(x.dtype)
