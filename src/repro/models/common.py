"""Shared model substrate: configs, norms, RoPE, embeddings, init.

All models are *functional*: parameters are nested dicts of jnp arrays,
layers are stacked along a leading axis and traversed with
``jax.lax.scan`` so HLO size (and dry-run compile time) is O(1) in depth.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------
# Config
# ----------------------------------------------------------------------

VOCAB_ALIGN = 256  # Megatron convention: pad vocab for clean TP sharding


def pad_vocab(v: int, align: int = VOCAB_ALIGN) -> int:
    return (v + align - 1) // align * align


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    capacity_factor: float = 1.25
    dense_residual: bool = False   # Arctic: dense FFN in parallel with MoE
    n_shared_experts: int = 0      # Moonlight/DeepSeek style
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    # fraction of d_model given to the SSM branch in hybrid blocks
    d_inner_override: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers every family in the pool (see configs/)."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm | nonparametric_ln
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    max_seq_len: int = 131072
    sliding_window: Optional[int] = None   # hybrid/hymba local attention
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    # vlm: number of prefix vision tokens the stub frontend provides
    n_vision_tokens: int = 0
    dtype: str = "bfloat16"
    # kernels: use Pallas paths (TPU) vs jnp reference paths (CPU tests)
    use_pallas: bool = False
    # decode KV cache quantization: None | "int8" (per-token-per-head
    # symmetric scales; beyond-paper application of C4)
    kv_quant: Optional[str] = None

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def active_params_per_layer(self) -> float:
        """Active (per-token) parameter count of one block."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.family == "ssm":
            s = self.ssm or SSMConfig()
            d_in = s.expand * d
            return 2 * d * d_in + d_in * d + d_in * (2 * s.state_dim)
        mlp = 3 * d * self.d_ff
        if self.moe is not None:
            m = self.moe
            mlp = 3 * d * m.d_expert_ff * (m.top_k + m.n_shared_experts)
            if m.dense_residual:
                mlp += 3 * d * self.d_ff
        if self.family == "hybrid":
            s = self.ssm or SSMConfig()
            d_in = s.d_inner_override or (s.expand * d)
            return attn + mlp + 2 * d * d_in + d_in * d
        return attn + mlp

    def active_params(self) -> float:
        body = self.n_layers * self.active_params_per_layer()
        emb = self.d_model * self.padded_vocab
        if not self.tie_embeddings:
            emb *= 2
        return body + emb

    def total_params(self) -> float:
        per = self.active_params_per_layer()
        if self.moe is not None:
            m = self.moe
            d = self.d_model
            per = (per - 3 * d * m.d_expert_ff * (m.top_k + m.n_shared_experts)
                   + 3 * d * m.d_expert_ff * (m.n_experts
                                              + m.n_shared_experts))
        body = self.n_layers * per
        emb = self.d_model * self.padded_vocab * (1 if self.tie_embeddings
                                                  else 2)
        return body + emb


# ----------------------------------------------------------------------
# Initialization
# ----------------------------------------------------------------------

def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (kept f32; cast at use)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dtype=jnp.float32):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.norm == "nonparametric_ln":      # OLMo: no learned affine
        return {}
    raise ValueError(cfg.norm)


def apply_norm(params, x: jnp.ndarray, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf / rms * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, head_dim: int,
                theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (..., S) int -> cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D); cos/sin: (..., S, D//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    # rotate-half convention (llama / qwen)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
                           ).astype(x.dtype)


# ----------------------------------------------------------------------
# Embedding / LM head
# ----------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    p = {"tok": dense_init(key, (cfg.padded_vocab, cfg.d_model), scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(jax.random.fold_in(key, 1),
                               (cfg.d_model, cfg.padded_vocab))
    return p


def embed(params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return params["tok"].astype(dtype)[tokens]


def lm_logits(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Final projection with padded-vocab masking to -inf."""
    if cfg.tie_embeddings:
        w = params["tok"].astype(x.dtype).T
    else:
        w = params["head"].astype(x.dtype)
    logits = jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy in f32. logits (..., V), labels (...).

    The gold logit is extracted with a one-hot einsum rather than
    ``take_along_axis`` so a vocab-sharded logits tensor reduces with a
    psum instead of an all-gather (GSPMD-friendly).
    """
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("...v,...v->...", logits, onehot)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


@jax.custom_jvp
def _scan_barrier(tree):
    """`optimization_barrier` with an identity differentiation rule.

    `optimization_barrier` has no JVP registered, so routing the scan
    carry through it raw breaks `jax.grad` over any scanned model.  The
    barrier only constrains *scheduling*; its tangent map is the
    identity, so the custom rule passes tangents straight through while
    the primal keeps pinning the weight all-gather inside the loop.
    """
    return jax.lax.optimization_barrier(tree)


@_scan_barrier.defjvp
def _scan_barrier_jvp(primals, tangents):
    (tree,), (dtree,) = primals, tangents
    return _scan_barrier(tree), dtree


def layer_scan(body, carry, xs):
    """lax.scan over stacked layers; fully unrolled when
    REPRO_SCAN_UNROLL=1 (dry-run mode) so XLA cost_analysis counts every
    layer instead of one while-loop body.

    The scanned path threads layer params through an optimization
    barrier tied to the carry, so the SPMD partitioner cannot hoist the
    FSDP weight all-gather out of the loop (which would materialize
    every layer's gathered weights at once -- the praxis/paxml trick).
    """
    unroll = os.environ.get("REPRO_SCAN_UNROLL", "0") == "1"
    if not unroll:
        def barrier_body(c, x):
            c, x = _scan_barrier((c, x))
            return body(c, x)
        return jax.lax.scan(barrier_body, carry, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        xi = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
