"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch.

Covers both assigned MoE architectures:

* **arctic-480b** -- 128 experts, top-2, plus a *dense residual* FFN in
  parallel (Snowflake Arctic's dense-MoE hybrid).
* **moonshot-v1-16b-a3b** -- 64 experts, top-6 (Moonlight/DeepSeek
  family), optional shared experts.

Dispatch is sort-based (Megablocks-style) rather than one-hot-einsum
(GShard): a (tokens x k) assignment list is sorted by expert id and
scattered into an (E, C, d) buffer -- memory O(E*C*d), not O(T*E*C) --
which is what makes 1M-token batches with 128 experts compileable.  The
expert dimension shards over the `model` mesh axis (expert parallelism);
GSPMD turns the scatter/gather into all-to-alls.

Aux losses: switch-style load-balance loss + router z-loss, returned to
the caller for accumulation.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import MoEConfig, dense_init, split_keys
from repro.parallel.sharding import constrain
from repro.models.mlp import init_swiglu, swiglu


def init_moe(key, d_model: int, moe: MoEConfig):
    kr, ke, ks = split_keys(key, 3)
    E, f = moe.n_experts, moe.d_expert_ff
    keys = split_keys(ke, 3)
    p = {
        "router": dense_init(kr, (d_model, E), scale=0.02),
        "w_gate": dense_init(keys[0], (E, d_model, f)),
        "w_up": dense_init(keys[1], (E, d_model, f)),
        "w_down": dense_init(keys[2], (E, f, d_model)),
    }
    if moe.n_shared_experts:
        p["shared"] = init_swiglu(ks, d_model, f * moe.n_shared_experts)
    return p


def _capacity(n_tokens: int, moe: MoEConfig) -> int:
    c = int(moe.capacity_factor * n_tokens * moe.top_k / moe.n_experts)
    return max(8, (c + 7) // 8 * 8)  # 8-aligned for TPU sublanes


def moe_forward(p, x: jnp.ndarray, moe: MoEConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).

    Million-token batches (32k prefill) are dispatched in fixed-size
    token chunks (lax.scan) so the (E, C, d) buffers stay bounded --
    REPRO_MOE_CHUNK tokens per chunk (0 disables; the dry-run cost pass
    disables it because an un-chunked graph is compile-only there).
    """
    import os
    b, s, d = x.shape
    t = b * s
    chunk = int(os.environ.get("REPRO_MOE_CHUNK", "65536"))
    if chunk and t > chunk and t % chunk == 0:
        from repro.models.common import layer_scan
        xc = x.reshape(t // chunk, 1, chunk, d)

        def body(aux, xi):
            out, a = _moe_tokens(p, xi, moe)
            return aux + a, out

        aux, outs = layer_scan(body, jnp.zeros((), jnp.float32), xc)
        return (outs.reshape(b, s, d),
                aux * (chunk / float(t)))
    return _moe_tokens(p, x, moe)


def _moe_tokens(p, x: jnp.ndarray, moe: MoEConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    E, k, C = moe.n_experts, moe.top_k, _capacity(t, moe)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (t, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance + z losses (Switch Transformer eqs) ----
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = moe.router_aux_weight * E * jnp.sum(me * ce)
    aux = aux + 1e-4 * jnp.mean(
        jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

    # ---- sort-based dispatch with capacity ----
    flat_expert = expert_ids.reshape(-1)                     # (t*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_expert)                         # stable
    se, st, sg = (flat_expert[order], flat_token[order], flat_gate[order])
    # position within expert: rank - segment_start
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(t * k) - seg_start[se]
    keep = pos < C
    # scatter tokens into (E, C, d); dropped tokens scatter to a dump row
    e_idx = jnp.where(keep, se, E - 1)
    c_idx = jnp.where(keep, pos, C)                          # C = dump slot
    buf = constrain(jnp.zeros((E, C + 1, d), x.dtype),
                    "expert", None, "fsdp")
    buf = buf.at[e_idx, c_idx].add(jnp.where(keep[:, None],
                                             xf[st], 0).astype(x.dtype))
    buf = constrain(buf, "expert", None, "fsdp")
    # d-dim sharded like the expert weights' contraction dim: the expert
    # einsums then produce partial sums (psum of the small activations)
    # instead of all-gathering the expert weights -- which GSPMD would
    # hoist out of the layer scan, materializing every layer's experts.
    ebuf = constrain(buf[:, :C, :], "expert", None, "fsdp")  # (E, C, d)

    # ---- expert computation (E sharded over `model`) ----
    g = jnp.einsum("ecd,edf->ecf", ebuf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", ebuf, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    eout = constrain(eout, "expert", None, "fsdp")

    # ---- combine back to token order, weighted by gates ----
    gathered = eout[e_idx, c_idx]                            # (t*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    out = constrain(jnp.zeros((t, d), x.dtype), "batch", None).at[st].add(
        gathered * sg[:, None].astype(x.dtype))

    if moe.n_shared_experts:
        out = out + swiglu(p["shared"], xf)
    return out.reshape(b, s, d), aux
