"""Decoder-only LM assembly covering dense / moe / ssm / hybrid / vlm.

One block definition per family, layers stacked along a leading axis and
executed with ``jax.lax.scan`` (HLO is O(1) in depth -> 80-layer dry-runs
compile in seconds).  Training, prefill (cache build) and single-token
decode all share the same per-layer functions.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (attention_decode, attention_decode_paged,
                                    attention_forward, init_attention)
from repro.models.common import (ModelConfig, apply_norm, cross_entropy, layer_scan,
                                 embed, init_embedding, init_norm, lm_logits,
                                 split_keys)
from repro.models.mlp import init_swiglu, swiglu
from repro.models.moe import init_moe, moe_forward
from repro.models.ssm import (init_mamba2, init_mamba2_state, mamba2_decode,
                              mamba2_forward)
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


# ----------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------

def init_block(key, cfg: ModelConfig) -> Params:
    keys = split_keys(key, 6)
    p: Params = {"norm1": init_norm(cfg)}
    if cfg.family == "ssm":
        p["ssm"] = init_mamba2(keys[0], cfg)
        return p
    p["attn"] = init_attention(keys[0], cfg)
    p["norm2"] = init_norm(cfg)
    if cfg.family == "moe":
        p["moe"] = init_moe(keys[1], cfg.d_model, cfg.moe)
        if cfg.moe.dense_residual:
            p["mlp"] = init_swiglu(keys[2], cfg.d_model, cfg.d_ff)
    elif cfg.family == "hybrid":
        p["ssm"] = init_mamba2(keys[1], cfg)
        p["mlp"] = init_swiglu(keys[2], cfg.d_model, cfg.d_ff)
    else:  # dense / vlm backbone
        p["mlp"] = init_swiglu(keys[1], cfg.d_model, cfg.d_ff)
    return p


def _sp_in(h):
    """Megatron-SP boundary: gather the sequence dim before projections
    so the TP (`model`) axis is free for weight shards -- otherwise GSPMD
    resolves the seq-vs-d_ff axis conflict by fully gathering the weight
    matrices (GBs/layer)."""
    return constrain(h, "batch", None, None)


def _sp_out(y):
    """Reduce-scatter block output back to the sequence-sharded stream."""
    return constrain(y, "batch", "seq", None)


def block_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  positions=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _sp_in(apply_norm(p["norm1"], x, cfg.norm))
    if cfg.family == "ssm":
        return x + _sp_out(mamba2_forward(p["ssm"], h, cfg)), aux
    if cfg.family == "hybrid":
        # Hymba: parallel attention + SSM heads over the same input,
        # fused by averaging (arXiv:2411.13676, simplified combiner).
        att = attention_forward(p["attn"], h, cfg, positions=positions)
        ssm = mamba2_forward(p["ssm"], h, cfg)
        x = x + _sp_out(0.5 * (att + ssm))
        h2 = _sp_in(apply_norm(p["norm2"], x, cfg.norm))
        return x + _sp_out(swiglu(p["mlp"], h2)), aux
    x = x + _sp_out(attention_forward(p["attn"], h, cfg,
                                      positions=positions))
    h2 = _sp_in(apply_norm(p["norm2"], x, cfg.norm))
    if cfg.family == "moe":
        mout, aux = moe_forward(p["moe"], h2, cfg.moe)
        if cfg.moe.dense_residual:
            mout = mout + swiglu(p["mlp"], h2)
        return x + _sp_out(mout), aux
    return x + _sp_out(swiglu(p["mlp"], h2)), aux


# ----------------------------------------------------------------------
# Model init / forward
# ----------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> Params:
    kemb, kblocks, kfinal = split_keys(key, 3)
    blocks = [init_block(jax.random.fold_in(kblocks, i), cfg)
              for i in range(cfg.n_layers)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return {
        "embed": init_embedding(kemb, cfg),
        "blocks": stacked,
        "final_norm": init_norm(cfg),
    }


def _maybe_inject_vision(x, vision_embeds, cfg: ModelConfig):
    if vision_embeds is None or cfg.n_vision_tokens == 0:
        return x
    n = vision_embeds.shape[1]
    return jnp.concatenate(
        [vision_embeds.astype(x.dtype), x[:, n:, :]], axis=1)


def lm_forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig, *,
               vision_embeds: Optional[jnp.ndarray] = None,
               remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) -> (logits (B,S,V), aux_loss)."""
    x = embed(params["embed"], tokens, cfg.compute_dtype)
    x = _maybe_inject_vision(x, vision_embeds, cfg)
    # sequence-sharded residual stream (Megatron-SP): the scan carry is
    # saved per layer by remat, so sharding it over `model` divides the
    # dominant training-memory term by the TP width.
    x = constrain(x, "batch", "seq", None)

    def body(carry, layer_params):
        xx, aux = carry
        xx, a = block_forward(layer_params, xx, cfg)
        xx = constrain(xx, "batch", "seq", None)
        return (xx, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = layer_scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embed"], x, cfg), aux


def lm_prefill_batched(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
                       vision_embeds: Optional[jnp.ndarray] = None,
                       last_pos: Optional[jnp.ndarray] = None):
    """Serving prefill: full-sequence pass that RETURNS the KV cache and
    only the last-position logits (llama.cpp semantics).  Attention-free
    families return logits only (their state is O(1) and rebuilt by the
    engine).

    ``last_pos`` (B,) selects which position's logits to return; it lets
    the engine right-pad prompts to a shape bucket (causal attention
    keeps positions < last_pos untouched by the padding) so prompt
    lengths stop forcing one XLA compile each."""
    x = embed(params["embed"], tokens, cfg.compute_dtype)
    x = _maybe_inject_vision(x, vision_embeds, cfg)
    x = constrain(x, "batch", "seq", None)
    has_attn = cfg.family != "ssm"

    def body(xx, layer_params):
        h = _sp_in(apply_norm(layer_params["norm1"], xx, cfg.norm))
        if cfg.family == "ssm":
            from repro.models.ssm import mamba2_forward
            return xx + _sp_out(
                mamba2_forward(layer_params["ssm"], h, cfg)), None
        att, kv = attention_forward(layer_params["attn"], h, cfg,
                                    return_kv=True)
        if cfg.family == "hybrid":
            from repro.models.ssm import mamba2_forward
            ssm = mamba2_forward(layer_params["ssm"], h, cfg)
            xx = xx + _sp_out(0.5 * (att + ssm))
        else:
            xx = xx + _sp_out(att)
        h2 = _sp_in(apply_norm(layer_params["norm2"], xx, cfg.norm))
        if cfg.family == "moe":
            mout, _ = moe_forward(layer_params["moe"], h2, cfg.moe)
            if cfg.moe.dense_residual:
                mout = mout + swiglu(layer_params["mlp"], h2)
            xx = xx + _sp_out(mout)
        else:
            xx = xx + _sp_out(swiglu(layer_params["mlp"], h2))
        return xx, kv

    x, kv = layer_scan(body, x, params["blocks"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if last_pos is None:
        x_last = x[:, -1]
    else:
        x_last = jnp.take_along_axis(
            x, last_pos.astype(jnp.int32)[:, None, None], axis=1)[:, 0]
    logits = lm_logits(params["embed"], x_last, cfg)
    return (logits, kv) if has_attn else (logits, None)


def lm_loss(params: Params, batch: Dict[str, jnp.ndarray],
            cfg: ModelConfig, *, remat: bool = False) -> jnp.ndarray:
    logits, aux = lm_forward(params, batch["tokens"], cfg,
                             vision_embeds=batch.get("vision_embeds"),
                             remat=remat)
    mask = batch.get("loss_mask")
    if mask is None and cfg.n_vision_tokens:
        mask = (jnp.arange(batch["tokens"].shape[1])[None, :]
                >= cfg.n_vision_tokens).astype(jnp.float32)
        mask = jnp.broadcast_to(mask, batch["tokens"].shape)
    return cross_entropy(logits, batch["labels"], mask) + aux


# ----------------------------------------------------------------------
# KV / state cache + decode
# ----------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Per-family decode cache, stacked over layers."""
    L = cfg.n_layers
    cache: Params = {"len": jnp.zeros((batch,), jnp.int32)}
    if cfg.family != "ssm":
        win = cfg.sliding_window
        s = min(max_len, win) if win else max_len
        kv_shape = (L, batch, cfg.n_kv_heads, s, cfg.hd)
        if cfg.kv_quant == "int8":
            cache["k"] = jnp.zeros(kv_shape, jnp.int8)
            cache["v"] = jnp.zeros(kv_shape, jnp.int8)
            sc_shape = (L, batch, cfg.n_kv_heads, s, 1)
            cache["k_scale"] = jnp.ones(sc_shape, jnp.float32)
            cache["v_scale"] = jnp.ones(sc_shape, jnp.float32)
        else:
            cache["k"] = jnp.zeros(kv_shape, cfg.compute_dtype)
            cache["v"] = jnp.zeros(kv_shape, cfg.compute_dtype)
    if cfg.family in ("ssm", "hybrid"):
        st = init_mamba2_state(cfg, batch)
        cache["ssm_h"] = jnp.broadcast_to(
            st["h"][None], (L,) + st["h"].shape).copy()
        cache["ssm_conv"] = jnp.broadcast_to(
            st["conv"][None], (L,) + st["conv"].shape).copy()
    return cache


#: cache keys that are NOT stacked per layer: ``len`` is per-lane
#: metadata; ``block_tables`` names pool pages shared by every layer.
CACHE_SHARED_KEYS = ("len", "block_tables")


def paged_capacity(max_len: int, cfg: ModelConfig) -> int:
    """Positions one lane's block table must back: the window if the
    config slides, else the full context."""
    win = cfg.sliding_window
    return min(max_len, win) if win else max_len


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     page_size: int = 16,
                     n_pages: Optional[int] = None) -> Params:
    """Paged decode cache: a global page pool plus per-lane block tables.

    Layout (vs :func:`init_cache`'s dense ``(L, B, Hkv, smax, D)``):

    * ``k_pages``/``v_pages``: ``(L, P, Hkv, ps, D)`` -- one pool shared
      by all lanes; a physical page holds ``ps`` consecutive positions
      of ONE lane (all layers use the same page id for a given logical
      page, so the table is per-lane, not per-layer);
    * ``block_tables``: ``(B, T)`` int32, lane's physical page ids in
      logical order (``T = capacity/ps``); rides the scan carry next to
      ``len``, un-sliced by the layer loop;
    * int8 adds ``k_scale_pages``/``v_scale_pages`` ``(L, P, Hkv, ps, 1)``
      per-token scales (same quantization as the dense int8 cache).

    ``n_pages`` defaults to dense-equivalent capacity
    (``batch * T``); a SERVING caller passes fewer lanes' worth and
    admission becomes proportional to live KV bytes instead of lanes.
    SSM/hybrid recurrent state is O(1) per lane and stays dense.

    Block tables initialize to page 0 for every lane: the CALLER owns
    the lane -> page mapping and must assign disjoint pages before
    decoding more than one lane (``ServeEngine`` additionally keeps a
    scratch page for dead lanes, whose frozen-slot writes would
    otherwise land on re-issued pages).
    """
    L = cfg.n_layers
    cache: Params = {"len": jnp.zeros((batch,), jnp.int32)}
    if cfg.family != "ssm":
        s = paged_capacity(max_len, cfg)
        assert s % page_size == 0, (
            f"page_size {page_size} must divide cache capacity {s}")
        bt_width = s // page_size
        if n_pages is None:
            n_pages = batch * bt_width
        cache["block_tables"] = jnp.zeros((batch, bt_width), jnp.int32)
        kv_shape = (L, n_pages, cfg.n_kv_heads, page_size, cfg.hd)
        if cfg.kv_quant == "int8":
            cache["k_pages"] = jnp.zeros(kv_shape, jnp.int8)
            cache["v_pages"] = jnp.zeros(kv_shape, jnp.int8)
            sc_shape = (L, n_pages, cfg.n_kv_heads, page_size, 1)
            cache["k_scale_pages"] = jnp.ones(sc_shape, jnp.float32)
            cache["v_scale_pages"] = jnp.ones(sc_shape, jnp.float32)
        else:
            cache["k_pages"] = jnp.zeros(kv_shape, cfg.compute_dtype)
            cache["v_pages"] = jnp.zeros(kv_shape, cfg.compute_dtype)
    if cfg.family in ("ssm", "hybrid"):
        st = init_mamba2_state(cfg, batch)
        cache["ssm_h"] = jnp.broadcast_to(
            st["h"][None], (L,) + st["h"].shape).copy()
        cache["ssm_conv"] = jnp.broadcast_to(
            st["conv"][None], (L,) + st["conv"].shape).copy()
    return cache


def _attn_decode(p, h, cfg, layer_cache, cache_len, new_cache,
                 attn_key="attn", block_tables=None):
    """Run cached attention, handling the quantized-KV and paged layouts."""
    if block_tables is not None:
        if cfg.kv_quant == "int8":
            att, kp, vp, ks, vs = attention_decode_paged(
                p[attn_key], h, cfg, layer_cache["k_pages"],
                layer_cache["v_pages"], block_tables, cache_len,
                layer_cache["k_scale_pages"], layer_cache["v_scale_pages"])
            new_cache.update(k_pages=kp, v_pages=vp, k_scale_pages=ks,
                             v_scale_pages=vs)
        else:
            att, kp, vp = attention_decode_paged(
                p[attn_key], h, cfg, layer_cache["k_pages"],
                layer_cache["v_pages"], block_tables, cache_len)
            new_cache.update(k_pages=kp, v_pages=vp)
        return att
    if cfg.kv_quant == "int8":
        att, kc, vc, ks, vs = attention_decode(
            p[attn_key], h, cfg, layer_cache["k"], layer_cache["v"],
            cache_len, layer_cache["k_scale"], layer_cache["v_scale"])
        new_cache.update(k=kc, v=vc, k_scale=ks, v_scale=vs)
    else:
        att, kc, vc = attention_decode(p[attn_key], h, cfg,
                                       layer_cache["k"], layer_cache["v"],
                                       cache_len)
        new_cache.update(k=kc, v=vc)
    return att


def block_decode(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                 layer_cache: Params, cache_len,
                 block_tables=None) -> Tuple[jnp.ndarray, Params]:
    """One-token decode through one block. x: (B, 1, d).

    ``block_tables`` (B, T) selects the paged-attention path; the dense
    per-lane cache path is the pinned parity reference.
    """
    new_cache = dict(layer_cache)
    h = apply_norm(p["norm1"], x, cfg.norm)
    if cfg.family == "ssm":
        y, st = mamba2_decode(p["ssm"], h, cfg,
                              {"h": layer_cache["ssm_h"],
                               "conv": layer_cache["ssm_conv"]})
        new_cache.update(ssm_h=st["h"], ssm_conv=st["conv"])
        return x + y, new_cache
    if cfg.family == "hybrid":
        att = _attn_decode(p, h, cfg, layer_cache, cache_len, new_cache,
                           block_tables=block_tables)
        ssm, st = mamba2_decode(p["ssm"], h, cfg,
                                {"h": layer_cache["ssm_h"],
                                 "conv": layer_cache["ssm_conv"]})
        new_cache.update(ssm_h=st["h"], ssm_conv=st["conv"])
        x = x + 0.5 * (att + ssm)
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        return x + swiglu(p["mlp"], h2), new_cache
    att = _attn_decode(p, h, cfg, layer_cache, cache_len, new_cache,
                       block_tables=block_tables)
    x = x + att
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    if cfg.family == "moe":
        mout, _ = moe_forward(p["moe"], h2, cfg.moe)
        if cfg.moe.dense_residual:
            mout = mout + swiglu(p["mlp"], h2)
        return x + mout, new_cache
    return x + swiglu(p["mlp"], h2), new_cache


def lm_decode_step(params: Params, cfg: ModelConfig, cache: Params,
                   tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """tokens: (B,) -> (logits (B, V), updated cache).

    The stacked (L, ...) cache rides the scan CARRY (not xs/ys): XLA
    aliases while-loop carries in place, so the multi-GB KV cache is
    updated without the double buffering a scan-output cache would cost.
    Each layer dynamic-slices its page out of the stack and writes the
    new token back at its layer index.  A paged cache carries its
    ``block_tables`` un-sliced next to ``len`` (the table is per-lane,
    shared by every layer); everything else stacks as before.
    """
    x = embed(params["embed"], tokens[:, None], cfg.compute_dtype)
    cache_len = cache["len"]
    block_tables = cache.get("block_tables")
    layer_keys = [k for k in cache if k not in CACHE_SHARED_KEYS]
    stack = {k: cache[k] for k in layer_keys}

    def body(carry, inp):
        x, stack = carry
        layer_params, i = inp
        layer_cache = {
            k: jax.lax.dynamic_index_in_dim(stack[k], i, 0, keepdims=False)
            for k in layer_keys}
        x, new_lc = block_decode(layer_params, x, cfg, layer_cache,
                                 cache_len, block_tables=block_tables)
        stack = {
            k: jax.lax.dynamic_update_index_in_dim(stack[k], new_lc[k], i, 0)
            for k in layer_keys}
        return (x, stack), None

    (x, stack), _ = layer_scan(
        body, (x, stack),
        (params["blocks"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x[:, 0], cfg)
    new_cache = dict(stack)
    new_cache["len"] = cache_len + 1
    if block_tables is not None:
        new_cache["block_tables"] = block_tables
    return logits, new_cache


def sample_tokens(logits: jnp.ndarray, rng, temperature: float
                  ) -> jnp.ndarray:
    """On-device greedy/temperature sampling. logits (B, V) -> (B,) int32.

    Lives next to the decode step so the logits tensor never leaves the
    device: the serving engine's per-token host round-trip (device->host
    logits copy + numpy argmax/categorical) collapses into the jitted
    step."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        rng, logits / temperature, axis=-1).astype(jnp.int32)


def sample_tokens_lanes(logits: jnp.ndarray, keys: jnp.ndarray,
                        temperature: float) -> jnp.ndarray:
    """Per-lane-keyed sampling: logits (B, V), keys (B,) of PRNG keys.

    Each lane draws with its own key, so a request's sampled stream is a
    pure function of (its key lineage, its token index) -- independent
    of lane neighbors, admission timing, and dispatch granularity."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.vmap(
        lambda k, l: jax.random.categorical(k, l / temperature))(
            keys, logits).astype(jnp.int32)


def lm_decode_sample_step(params: Params, cfg: ModelConfig, cache: Params,
                          tokens: jnp.ndarray, rng, *,
                          temperature: float = 0.0
                          ) -> Tuple[jnp.ndarray, Params]:
    """Fused decode step: advance one token AND sample the next, all on
    device. tokens (B,) -> (sampled (B,) int32, updated cache)."""
    logits, cache = lm_decode_step(params, cfg, cache, tokens)
    return sample_tokens(logits, rng, temperature), cache


def lm_decode_n_steps(params: Params, cfg: ModelConfig, cache: Params,
                      tokens: jnp.ndarray, rng, remaining: jnp.ndarray,
                      lane_seed: jnp.ndarray, tok_idx: jnp.ndarray, *,
                      n_steps: int, temperature: float = 0.0,
                      len_cap: int = 0, step_fn=None):
    """Advance every lane ``n_steps`` tokens in ONE host dispatch.

    A ``jax.lax.scan`` over the fused decode+sample step; tokens and
    validity flags accumulate on device and are drained by the caller in
    a single host transfer.  Each lane samples with key
    ``fold_in(fold_in(rng, lane_seed), tok_idx)`` -- ``lane_seed`` is
    the request's admission index, ``tok_idx`` its generated-token count
    -- so a request's stream is a pure function of its own identity:
    invariant to dispatch granularity, admission timing, and lane
    neighbors.

    ``remaining`` (B,) int32 is each lane's generation budget; exhausted
    lanes keep stepping (their KV writes land in a lane that will be
    re-prefilled on admission) but their samples are flagged invalid,
    their token index stops advancing, and their cache length is frozen
    (so the length-aware kernel does not stream a retired context).
    ``len_cap`` > 0 zeroes the budget once the cache length reaches it
    (the engine passes ``max_len - 1``).

    Returns (tokens (n, B), valid (n, B) bool, next_tokens (B,), cache,
    remaining, tok_idx).
    """
    if step_fn is None:
        step_fn = functools.partial(lm_decode_step, params, cfg)
    lane_keys = jax.vmap(lambda s: jax.random.fold_in(rng, s))(lane_seed)

    def body(carry, _):
        cache, tok, rem, idx = carry
        live = rem > 0
        len_before = cache["len"]
        logits, cache = step_fn(cache, tok)
        cache["len"] = jnp.where(live, cache["len"], len_before)
        keys = jax.vmap(jax.random.fold_in)(lane_keys, idx)
        nxt = sample_tokens_lanes(logits, keys, temperature)
        rem = jnp.where(live, rem - 1, 0)
        if len_cap > 0:
            rem = jnp.where(cache["len"] >= len_cap, 0, rem)
        idx = idx + live.astype(jnp.int32)
        return (cache, nxt, rem, idx), (nxt, live)

    (cache, tok, remaining, tok_idx), (toks, valid) = jax.lax.scan(
        body, (cache, tokens, remaining, tok_idx), None, length=n_steps)
    return toks, valid, tok, cache, remaining, tok_idx


def lm_prefill(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
               max_len: int,
               vision_embeds: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, Params]:
    """Build a decode cache by streaming the prompt one token at a time.

    Functional but deliberately simple -- the serving engine
    (``repro.serving``) uses the batched flash path for long prompts and
    falls back to this for correctness tests.
    """
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)

    def step(cache, t):
        logits, cache = lm_decode_step(params, cfg, cache, t)
        return cache, logits

    cache, logits = jax.lax.scan(step, cache, tokens.T)
    return logits[-1], cache
