"""Mamba-2 (SSD, state-space duality) block: chunked scan + decode step.

Implements the SSD algorithm of arXiv:2405.21060 with scalar-per-head A
and a single B/C group (n_groups=1), the mamba2-780m configuration:

  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T        (state: H x N x P)
  y_t = C_t . h_t + D x_t

Training uses the chunked dual form: intra-chunk attention-like term
``(L o C B^T) (dt*X)`` plus an inter-chunk state recurrence (lax.scan over
chunks), giving O(S Q) work with chunk Q.  Decode carries the (H, N, P)
state -- O(1) per token, which is what qualifies the SSM families for the
``long_500k`` shape (DESIGN.md SS4).

The block follows mamba_ssm's Mamba2: in_proj -> [z | x | B | C | dt],
causal depthwise conv on (x,B,C), SSD, gated RMSNorm, out_proj.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, SSMConfig, dense_init, split_keys


# ----------------------------------------------------------------------
# SSD core
# ----------------------------------------------------------------------

def ssd_naive(x, dt, a_log, b, c):
    """Reference recurrence. x: (B,S,H,P); dt: (B,S,H); a_log: (H,);
    b/c: (B,S,N). Returns y: (B,S,H,P)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    decay = jnp.exp(dt * a_log[None, None, :])            # (B,S,H)

    def step(hstate, inp):
        xt, dtt, bt, ct, dect = inp
        hstate = hstate * dect[..., None, None] + \
            dtt[..., None, None] * bt[:, None, :, None] * xt[:, :, None, :]
        yt = jnp.einsum("bn,bhnp->bhp", ct, hstate)
        return hstate, yt

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1).astype(jnp.float32),
          c.swapaxes(0, 1).astype(jnp.float32),
          decay.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1)                               # (B,S,H,P)


def _segsum(la):
    """Stable segment-sum: la (..., Q) -> (..., Q, Q) lower-tri cum-decays."""
    q = la.shape[-1]
    cum = jnp.cumsum(la, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :] + la[..., None, :] * 0
    # exp(la_i .. la_j window) = cum_i - cum_j + la_j ... we want
    # sum_{m=j+1..i} la_m = cum_i - cum_j
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, chunk: int = 256):
    """Chunked SSD (the dual form). Same signature as ssd_naive.

    Sequences not divisible by the chunk are zero-padded: padded steps
    carry dt=0 => decay exp(0)=1 and zero input, so the recurrence is
    unchanged.
    """
    bsz, s0, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s0)
    pad = (-s0) % q
    if pad:
        zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +  # noqa: E731
                               [(0, 0)] * (t.ndim - 2))
        x, dt, b, c = zp(x), zp(dt), zp(b), zp(c)
    s = s0 + pad
    nc = s // q
    f32 = jnp.float32
    xc = x.reshape(bsz, nc, q, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, q, h).astype(f32)
    bc = b.reshape(bsz, nc, q, n).astype(f32)
    cc = c.reshape(bsz, nc, q, n).astype(f32)
    la = dtc * a_log[None, None, None, :]                  # (B,NC,Q,H) log-decay
    la = la.transpose(0, 1, 3, 2)                          # (B,NC,H,Q)
    cum = jnp.cumsum(la, axis=-1)                          # within-chunk

    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i.B_j) x_j
    seg = _segsum(la)                                      # (B,NC,H,Q,Q)
    l_mat = jnp.exp(seg)
    cb = jnp.einsum("bzqn,bzkn->bzqk", cc, bc)             # (B,NC,Q,Q)
    w = cb[:, :, None] * l_mat                             # (B,NC,H,Q,Q)
    xdt = xc * dtc[..., None]                              # (B,NC,Q,H,P)
    y_intra = jnp.einsum("bzhqk,bzkhp->bzqhp", w, xdt)

    # chunk-final states: S_z = sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cum[..., -1:] - cum)            # (B,NC,H,Q)
    sz = jnp.einsum("bzhq,bzqn,bzqhp->bzhnp",
                    decay_to_end, bc, xdt)                 # (B,NC,H,N,P)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[..., -1])                    # (B,NC,H)

    def step(hstate, inp):
        s_z, dec = inp                                     # (B,H,N,P),(B,H)
        h_in = hstate
        hstate = hstate * dec[..., None, None] + s_z
        return hstate, h_in

    h0 = jnp.zeros((bsz, h, n, p), f32)
    _, h_starts = jax.lax.scan(
        step, h0, (sz.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_starts = h_starts.swapaxes(0, 1)                     # (B,NC,H,N,P)

    # inter-chunk output: y_i += exp(cum_i) C_i . h_start
    decay_in = jnp.exp(cum)                                # (B,NC,H,Q)
    y_inter = jnp.einsum("bzhq,bzqn,bzhnp->bzqhp",
                         decay_in, cc, h_starts)
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    if pad:
        y = y[:, :s0]
    return y.astype(x.dtype)


# ----------------------------------------------------------------------
# Mamba2 block
# ----------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    s = cfg.ssm or SSMConfig()
    d_inner = s.d_inner_override or (s.expand * cfg.d_model)
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.state_dim
    return s, d_inner, n_heads, conv_ch


def init_mamba2(key, cfg: ModelConfig):
    s, d_inner, nh, conv_ch = _dims(cfg)
    kin, kout, kconv, kdt = split_keys(key, 4)
    d_in_proj = 2 * d_inner + 2 * s.state_dim + nh
    p = {
        "in_proj": dense_init(kin, (cfg.d_model, d_in_proj)),
        "out_proj": dense_init(kout, (d_inner, cfg.d_model)),
        "conv_w": dense_init(kconv, (s.conv_width, conv_ch), scale=0.2),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.full((nh,), 0.01, jnp.float32))),  # softplus^-1(dt_init)
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
    }
    return p


def _split_in_proj(zxbcdt, cfg: ModelConfig):
    s, d_inner, nh, _ = _dims(cfg)
    z, xin, bc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + 2 * s.state_dim],
        axis=-1)
    b, c = jnp.split(bc, 2, axis=-1)
    return z, xin, b, c, dt


def _gated_norm(p, y, z, eps=1e-5):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    rms = jnp.sqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + eps)
    return (yf / rms * p["norm_scale"].astype(jnp.float32)).astype(y.dtype)


def mamba2_forward(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B, S, d_model) -> (B, S, d_model)."""
    s_cfg, d_inner, nh, conv_ch = _dims(cfg)
    bsz, s, _ = x.shape
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xin, b, c, dt = _split_in_proj(zxbcdt, cfg)

    # causal depthwise conv over (x, B, C)
    xbc = jnp.concatenate([xin, b, c], axis=-1)            # (B,S,conv_ch)
    w = p["conv_w"].astype(xbc.dtype)
    pad = s_cfg.conv_width - 1
    xp = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(xp[:, i:i + s, :] * w[i][None, None, :]
               for i in range(s_cfg.conv_width))
    xbc = jax.nn.silu((conv + p["conv_b"].astype(conv.dtype)
                       ).astype(jnp.float32)).astype(x.dtype)
    xin, b, c = jnp.split(xbc, [d_inner, d_inner + s_cfg.state_dim], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])    # (B,S,H)
    xh = xin.reshape(bsz, s, nh, s_cfg.head_dim)
    y = ssd_chunked(xh, dt, -jnp.exp(p["a_log"]), b, c, chunk=s_cfg.chunk)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(y.dtype)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = _gated_norm(p, y, z)
    return jnp.einsum("bse,ed->bsd", y,
                      p["out_proj"].astype(x.dtype)).astype(x.dtype)


def init_mamba2_state(cfg: ModelConfig, batch: int):
    s, d_inner, nh, conv_ch = _dims(cfg)
    return {
        "h": jnp.zeros((batch, nh, s.state_dim, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), jnp.float32),
    }


def mamba2_decode(p, x: jnp.ndarray, cfg: ModelConfig, state
                  ) -> Tuple[jnp.ndarray, dict]:
    """x: (B, 1, d_model); state: {'h', 'conv'} -> (y, new_state)."""
    s_cfg, d_inner, nh, conv_ch = _dims(cfg)
    bsz = x.shape[0]
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xin, b, c, dt = _split_in_proj(zxbcdt[:, 0], cfg)   # (B, ...)

    xbc = jnp.concatenate([xin, b, c], axis=-1)            # (B,conv_ch)
    hist = jnp.concatenate([state["conv"],
                            xbc[:, None, :].astype(jnp.float32)], axis=1)
    w = p["conv_w"].astype(jnp.float32)                    # (W, ch)
    conv = jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"]
    xbc = jax.nn.silu(conv).astype(x.dtype)
    new_conv = hist[:, 1:, :]
    xin, b, c = jnp.split(xbc, [d_inner, d_inner + s_cfg.state_dim], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    a = jnp.exp(dt * (-jnp.exp(p["a_log"]))[None, :])      # (B,H)
    xh = xin.reshape(bsz, nh, s_cfg.head_dim).astype(jnp.float32)
    h = state["h"] * a[..., None, None] + \
        dt[..., None, None] * b.astype(jnp.float32)[:, None, :, None] * \
        xh[:, :, None, :]
    y = jnp.einsum("bn,bhnp->bhp", c.astype(jnp.float32), h)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = _gated_norm(p, y, z[:, None, :])
    out = jnp.einsum("bse,ed->bsd", y,
                     p["out_proj"].astype(x.dtype)).astype(x.dtype)
    return out, {"h": h, "conv": new_conv}
