"""GQA attention with RoPE: training forward + cached decode step."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import (decode_attention,
                                                decode_attention_paged)
from repro.kernels.flash_attention.ops import flash_attention
from repro.parallel.sharding import constrain
from repro.models.common import (ModelConfig, apply_rope, dense_init,
                                 rope_angles, split_keys)


def init_attention(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko = split_keys(key, 4)
    p = {
        "wq": dense_init(kq, (d, cfg.n_heads * hd)),
        "wk": dense_init(kk, (d, cfg.n_kv_heads * hd)),
        "wv": dense_init(kv, (d, cfg.n_kv_heads * hd)),
        "wo": dense_init(ko, (cfg.n_heads * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg: ModelConfig):
    b, s, d = x.shape
    hd = cfg.hd
    q = constrain(jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype)),
                  "batch", None, "tp")
    k = constrain(jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype)),
                  "batch", None, "tp")
    v = constrain(jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype)),
                  "batch", None, "tp")
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def attention_forward(p, x: jnp.ndarray, cfg: ModelConfig, *,
                      positions: Optional[jnp.ndarray] = None,
                      causal: bool = True,
                      return_kv: bool = False):
    """Full-sequence attention. x: (B, S, d)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    cos, sin = rope_angles(positions, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # sequence parallelism: shard S over the model axis for attention
    qt = constrain(q.transpose(0, 2, 1, 3), "batch", None, "seq", None)
    kt = constrain(k.transpose(0, 2, 1, 3), "batch", None, "seq", None)
    vt = constrain(v.transpose(0, 2, 1, 3), "batch", None, "seq", None)
    out = flash_attention(qt, kt, vt, causal=causal,
                          window=cfg.sliding_window,
                          use_pallas=cfg.use_pallas)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return out, (kt, vt)
    return out


def quantize_kv_token(k: jnp.ndarray):
    """Per-(token, head) symmetric int8 quantization of one KV vector.
    k: (B, Hkv, D) -> (int8 values, f32 scale (B, Hkv, 1))."""
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def attention_decode(p, x: jnp.ndarray, cfg: ModelConfig, k_cache, v_cache,
                     cache_len, k_scale=None, v_scale=None):
    """Single-token decode. x: (B, 1, d); caches: (B, Hkv, Smax, D).

    With a sliding-window config the cache is a ring buffer of size
    ``window`` (positions wrap), keeping long-context decode O(window).
    With ``cfg.kv_quant == "int8"`` the caches are int8 with per-token
    f32 scales (k_scale/v_scale: (B, Hkv, Smax, 1)): the dequantize
    fuses into the attention reads, halving decode's dominant HBM term.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)
    cos, sin = rope_angles(cache_len[:, None], cfg.hd, cfg.rope_theta)
    # single-token tensors are tiny: replicate over the model axis so the
    # softmax conflict resolves by gathering q, never the KV cache.
    q = constrain(apply_rope(q, cos, sin)[:, 0], "batch", None, None)
    k = constrain(apply_rope(k, cos, sin)[:, 0], "batch", None, None)
    v = constrain(v[:, 0], "batch", None, None)
    smax = k_cache.shape[2]
    # uniform ring addressing: slot = position mod capacity.  For a
    # full-context cache positions never wrap (the engine caps length at
    # smax), so the modulo is the identity; for a window cache it IS the
    # rotation -- one formula, no sliding-window special case.
    slot = cache_len % smax

    def put(cache, val, i):
        return jax.vmap(
            lambda c, vv, j: jax.lax.dynamic_update_slice(
                c, vv[:, None, :], (0, j, 0)))(cache, val, i)

    quant = cfg.kv_quant == "int8"
    if quant:
        kq, ks = quantize_kv_token(k)
        vq, vs = quantize_kv_token(v)
        k_cache = put(k_cache, kq, slot)
        v_cache = put(v_cache, vq, slot)
        k_scale = put(k_scale, ks, slot)
        v_scale = put(v_scale, vs, slot)
        # dequantize fused into the attention reads (int8 HBM traffic)
        k_eff = k_cache.astype(jnp.float32) * k_scale
        v_eff = v_cache.astype(jnp.float32) * v_scale
    else:
        k_cache = put(k_cache, k, slot)
        v_cache = put(v_cache, v, slot)
        k_eff, v_eff = k_cache, v_cache
    eff_len = jnp.minimum(cache_len + 1, smax)
    out = decode_attention(q, k_eff, v_eff, eff_len.astype(jnp.int32),
                           use_pallas=cfg.use_pallas)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    if quant:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache


def attention_decode_paged(p, x: jnp.ndarray, cfg: ModelConfig, k_pages,
                           v_pages, block_tables, cache_len,
                           k_scale_pages=None, v_scale_pages=None):
    """Single-token decode against a paged KV cache.

    x: (B, 1, d); k_pages/v_pages: (P, Hkv, ps, D) -- one layer's slice
    of the global page pool; block_tables: (B, T) physical page ids in
    logical order.  The lane's capacity is ``T*ps`` positions: a
    sliding-window lane owns a FIXED set of pages and rotates through
    them at page granularity (slot = position mod T*ps), so the ring
    write and the block-table gather share one formula with the
    full-context case.  With ``cfg.kv_quant == "int8"`` the pools are
    int8 with per-token f32 scale pools (P, Hkv, ps, 1), dequantized at
    the attention read exactly like the dense cache.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)
    cos, sin = rope_angles(cache_len[:, None], cfg.hd, cfg.rope_theta)
    q = constrain(apply_rope(q, cos, sin)[:, 0], "batch", None, None)
    k = constrain(apply_rope(k, cos, sin)[:, 0], "batch", None, None)
    v = constrain(v[:, 0], "batch", None, None)
    ps = k_pages.shape[2]
    t = block_tables.shape[1]
    cap = t * ps                         # positions the table can back
    slot = cache_len % cap
    page = jnp.take_along_axis(block_tables, (slot // ps)[:, None],
                               axis=1)[:, 0]
    off = slot % ps

    def put(pool, val):
        # distinct lanes own distinct pages (allocator invariant), so
        # the batched scatter writes never collide
        return pool.at[page, :, off].set(val.astype(pool.dtype))

    quant = cfg.kv_quant == "int8"
    if quant:
        kq, ks = quantize_kv_token(k)
        vq, vs = quantize_kv_token(v)
        k_pages = put(k_pages, kq)
        v_pages = put(v_pages, vq)
        k_scale_pages = put(k_scale_pages, ks)
        v_scale_pages = put(v_scale_pages, vs)
        k_eff = k_pages.astype(jnp.float32) * k_scale_pages
        v_eff = v_pages.astype(jnp.float32) * v_scale_pages
    else:
        k_pages = put(k_pages, k)
        v_pages = put(v_pages, v)
        k_eff, v_eff = k_pages, v_pages
    eff_len = jnp.minimum(cache_len + 1, cap)
    out = decode_attention_paged(q, k_eff, v_eff,
                                 block_tables.astype(jnp.int32),
                                 eff_len.astype(jnp.int32),
                                 use_pallas=cfg.use_pallas)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    if quant:
        return out, k_pages, v_pages, k_scale_pages, v_scale_pages
    return out, k_pages, v_pages
