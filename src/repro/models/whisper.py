"""Whisper-style encoder-decoder backbone (audio frontend is a stub).

Per the assignment spec, the conv/mel frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d_model).  The backbone
is faithful to Whisper's transformer: pre-LN LayerNorm, GELU MLPs,
bidirectional encoder self-attention, causal decoder self-attention plus
cross-attention into the encoder states; learned absolute positions are
replaced by RoPE for shape-agnostic long dry-run cells (noted in
DESIGN.md as a hardware-adaptation simplification).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.models.attention import (attention_decode, attention_forward,
                                    init_attention)
from repro.models.common import (ModelConfig, apply_norm, cross_entropy, layer_scan,
                                 embed, init_embedding, init_norm, lm_logits,
                                 split_keys)
from repro.models.mlp import gelu_mlp, init_gelu_mlp
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------

def _init_enc_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = split_keys(key, 2)
    return {
        "norm1": init_norm(cfg), "attn": init_attention(k1, cfg),
        "norm2": init_norm(cfg),
        "mlp": init_gelu_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_block(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = split_keys(key, 3)
    return {
        "norm1": init_norm(cfg), "self_attn": init_attention(k1, cfg),
        "norm_x": init_norm(cfg), "cross_attn": init_attention(k2, cfg),
        "norm2": init_norm(cfg),
        "mlp": init_gelu_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def init_whisper(key, cfg: ModelConfig) -> Params:
    kemb, kenc, kdec = split_keys(key, 3)
    enc = [_init_enc_block(jax.random.fold_in(kenc, i), cfg)
           for i in range(cfg.n_encoder_layers)]
    dec = [_init_dec_block(jax.random.fold_in(kdec, i), cfg)
           for i in range(cfg.n_layers)]
    stack = lambda blocks: jax.tree_util.tree_map(  # noqa: E731
        lambda *xs: jnp.stack(xs), *blocks)
    return {
        "embed": init_embedding(kemb, cfg),
        "encoder": stack(enc),
        "decoder": stack(dec),
        "enc_final_norm": init_norm(cfg),
        "final_norm": init_norm(cfg),
    }


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------

def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig,
           remat: bool = False) -> jnp.ndarray:
    """frames: (B, S_enc, d) stub embeddings -> encoder states."""
    x = constrain(frames.astype(cfg.compute_dtype), "batch", None, None)

    def body(x, p):
        h = constrain(apply_norm(p["norm1"], x, cfg.norm),
                      "batch", None, None)
        x = x + constrain(attention_forward(p["attn"], h, cfg, causal=False),
                          "batch", "seq", None)
        h2 = constrain(apply_norm(p["norm2"], x, cfg.norm),
                       "batch", None, None)
        x = constrain(x + gelu_mlp(p["mlp"], h2), "batch", "seq", None)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = layer_scan(body, x, params["encoder"])
    return apply_norm(params["enc_final_norm"], x, cfg.norm)


def _cross_attention(p, x, enc_kv, cfg: ModelConfig):
    """Cross-attn with precomputed encoder K/V: (B, Hkv, S_enc, D)."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, cfg.hd).transpose(0, 2, 1, 3)
    k, v = enc_kv
    out = flash_attention(q, k, v, causal=False, use_pallas=cfg.use_pallas)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))


def _enc_kv(p, enc: jnp.ndarray, cfg: ModelConfig):
    b, s, _ = enc.shape
    k = jnp.einsum("bsd,dh->bsh", enc, p["wk"].astype(enc.dtype))
    v = jnp.einsum("bsd,dh->bsh", enc, p["wv"].astype(enc.dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(enc.dtype)
        v = v + p["bv"].astype(enc.dtype)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
    return k, v


def decode_forward(params: Params, tokens: jnp.ndarray, enc: jnp.ndarray,
                   cfg: ModelConfig, remat: bool = False) -> jnp.ndarray:
    """Teacher-forced decoder pass -> logits (B, S_dec, V)."""
    x = embed(params["embed"], tokens, cfg.compute_dtype)

    def body(x, p):
        h = constrain(apply_norm(p["norm1"], x, cfg.norm),
                      "batch", None, None)
        x = x + constrain(
            attention_forward(p["self_attn"], h, cfg, causal=True),
            "batch", "seq", None)
        hx = constrain(apply_norm(p["norm_x"], x, cfg.norm),
                       "batch", None, None)
        x = x + constrain(
            _cross_attention(p["cross_attn"], hx,
                             _enc_kv(p["cross_attn"], enc, cfg), cfg),
            "batch", "seq", None)
        h2 = constrain(apply_norm(p["norm2"], x, cfg.norm),
                       "batch", None, None)
        x = constrain(x + gelu_mlp(p["mlp"], h2), "batch", "seq", None)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = layer_scan(body, x, params["decoder"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embed"], x, cfg)


def whisper_loss(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: ModelConfig, remat: bool = False) -> jnp.ndarray:
    enc = encode(params, batch["frames"], cfg, remat=remat)
    logits = decode_forward(params, batch["tokens"], enc, cfg, remat=remat)
    return cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


# ----------------------------------------------------------------------
# cached decode
# ----------------------------------------------------------------------

def init_whisper_cache(params: Params, enc: jnp.ndarray, cfg: ModelConfig,
                       batch: int, max_len: int) -> Params:
    """Self-attn KV ring + precomputed per-layer cross KV."""
    L = cfg.n_layers
    kv_shape = (L, batch, cfg.n_kv_heads, max_len, cfg.hd)

    def per_layer_kv(p):
        return _enc_kv(p, enc, cfg)

    cross_k, cross_v = jax.vmap(
        lambda p: per_layer_kv(p))(
        params["decoder"]["cross_attn"])  # (L, B, Hkv, S_enc, D)
    return {
        "len": jnp.zeros((batch,), jnp.int32),
        "k": jnp.zeros(kv_shape, cfg.compute_dtype),
        "v": jnp.zeros(kv_shape, cfg.compute_dtype),
        "cross_k": cross_k,
        "cross_v": cross_v,
    }


def whisper_decode_step(params: Params, cfg: ModelConfig, cache: Params,
                        tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    if cfg.kv_quant is not None:
        raise NotImplementedError(
            "int8 KV cache is wired for the decoder-only families; "
            "whisper-base caches are small enough in bf16")
    x = embed(params["embed"], tokens[:, None], cfg.compute_dtype)
    cache_len = cache["len"]
    b = tokens.shape[0]
    enc_len = jnp.full((b,), cache["cross_k"].shape[3], jnp.int32)

    def body(carry, xs):
        x, k_all, v_all = carry
        p, ck, cv, i = xs
        k_c = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        v_c = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        h = apply_norm(p["norm1"], x, cfg.norm)
        att, k_c, v_c = attention_decode(p["self_attn"], h, cfg, k_c, v_c,
                                         cache_len)
        x = x + att
        hx = apply_norm(p["norm_x"], x, cfg.norm)
        q = jnp.einsum("bsd,dh->bsh", hx,
                       p["cross_attn"]["wq"].astype(x.dtype))
        if cfg.qkv_bias:
            q = q + p["cross_attn"]["bq"].astype(x.dtype)
        q = q.reshape(b, cfg.n_heads, cfg.hd)
        co = decode_attention(q, ck, cv, enc_len, use_pallas=cfg.use_pallas)
        co = co.reshape(b, 1, cfg.n_heads * cfg.hd)
        x = x + jnp.einsum("bsh,hd->bsd", co,
                           p["cross_attn"]["wo"].astype(x.dtype))
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        x = x + gelu_mlp(p["mlp"], h2)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, k_c, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, v_c, i, 0)
        return (x, k_all, v_all), None

    xs = (params["decoder"], cache["cross_k"], cache["cross_v"],
          jnp.arange(cfg.n_layers, dtype=jnp.int32))
    (x, new_k, new_v), _ = layer_scan(body, (x, cache["k"], cache["v"]), xs)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x[:, 0], cfg)
    new_cache = dict(cache)
    new_cache.update(k=new_k, v=new_v, len=cache_len + 1)
    return logits, new_cache
