"""Sharding rules: FSDP + TP + EP + SP over the production mesh.

Logical axes and their mesh mapping:

=========  =====================  ======================================
logical    mesh axes              used for
=========  =====================  ======================================
``batch``  ("pod", "data")        data parallelism (activations, tokens)
``fsdp``   ("pod", "data")        weight/optimizer sharding (ZeRO-3)
``tp``     ("model",)             d_ff / flattened head / vocab dims
``seq``    ("model",)             sequence parallelism inside attention
``expert`` ("model",)             MoE expert parallelism
=========  =====================  ======================================

Every rule degrades gracefully: if a tensor dim is not divisible by the
mesh axis size (e.g. Hymba's 6482-wide in_proj), the axis is dropped for
that dim rather than relying on GSPMD padding -- keeps memory analysis
honest.  A process-global mesh context (``use_mesh``) lets model code
call :func:`constrain` without threading mesh objects through every
layer; outside the context it is the identity, so single-device smoke
tests are untouched.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def mesh_logical_axes(mesh: Mesh, mode: str = "train") -> Dict[str, Any]:
    """Logical-axis -> mesh-axis mapping.

    ``train``: FSDP over (pod, data) + TP over model + SP over model.
    ``serve``: weight-stationary 2-D TP -- feature dims shard over
    (data, model) jointly, NO fsdp gathering (decode must never stream
    whole layers over the interconnect); batch rides the pod axis when
    present (the KV cache keeps its own batch/data sharding).
    """
    names = mesh.axis_names
    dp: Tuple[str, ...] = tuple(a for a in ("pod", "data") if a in names)
    dp_ax: Any = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp = "model" if "model" in names else None
    flat = tuple(names) or None
    if mode == "serve":
        tp2 = tuple(a for a in ("data", "model") if a in names) or None
        return {"batch": "pod" if "pod" in names else None,
                "fsdp": None, "tp": tp2, "seq": tp, "expert": tp,
                "edata": "data" if "data" in names else None,
                "flat": flat}
    return {"batch": dp_ax, "fsdp": dp_ax, "tp": tp, "seq": tp,
            "expert": tp, "flat": flat}


# ----------------------------------------------------------------------
# global mesh context
# ----------------------------------------------------------------------

_last_active: list = [None]   # mesh the cached traces were created under


def _activate(mesh: Optional[Mesh]) -> None:
    """Guard every mesh (re)activation -- context entry AND the exit
    path restoring an outer context.

    `constrain` bakes the CONCRETE mesh into the traced jaxpr, but
    jax's jaxpr trace cache is keyed on (function, avals) only -- so
    re-jitting the same step function under a different mesh (elastic
    re-mesh, dry-run cell sweeps, nested contexts) would silently reuse
    constraints pointing at the old device set.  Dropping the caches on
    every mesh CHANGE keeps the invariant "cached traces belong to
    `_last_active`".  clear_caches() is deliberately global
    (wrong-device constraints are a correctness bug, retracing is only
    a cost); mesh-free paths and repeated same-mesh contexts never pay
    it, and mesh-alternating paths are compile-everything sweeps
    anyway.  Like jax's own trace caches (and clear_caches itself) this
    guard is process-global: concurrent use_mesh from multiple threads
    with DIFFERENT meshes is unsupported -- every launcher/dry-run path
    in this repo activates meshes from one thread."""
    if mesh is None:        # mesh-free traces are constraint-free: safe
        return
    if _last_active[0] is not None and mesh != _last_active[0]:
        jax.clear_caches()
    _last_active[0] = mesh


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], mode: str = "train"):
    _activate(mesh)
    prev = getattr(_ctx, "mesh", None)
    prev_mode = getattr(_ctx, "mode", "train")
    _ctx.mesh = mesh
    _ctx.mode = mode
    try:
        yield
    finally:
        _ctx.mesh = prev
        _ctx.mode = prev_mode
        _activate(prev)


def current_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def current_mode() -> str:
    return getattr(_ctx, "mode", "train")


def _fallback_axes(mesh: Mesh, dim: int, axes):
    """Progressively drop leading axes of a tuple until divisible."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(axes)
    while axes:
        if dim % _axis_size(mesh, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
        axes = axes[1:]
    return None


def constrain(x, *logical: Optional[str]):
    """with_sharding_constraint by logical axis names (None = unsharded).

    Identity when no mesh context is active or when a dim is not
    divisible by its mesh axes.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    la = mesh_logical_axes(mesh, current_mode())
    spec = []
    for dim, name in zip(x.shape, logical):
        axes = la.get(name) if name else None
        spec.append(_fallback_axes(mesh, dim, axes))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


# ----------------------------------------------------------------------
# parameter sharding rules
# ----------------------------------------------------------------------

#: param-name -> logical axes per dim (matched by the *last* path element,
#: with container names joined for disambiguation).
_PARAM_RULES: Dict[str, Sequence[Optional[str]]] = {
    # embedding / head
    "tok": ("tp", "fsdp"),
    "head": ("fsdp", "tp"),
    # attention
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    # dense mlp
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    "w1": ("fsdp", "tp"), "b1": ("tp",),
    "w2": ("tp", "fsdp"), "b2": (None,),
    # moe (3-D expert tensors; matched with the moe/ prefix below)
    "router": ("fsdp", None),
    "moe/w_gate": ("expert", "fsdp", None),
    "moe/w_up": ("expert", "fsdp", None),
    "moe/w_down": ("expert", None, "fsdp"),
    # mamba2
    "in_proj": ("fsdp", "tp"), "out_proj": ("tp", "fsdp"),
    "conv_w": (None, "tp"), "conv_b": ("tp",),
    "a_log": (None,), "dt_bias": (None,), "d_skip": (None,),
    "norm_scale": ("tp",),
    # norms
    "scale": (None,), "bias": (None,),
}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):          # GetAttrKey (NamedTuple fields)
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _rule_for(path_str: str, ndim: int) -> Sequence[Optional[str]]:
    leaf = path_str.rsplit("/", 1)[-1]
    # int8-Adam moments keep the parameter's shape: route "q"/"scale"
    # leaves to the parent parameter's rule (scale has last dim 1, which
    # the divisibility fallback leaves unsharded automatically).
    if (("/mu/" in path_str or path_str.startswith("mu/")
         or "/nu/" in path_str or path_str.startswith("nu/"))
            and leaf in ("q", "scale")):
        return _rule_for(path_str.rsplit("/", 1)[0], ndim)
    # stacked layer params gain a leading layer dim
    lead = 1 if ("blocks/" in path_str or "encoder/" in path_str
                 or "decoder/" in path_str) else 0
    if "moe/" in path_str and "moe/" + leaf in _PARAM_RULES:
        rule = _PARAM_RULES["moe/" + leaf]
    elif leaf in _PARAM_RULES:
        rule = _PARAM_RULES[leaf]
    else:
        rule = (None,) * (ndim - lead)
    full = (None,) * lead + tuple(rule)
    if len(full) < ndim:   # e.g. shared-expert swiglu under moe
        full = full + (None,) * (ndim - len(full))
    return full[:ndim]


#: serve-mode overrides: expert weights stay resident -- experts over
#: `model`, expert-ff over `data` (never gathered during decode).
_SERVE_OVERRIDES: Dict[str, Sequence[Optional[str]]] = {
    "moe/w_gate": ("expert", None, "edata"),
    "moe/w_up": ("expert", None, "edata"),
    "moe/w_down": ("expert", "edata", None),
}


def param_spec(mesh: Mesh, path, leaf, mode: str = "train") -> P:
    la = mesh_logical_axes(mesh, mode)
    rule = _rule_for(_path_str(path), leaf.ndim)
    if mode == "serve":
        ps = _path_str(path)
        leaf_name = ps.rsplit("/", 1)[-1]
        key = "moe/" + leaf_name if "moe/" in ps else leaf_name
        if key in _SERVE_OVERRIDES:
            lead = 1 if ("blocks/" in ps or "encoder/" in ps
                         or "decoder/" in ps) else 0
            rule = (None,) * lead + tuple(_SERVE_OVERRIDES[key])
            rule = rule[:leaf.ndim]
    spec = []
    for dim, name in zip(leaf.shape, rule):
        axes = la.get(name) if name else None
        spec.append(_fallback_axes(mesh, dim, axes))
    return P(*spec)


def param_shardings(mesh: Mesh, params_tree, mode: str = "train"):
    """Tree of NamedShardings mirroring a (possibly abstract) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(mesh, path, leaf, mode)), params_tree)


# ----------------------------------------------------------------------
# batch / cache shardings
# ----------------------------------------------------------------------

def _spec_with_div(mesh: Mesh, shape, logical, mode: str = "train") -> P:
    la = mesh_logical_axes(mesh, mode)
    out = []
    for dim, name in zip(shape, logical):
        axes = la.get(name) if name else None
        out.append(_fallback_axes(mesh, dim, axes))
    return P(*out)


_BATCH_RULES = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "loss_mask": ("batch", None),
    "frames": ("batch", None, None),
    "vision_embeds": ("batch", None, None),
}

_CACHE_RULES = {
    "k": (None, "kv_batch", None, "seq", None),
    "v": (None, "kv_batch", None, "seq", None),
    "k_scale": (None, "kv_batch", None, "seq", None),
    "v_scale": (None, "kv_batch", None, "seq", None),
    "cross_k": (None, "kv_batch", None, "seq", None),
    "cross_v": (None, "kv_batch", None, "seq", None),
    "ssm_h": (None, "kv_batch", None, "tp", None),
    "ssm_conv": (None, "kv_batch", None, "tp"),
    "len": (None,),
}


def batch_shardings(mesh: Mesh, batch_tree):
    def f(path, leaf):
        name = _path_str(path).rsplit("/", 1)[-1]
        rule = _BATCH_RULES.get(name, ("batch",) + (None,) * (leaf.ndim - 1))
        return NamedSharding(mesh, _spec_with_div(mesh, leaf.shape, rule))
    return jax.tree_util.tree_map_with_path(f, batch_tree)


def cache_shardings(mesh: Mesh, cache_tree):
    """KV/state cache shardings: batch over (pod, data), seq over model --
    identical in train and serve modes (the cache IS the decode working
    set; weight-stationary serving leaves it untouched)."""
    la = {"kv_batch": tuple(a for a in ("pod", "data")
                            if a in mesh.axis_names) or None,
          "seq": "model" if "model" in mesh.axis_names else None,
          "tp": "model" if "model" in mesh.axis_names else None}

    def f(path, leaf):
        name = _path_str(path).rsplit("/", 1)[-1]
        rule = _CACHE_RULES.get(name, (None,) * leaf.ndim)
        spec = [
            _fallback_axes(mesh, dim, la.get(r) if r else None)
            for dim, r in zip(leaf.shape, rule)]
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(f, cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
