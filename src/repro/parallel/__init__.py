from repro.parallel.sharding import (batch_shardings, cache_shardings,
                                     constrain, current_mesh,
                                     mesh_logical_axes, param_shardings,
                                     param_spec, replicated, use_mesh)

__all__ = ["batch_shardings", "cache_shardings", "constrain", "current_mesh",
           "mesh_logical_axes", "param_shardings", "param_spec",
           "replicated", "use_mesh"]
