"""Radix cache of prompt-prefix KV pages over the paged pool.

At millions-of-users scale most traffic shares long common prefixes
(system prompts, few-shot templates, multi-turn history).  Recomputing
a shared prefix burns prefill compute AND pool pages the board cannot
spare -- on the CMP 170HX profile every resident KV byte has to earn
its keep (PAPER.md's §6 economics).  This module caches the *pages*
that back previously served prompts in a radix tree keyed by token
ids, at page granularity:

* an interior/full node covers exactly ``page_size`` tokens and owns
  one pool page holding their KV;
* a leaf may additionally be *partial* (fewer than ``page_size``
  tokens): the donor's last prompt page, shared up to the tokens the
  donor actually prefilled.  A consumer that maps a partial page must
  copy-on-write before its first append (the donor keeps decoding into
  the original).

Ownership: the cache holds its OWN reference on every cached page
(``PagePool.share`` on insert, ``PagePool.free`` on eviction/flush).
A cached page therefore stays allocated after its donor lane retires,
and a page mapped by live lanes survives cache eviction -- the pool's
refcount, not the tree, decides when bytes are really reclaimed.

Correctness of sharing a page whose donor is still decoding: a full
node's tokens all precede the donor's first decode write (the donor
writes at positions >= its prompt length, which live in later blocks),
so full pages are frozen.  A partial page IS appended to by the donor,
but only at slots >= the cached token count; consumers copy the page
before writing and never read past their own live length, so the
donor's junk in the copied tail is dead data.

The tree is deliberately host-side and tiny (a few nodes per cached
prompt): matching is a dict walk per page, far off the decode hot
path.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["PrefixCache"]


class _Node:
    """One cached page: ``tokens`` under the parent's position."""

    __slots__ = ("tokens", "page", "parent", "children", "last_used")

    def __init__(self, tokens: Tuple[int, ...], page: int,
                 parent: Optional["_Node"]):
        self.tokens = tokens
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_used = 0


def _key(prompt, start: int, stop: int) -> Tuple[int, ...]:
    return tuple(int(t) for t in prompt[start:stop])


class PrefixCache:
    """Page-granular radix tree of cached prompt prefixes.

    ``match`` walks the tree along an incoming prompt and returns the
    longest cached prefix in whole pages (plus, optionally, one partial
    tail page); ``insert`` records a freshly prefilled lane's prompt
    pages.  Eviction is LRU over leaves, so an interior page is never
    dropped while a longer cached prefix still extends it.
    """

    def __init__(self, pool, page_size: int,
                 max_pages: Optional[int] = None):
        self.pool = pool
        self.page_size = int(page_size)
        #: soft page budget (None: bounded only by pool pressure --
        #: the engine trims the cache when admission cannot reserve)
        self.max_pages = max_pages
        self._root = _Node((), -1, None)
        self._clock = 0
        self._n_pages = 0
        # host-side event counters (the engine republishes them as
        # namespaced metrics; the cache stays registry-free)
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0

    @property
    def n_pages(self) -> int:
        """Pages the cache currently holds a reference on."""
        return self._n_pages

    # ------------------------------------------------------------------
    # lookup
    # ------------------------------------------------------------------
    def match(self, prompt: np.ndarray, allow_partial: bool = True
              ) -> Tuple[List[int], int, Optional[Tuple[int, int]]]:
        """Longest cached prefix of ``prompt``, in pages.

        Returns ``(pages, matched_len, partial)``:

        * ``pages`` -- full shared pages in logical block order;
        * ``matched_len`` -- prompt tokens they cover (including the
          partial page, when one matches);
        * ``partial`` -- ``(page_id, n_tokens)`` for a matched partial
          tail page, or None.

        At least one tail token is ALWAYS left unmatched
        (``matched_len <= len(prompt) - 1``): the admitting lane must
        run a real forward step over its final prompt token to produce
        the first-token logits, exactly like a cache miss would.
        """
        ps = self.page_size
        plen = int(len(prompt))
        max_full = max((plen - 1) // ps, 0)
        self._clock += 1
        node = self._root
        pages: List[int] = []
        pos = 0
        while len(pages) < max_full:
            child = node.children.get(_key(prompt, pos, pos + ps))
            if child is None:
                break
            child.last_used = self._clock
            pages.append(child.page)
            node = child
            pos += ps
        partial: Optional[Tuple[int, int]] = None
        if allow_partial:
            best = None
            for key, child in node.children.items():
                if len(key) >= ps or pos + len(key) > plen - 1:
                    continue
                if key == _key(prompt, pos, pos + len(key)):
                    if best is None or len(key) > len(best.tokens):
                        best = child
            if best is not None:
                best.last_used = self._clock
                partial = (best.page, len(best.tokens))
                pos += len(best.tokens)
        if pos > 0:
            self.hits += 1
        else:
            self.misses += 1
        return pages, pos, partial

    # ------------------------------------------------------------------
    # insertion
    # ------------------------------------------------------------------
    def insert(self, prompt: np.ndarray, plen: int,
               lane_pages: List[int], allow_partial: bool = True) -> int:
        """Record a prefilled lane's prompt pages; returns pages newly
        cached.  ``lane_pages[i]`` must back prompt positions
        ``[i * page_size, (i + 1) * page_size)`` -- true for any lane
        the engine just prefilled (hit or miss: a hit lane's head
        blocks are the donor pages themselves, which the walk simply
        revisits).  Existing nodes win ties: a prefix already cached
        keeps its original page, the new lane's duplicate stays
        lane-private."""
        ps = self.page_size
        self._clock += 1
        node = self._root
        added = 0
        n_full = plen // ps
        for i in range(n_full):
            key = _key(prompt, i * ps, (i + 1) * ps)
            child = node.children.get(key)
            if child is None:
                child = self._add_node(node, key, lane_pages[i])
                added += 1
            child.last_used = self._clock
            node = child
        rem = plen - n_full * ps
        if allow_partial and rem > 0:
            key = _key(prompt, n_full * ps, plen)
            child = node.children.get(key)
            if child is None:
                child = self._add_node(node, key, lane_pages[n_full])
                added += 1
            child.last_used = self._clock
        return added

    def _add_node(self, parent: _Node, key: Tuple[int, ...],
                  page: int) -> _Node:
        if self.max_pages is not None:
            while self._n_pages >= self.max_pages and self.evict_lru():
                pass
        self.pool.share([page], holder="cache")   # the cache's own ref
        child = _Node(key, page, parent)
        parent.children[key] = child
        self._n_pages += 1
        self.insertions += 1
        return child

    # ------------------------------------------------------------------
    # eviction / invalidation
    # ------------------------------------------------------------------
    def _lru_leaf(self) -> Optional[_Node]:
        best = None
        stack = [self._root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                if child.children:
                    stack.append(child)
                elif best is None or child.last_used < best.last_used:
                    best = child
        return best

    def evict_lru(self) -> bool:
        """Drop the least-recently-matched LEAF page (an interior page
        outlives every cached prefix that extends it).  The page's
        bytes return to the pool only if no live lane still maps it --
        that is the refcount's call, not ours."""
        leaf = self._lru_leaf()
        if leaf is None:
            return False
        del leaf.parent.children[leaf.tokens]
        self.pool.free([leaf.page], holder="cache")
        self._n_pages -= 1
        self.evictions += 1
        return True

    def flush(self) -> int:
        """Invalidate everything (weight unload / end of replay):
        releases the cache's reference on every cached page.  Returns
        the number of pages released."""
        released = 0
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            self.pool.free([node.page], holder="cache")
            released += 1
        self._root.children.clear()
        self._n_pages = 0
        self.evictions += released
        return released
