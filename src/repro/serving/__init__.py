from repro.serving.disaggregation import (FleetPlan, PoolAssignment, Workload,
                                          homogeneous_baseline, plan_fleet)
from repro.serving.engine import (Request, ServeEngine, dequantize_params,
                                  quantize_params)

__all__ = ["FleetPlan", "PoolAssignment", "Workload",
           "homogeneous_baseline", "plan_fleet", "Request", "ServeEngine",
           "dequantize_params", "quantize_params"]
