from repro.serving.disaggregation import (FleetPlan, PoolAssignment,
                                          homogeneous_baseline, plan_fleet)
from repro.serving.engine import (LaneCheckpoint, PagePool, PrefixHit,
                                  Request, ServeEngine, dequantize_params,
                                  quantize_params)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.modelpool import (ModelEntry, ModelPool,
                                     MultiModelServeEngine, kv_page_bytes,
                                     params_nbytes)
from repro.serving.phase_model import (Workload, capex_usd_per_hour,
                                       effective_prefill_tps,
                                       energy_usd_per_hour,
                                       kv_handoff_seconds,
                                       link_transfer_seconds, phase_tps)
from repro.serving.resilience import (AdmissionRejected, DegradationLadder,
                                      RetryPolicy)

__all__ = ["FleetPlan", "LaneCheckpoint", "PagePool", "PoolAssignment",
           "PrefixCache", "PrefixHit", "Workload",
           "ModelEntry", "ModelPool", "MultiModelServeEngine",
           "kv_page_bytes", "params_nbytes",
           "homogeneous_baseline", "plan_fleet", "Request", "ServeEngine",
           "dequantize_params", "quantize_params", "phase_tps",
           "kv_handoff_seconds", "link_transfer_seconds",
           "effective_prefill_tps",
           "capex_usd_per_hour", "energy_usd_per_hour",
           "AdmissionRejected", "DegradationLadder", "RetryPolicy"]
