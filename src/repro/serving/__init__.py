from repro.serving.disaggregation import (FleetPlan, PoolAssignment,
                                          homogeneous_baseline, plan_fleet)
from repro.serving.engine import (LaneCheckpoint, PagePool, Request,
                                  ServeEngine, dequantize_params,
                                  quantize_params)
from repro.serving.phase_model import (Workload, capex_usd_per_hour,
                                       effective_prefill_tps,
                                       energy_usd_per_hour,
                                       kv_handoff_seconds, phase_tps)

__all__ = ["FleetPlan", "LaneCheckpoint", "PagePool", "PoolAssignment",
           "Workload",
           "homogeneous_baseline", "plan_fleet", "Request", "ServeEngine",
           "dequantize_params", "quantize_params", "phase_tps",
           "kv_handoff_seconds", "effective_prefill_tps",
           "capex_usd_per_hour", "energy_usd_per_hour"]
