"""Serving engine: continuous batching over a fixed-lane KV cache.

The paper's deployment target is single-board LLM inference; this engine
is the framework-scale version: a lane-based continuous batcher
(vLLM-style, fixed lanes instead of paged blocks -- the TPU-friendly
layout) in front of the model zoo's prefill/decode functions.

* ``prefill`` runs the batched flash path and scatters the prompt KV
  into a free lane (per-lane lengths make the batch ragged);
* ``decode_step`` advances every live lane one token;
* weights can be stored block-quantized (``quantize_params``): the
  bandwidth saving is what the paper's decode evaluation is about.

Sampling: greedy or temperature; logits arrive already vocab-masked.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.registry import Model, build_model
from repro.models.transformer import init_cache, lm_prefill_batched
from repro.quant.quantize import QTensor, dequantize, quantize


# ----------------------------------------------------------------------
# weight quantization store
# ----------------------------------------------------------------------

def quantize_params(params, fmt: str, min_size: int = 1 << 16):
    """Quantize every >=2-D weight whose k-dim divides the block size.

    Returns (q_params, stats).  Weights that cannot be block-quantized
    (small, odd shapes) stay dense -- same policy as llama.cpp, which
    keeps norms/embeddings in high precision for Q formats.
    """
    from repro.quant.formats import get_format
    blk = get_format(fmt).block
    n_q = n_dense = bytes_q = bytes_dense = 0

    def leaf(path, x):
        nonlocal n_q, n_dense, bytes_q, bytes_dense
        if (x.ndim == 2 and x.size >= min_size and x.shape[0] % blk == 0):
            qt = quantize(x, fmt)
            n_q += 1
            bytes_q += qt.nbytes()
            return qt
        n_dense += 1
        bytes_dense += x.size * x.dtype.itemsize
        return x

    qp = jax.tree_util.tree_map_with_path(leaf, params)
    stats = {"quantized": n_q, "dense": n_dense,
             "quantized_bytes": bytes_q, "dense_bytes": bytes_dense}
    return qp, stats


def dequantize_params(q_params):
    """Materialize dense weights (carrying the quantization error)."""
    return jax.tree_util.tree_map(
        lambda x: dequantize(x) if isinstance(x, QTensor) else x,
        q_params, is_leaf=lambda x: isinstance(x, QTensor))


# ----------------------------------------------------------------------
# continuous-batching engine
# ----------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-lane continuous batcher around the LM decode step."""

    def __init__(self, cfg: ModelConfig, params, n_lanes: int = 4,
                 max_len: int = 512, temperature: float = 0.0,
                 rng_seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.temperature = temperature
        self.cache = init_cache(cfg, n_lanes, max_len)
        self.lane_req: List[Optional[Request]] = [None] * n_lanes
        self._rng = jax.random.PRNGKey(rng_seed)
        self._next_token = jnp.zeros((n_lanes,), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t))

    # -- admission --------------------------------------------------------
    def free_lanes(self) -> List[int]:
        return [i for i, r in enumerate(self.lane_req) if r is None]

    def admit(self, req: Request) -> bool:
        lanes = self.free_lanes()
        if not lanes:
            return False
        lane = lanes[0]
        self._prefill_into_lane(req, lane)
        self.lane_req[lane] = req
        return True

    def _prefill_into_lane(self, req: Request, lane: int) -> None:
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, kv = lm_prefill_batched(self.params, tokens, self.cfg)
        plen = int(req.prompt.shape[0])
        if kv is not None:
            k, v = kv        # (L, 1, Hkv, S_prompt, D)
            smax = self.cache["k"].shape[3]
            take = min(plen, smax)
            self.cache["k"] = jax.lax.dynamic_update_slice(
                self.cache["k"], k[:, :, :, -take:, :].astype(
                    self.cache["k"].dtype), (0, lane, 0, 0, 0))
            self.cache["v"] = jax.lax.dynamic_update_slice(
                self.cache["v"], v[:, :, :, -take:, :].astype(
                    self.cache["v"].dtype), (0, lane, 0, 0, 0))
        if "ssm_h" in self.cache:
            # SSM state is rebuilt by streaming the prompt through the
            # decode path (exactly once, O(len) state updates).
            self._stream_ssm_prompt(req, lane)
            return
        self.cache["len"] = self.cache["len"].at[lane].set(plen)
        tok = self._sample(logits)[0]
        self._next_token = self._next_token.at[lane].set(tok)

    def _stream_ssm_prompt(self, req: Request, lane: int) -> None:
        lane_cache = jax.tree_util.tree_map(
            lambda x: x[:, lane:lane + 1] if x.ndim > 1 else x[lane:lane + 1],
            self.cache)
        lane_cache["len"] = jnp.zeros((1,), jnp.int32)
        logits = None
        for t in req.prompt:
            logits, lane_cache = self.model.decode_step(
                self.params, lane_cache, jnp.asarray([t], jnp.int32))

        def put(full, one):
            if one.ndim > 1:
                return jax.lax.dynamic_update_slice(
                    full, one, (0, lane) + (0,) * (one.ndim - 2))
            return full.at[lane].set(one[0])

        self.cache = jax.tree_util.tree_map(put, self.cache, lane_cache)
        tok = self._sample(logits)[0]
        self._next_token = self._next_token.at[lane].set(tok)

    # -- stepping ----------------------------------------------------------
    def _sample(self, logits: jnp.ndarray) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._rng, k = jax.random.split(self._rng)
        return np.asarray(jax.random.categorical(
            k, logits / self.temperature, axis=-1), np.int32)

    def decode_step(self) -> Dict[int, int]:
        """Advance every live lane one token; returns {uid: token}."""
        live = [i for i, r in enumerate(self.lane_req) if r is not None]
        if not live:
            return {}
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._next_token)
        toks = self._sample(logits)
        out: Dict[int, int] = {}
        for lane in live:
            req = self.lane_req[lane]
            tok = int(toks[lane])
            req.generated.append(tok)
            out[req.uid] = tok
            self._next_token = self._next_token.at[lane].set(tok)
            if (len(req.generated) >= req.max_new_tokens
                    or int(self.cache["len"][lane]) >= self.max_len - 1):
                req.done = True
                self.lane_req[lane] = None
        return out

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a workload to completion with continuous admission."""
        pending = list(requests)
        done: List[Request] = []
        while pending or any(r is not None for r in self.lane_req):
            while pending and self.free_lanes():
                self.admit(pending.pop(0))
            self.decode_step()
            done.extend(r for r in requests
                        if r.done and r not in done)
        return requests
