"""Serving engine: continuous batching over a fixed-lane KV cache.

The paper's deployment target is single-board LLM inference; this engine
is the framework-scale version: a lane-based continuous batcher
(vLLM-style, fixed lanes instead of paged blocks -- the TPU-friendly
layout) in front of the model zoo's prefill/decode functions.

The decode hot path is host-sync-free:

* ``prefill`` pads prompts to power-of-two buckets (one XLA compile per
  bucket, not per prompt length) and scatters the prompt KV into a free
  lane;
* ``decode_n`` advances every lane ``dispatch_n`` tokens per Python
  dispatch via a jitted ``lax.scan``: sampling (greedy or temperature)
  happens on device, tokens and done-flags accumulate on device, and one
  host transfer drains the block;
* lane retirement/admission happens only at dispatch boundaries;
* weights can be stored block-quantized (``quantize_params``): the
  bandwidth saving is what the paper's decode evaluation is about.

Sampling keys fold from (request admission index, per-request token
index), so a request's generated stream -- greedy or temperature -- is
invariant to dispatch granularity, admission timing, and lane neighbors.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.registry import Model, build_model
from repro.models.transformer import (init_cache, lm_prefill_batched,
                                      sample_tokens)
from repro.quant.quantize import QTensor, dequantize, quantize


# ----------------------------------------------------------------------
# weight quantization store
# ----------------------------------------------------------------------

def quantize_params(params, fmt: str, min_size: int = 1 << 16):
    """Quantize every >=2-D weight whose k-dim divides the block size.

    Returns (q_params, stats).  Weights that cannot be block-quantized
    (small, odd shapes) stay dense -- same policy as llama.cpp, which
    keeps norms/embeddings in high precision for Q formats.
    """
    from repro.quant.formats import get_format
    blk = get_format(fmt).block
    n_q = n_dense = bytes_q = bytes_dense = 0

    def leaf(path, x):
        nonlocal n_q, n_dense, bytes_q, bytes_dense
        if (x.ndim == 2 and x.size >= min_size and x.shape[0] % blk == 0):
            qt = quantize(x, fmt)
            n_q += 1
            bytes_q += qt.nbytes()
            return qt
        n_dense += 1
        bytes_dense += x.size * x.dtype.itemsize
        return x

    qp = jax.tree_util.tree_map_with_path(leaf, params)
    stats = {"quantized": n_q, "dense": n_dense,
             "quantized_bytes": bytes_q, "dense_bytes": bytes_dense}
    return qp, stats


def dequantize_params(q_params):
    """Materialize dense weights (carrying the quantization error)."""
    return jax.tree_util.tree_map(
        lambda x: dequantize(x) if isinstance(x, QTensor) else x,
        q_params, is_leaf=lambda x: isinstance(x, QTensor))


# ----------------------------------------------------------------------
# continuous-batching engine
# ----------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket_len(n: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor) -- the prefill shape bucket."""
    b = floor
    while b < n:
        b <<= 1
    return b


class ServeEngine:
    """Fixed-lane continuous batcher around the LM decode step.

    ``dispatch_n`` is the decode granularity: tokens generated per Python
    dispatch (per lane).  ``stats`` tracks dispatches, decode steps,
    generated tokens, and prefill compiles for the perf regression
    benches.
    """

    def __init__(self, cfg: ModelConfig, params, n_lanes: int = 4,
                 max_len: int = 512, temperature: float = 0.0,
                 rng_seed: int = 0, dispatch_n: int = 8,
                 prefill_bucketing: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.n_lanes = n_lanes
        self.max_len = max_len
        # fixed at construction: the value is baked into the jitted
        # dispatch below, so post-hoc mutation would silently desync the
        # prefill-sampled first token from the decode stream
        self.temperature = float(temperature)
        self.dispatch_n = max(1, dispatch_n)
        self.prefill_bucketing = prefill_bucketing
        self.cache = init_cache(cfg, n_lanes, max_len)
        self.lane_req: List[Optional[Request]] = [None] * n_lanes
        base = jax.random.PRNGKey(rng_seed)
        self._rng_decode = jax.random.fold_in(base, 0)
        self._rng_prefill = jax.random.fold_in(base, 1)
        self._next_token = jnp.zeros((n_lanes,), jnp.int32)
        self._remaining = jnp.zeros((n_lanes,), jnp.int32)
        self._remaining_host = np.zeros((n_lanes,), np.int64)
        # per-lane sampling identity: the admission index seeds the
        # lane's key lineage, tok_idx is its generated-token counter --
        # streams depend only on (admission order, token index)
        self._lane_seed = jnp.zeros((n_lanes,), jnp.int32)
        self._tok_idx = jnp.zeros((n_lanes,), jnp.int32)
        self._admit_count = 0        # admission counter (key lineages)
        self.stats = {"decode_dispatches": 0, "decode_steps": 0,
                      "generated_tokens": 0, "prefill_compiles": 0}
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t))
        self._temperature = self.temperature      # captured, see above
        self._decode_n = jax.jit(
            functools.partial(self._decode_n_fn,
                              temperature=self._temperature,
                              len_cap=self.max_len - 1),
            static_argnames=("n_steps",))

        def prefill_fn(p, tokens, last_pos):
            # Python side effect fires once per trace == once per shape
            # bucket; the recompile regression test pins this counter.
            self.stats["prefill_compiles"] += 1
            return lm_prefill_batched(p, tokens, self.cfg,
                                      last_pos=last_pos)

        self._prefill = jax.jit(prefill_fn)

    def _decode_n_fn(self, params, cache, tokens, rng, remaining,
                     lane_seed, tok_idx, *, n_steps, temperature, len_cap):
        return self.model.decode_n_steps(
            params, cache, tokens, rng, remaining, lane_seed, tok_idx,
            n_steps=n_steps, temperature=temperature, len_cap=len_cap)

    # -- admission --------------------------------------------------------
    def free_lanes(self) -> List[int]:
        return [i for i, r in enumerate(self.lane_req) if r is None]

    def admit(self, req: Request) -> bool:
        lanes = self.free_lanes()
        if not lanes:
            return False
        lane = lanes[0]
        self._lane_seed = self._lane_seed.at[lane].set(self._admit_count)
        self._tok_idx = self._tok_idx.at[lane].set(0)
        self._prefill_into_lane(req, lane)
        self.lane_req[lane] = req
        self._remaining = self._remaining.at[lane].set(req.max_new_tokens)
        self._remaining_host[lane] = req.max_new_tokens
        return True

    def _prefill_into_lane(self, req: Request, lane: int) -> None:
        prompt = req.prompt
        # a fixed-lane cache cannot hold more than max_len - 1 prompt
        # positions and still decode: keep the TAIL of over-long prompts
        # (coherent positions/KV, llama.cpp-style truncation) instead of
        # recording a length the cache cannot back.
        limit = self.max_len - 1
        if prompt.shape[0] > limit:
            prompt = prompt[-limit:]
        plen = int(prompt.shape[0])
        bucket = _bucket_len(plen) if self.prefill_bucketing else plen
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = prompt
        logits, kv = self._prefill(self.params, jnp.asarray(padded),
                                   jnp.asarray([plen - 1], jnp.int32))
        if kv is not None:
            k, v = kv        # (L, 1, Hkv, S_bucket, D)
            smax = self.cache["k"].shape[3]
            take = min(plen, smax)
            self.cache["k"] = jax.lax.dynamic_update_slice(
                self.cache["k"], k[:, :, :, plen - take:plen, :].astype(
                    self.cache["k"].dtype), (0, lane, 0, 0, 0))
            self.cache["v"] = jax.lax.dynamic_update_slice(
                self.cache["v"], v[:, :, :, plen - take:plen, :].astype(
                    self.cache["v"].dtype), (0, lane, 0, 0, 0))
        if "ssm_h" in self.cache:
            # SSM state is rebuilt by streaming the prompt through the
            # decode path (exactly once, O(len) state updates).
            self._stream_ssm_prompt(prompt, lane)
            return
        self.cache["len"] = self.cache["len"].at[lane].set(plen)
        self._set_first_token(logits, lane)

    def _set_first_token(self, logits: jnp.ndarray, lane: int) -> None:
        key = jax.random.fold_in(self._rng_prefill, self._admit_count)
        self._admit_count += 1
        tok = sample_tokens(logits, key, self._temperature)[0]
        self._next_token = self._next_token.at[lane].set(tok)

    def _stream_ssm_prompt(self, prompt: np.ndarray, lane: int) -> None:
        lane_cache = jax.tree_util.tree_map(
            lambda x: x[:, lane:lane + 1] if x.ndim > 1 else x[lane:lane + 1],
            self.cache)
        lane_cache["len"] = jnp.zeros((1,), jnp.int32)
        # a re-admitted lane must NOT inherit the previous request's
        # recurrent state (init_mamba2_state is all-zeros)
        for key in ("ssm_h", "ssm_conv"):
            if key in lane_cache:
                lane_cache[key] = jnp.zeros_like(lane_cache[key])
        logits = None
        for t in prompt:
            logits, lane_cache = self._decode(
                self.params, lane_cache, jnp.asarray([t], jnp.int32))

        def put(full, one):
            if one.ndim > 1:
                return jax.lax.dynamic_update_slice(
                    full, one, (0, lane) + (0,) * (one.ndim - 2))
            return full.at[lane].set(one[0])

        self.cache = jax.tree_util.tree_map(put, self.cache, lane_cache)
        self._set_first_token(logits, lane)

    # -- stepping ----------------------------------------------------------
    def _dispatch_size(self, n: Optional[int]) -> int:
        """Tokens per dispatch: dispatch_n, shrunk (to a power of two, to
        bound recompiles) when every live lane owes fewer tokens."""
        n = n or self.dispatch_n
        live = [i for i, r in enumerate(self.lane_req) if r is not None]
        max_rem = int(self._remaining_host[live].max()) if live else 0
        return min(n, _bucket_len(max(max_rem, 1), floor=1))

    def decode_n(self, n: Optional[int] = None) -> Dict[int, List[int]]:
        """Advance all live lanes up to ``n`` tokens in ONE dispatch.

        Returns {uid: [tokens]} for this block; requests that exhaust
        their budget (or the cache) are retired at the boundary.
        """
        live = [i for i, r in enumerate(self.lane_req) if r is not None]
        if not live:
            return {}
        n = self._dispatch_size(n)
        (toks, valid, self._next_token, self.cache, self._remaining,
         self._tok_idx) = self._decode_n(
            self.params, self.cache, self._next_token, self._rng_decode,
            self._remaining, self._lane_seed, self._tok_idx, n_steps=n)
        self.stats["decode_dispatches"] += 1
        self.stats["decode_steps"] += n
        # one host transfer drains the whole block
        toks_h, valid_h, rem_h = jax.device_get(
            (toks, valid, self._remaining))
        self._remaining_host = np.asarray(rem_h, np.int64)
        out: Dict[int, List[int]] = {}
        for lane in live:
            req = self.lane_req[lane]
            seq = [int(t) for t in toks_h[valid_h[:, lane], lane]]
            req.generated.extend(seq)
            out[req.uid] = seq
            self.stats["generated_tokens"] += len(seq)
            if self._remaining_host[lane] <= 0:
                req.done = True
                self.lane_req[lane] = None
                # a retired lane is DEAD until re-admission: zero its
                # cache length so the length-aware kernel pins a single
                # key block instead of streaming the stale context.
                self.cache["len"] = self.cache["len"].at[lane].set(0)
        return out

    def decode_step(self) -> Dict[int, int]:
        """Single-token compatibility wrapper; returns {uid: token}."""
        return {uid: seq[0] for uid, seq in self.decode_n(1).items() if seq}

    def run(self, requests: List[Request],
            dispatch_n: Optional[int] = None) -> List[Request]:
        """Serve a workload to completion with continuous admission.

        Retirement rides the done-flags returned by the batched dispatch
        (no per-step completion scan over the request list).
        """
        pending = list(requests)
        while pending or any(r is not None for r in self.lane_req):
            while pending and self.free_lanes():
                self.admit(pending.pop(0))
            self.decode_n(dispatch_n)
        return requests
