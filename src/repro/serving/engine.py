"""Serving engine: continuous batching over a fixed-lane or paged KV cache.

The paper's deployment target is single-board LLM inference; this engine
is the framework-scale version: a continuous batcher (vLLM-style) in
front of the model zoo's prefill/decode functions.

Two cache layouts:

* **fixed-lane** (default, the pinned parity reference): the cache is
  partitioned as ``n_lanes x max_len`` at construction -- admission
  capacity is lanes, independent of live context;
* **paged** (``paged=True``): KV lives in a global page pool governed by
  :class:`PagePool`; each lane holds a block table of page ids.  Pages
  are allocated at admission (prompt) and at dispatch boundaries
  (generation growth), freed at retirement, and admission is gated on
  free PAGES, not free lanes -- a board's concurrency becomes
  proportional to actual KV bytes, which is the §6.2 economics argument
  (1.5 TB/s HBM decode engine, capacity-constrained).  Lane reuse is
  copy-free: re-admission just rewrites the lane's block-table row.

The decode hot path is host-sync-free:

* ``prefill`` pads prompts to power-of-two buckets (one XLA compile per
  bucket, not per prompt length) and scatters the prompt KV into a free
  lane (or its pages);
* ``decode_n`` advances every lane ``dispatch_n`` tokens per Python
  dispatch via a jitted ``lax.scan``: sampling (greedy or temperature)
  happens on device, tokens and done-flags accumulate on device, and one
  host transfer drains the block;
* lane retirement/admission (and page mapping) happens only at dispatch
  boundaries;
* weights can be stored block-quantized (``quantize_params``): the
  bandwidth saving is what the paper's decode evaluation is about.

Sampling keys fold from (request admission index, per-request token
index), so a request's generated stream -- greedy or temperature -- is
invariant to dispatch granularity, admission timing, lane neighbors,
and cache layout (paged vs dense is token-exact).
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.invariants import invariant
from repro.analysis.sanitizer import PageSanitizer
from repro.models.common import ModelConfig
from repro.models.registry import Model, build_model
from repro.models.transformer import (init_cache, init_paged_cache,
                                      lm_prefill_batched, paged_capacity,
                                      sample_tokens)
from repro.obs.flight import FlightRecorder, flight_guard
from repro.obs.metrics import MetricsRegistry, StatsView
from repro.obs.trace import SpanTracer
from repro.quant.quantize import QTensor, dequantize, quantize
from repro.serving.prefix_cache import PrefixCache
from repro.serving.resilience import AdmissionRejected, DegradationLadder


# ----------------------------------------------------------------------
# weight quantization store
# ----------------------------------------------------------------------

def quantize_params(params, fmt: str, min_size: int = 1 << 16):
    """Quantize every >=2-D weight whose k-dim divides the block size.

    Returns (q_params, stats).  Weights that cannot be block-quantized
    (small, odd shapes) stay dense -- same policy as llama.cpp, which
    keeps norms/embeddings in high precision for Q formats.
    """
    from repro.quant.formats import get_format
    blk = get_format(fmt).block
    n_q = n_dense = bytes_q = bytes_dense = 0

    def leaf(path, x):
        nonlocal n_q, n_dense, bytes_q, bytes_dense
        if (x.ndim == 2 and x.size >= min_size and x.shape[0] % blk == 0):
            qt = quantize(x, fmt)
            n_q += 1
            bytes_q += qt.nbytes()
            return qt
        n_dense += 1
        bytes_dense += x.size * x.dtype.itemsize
        return x

    qp = jax.tree_util.tree_map_with_path(leaf, params)
    stats = {"quantized": n_q, "dense": n_dense,
             "quantized_bytes": bytes_q, "dense_bytes": bytes_dense}
    return qp, stats


def dequantize_params(q_params):
    """Materialize dense weights (carrying the quantization error)."""
    return jax.tree_util.tree_map(
        lambda x: dequantize(x) if isinstance(x, QTensor) else x,
        q_params, is_leaf=lambda x: isinstance(x, QTensor))


# ----------------------------------------------------------------------
# page-pool allocator
# ----------------------------------------------------------------------

class PagePool:
    """Host-side free-list allocator over the global KV page pool.

    Invariants (pinned by the allocator-churn tests):

    * conservation -- ``n_free + n_in_use == n_pages`` at all times;
    * no double-free / no double-alloc -- page ids move between exactly
      two disjoint sets;
    * refcounting -- every in-use page carries a refcount >= 1 (one per
      holder: each mapping lane, plus the prefix cache when it caches
      the page).  ``share`` adds a holder, ``free`` drops one; the page
      returns to the free list only when the LAST holder lets go, so a
      retiring lane can never free a page another lane still maps;
    * reservation safety -- ``reserve(n)`` promises ``n`` future
      ``alloc`` pages; ``available()`` (what admission gates on) never
      counts pages already promised to admitted requests, so a lane's
      mid-generation growth (and its copy-on-write split of a shared
      page, which draws on the same reservation) cannot fail;
    * zero fragmentation by construction -- pages are an unordered pool
      (the block table supplies ordering), so any free page serves any
      request: the free list can never be "too fragmented to admit";
    * capacity elasticity -- ``shrink(n)`` retires up to ``n`` FREE
      (and unpromised) pages into a disabled set and ``grow(n)``
      returns them: the multi-model pool trades KV pages for weight
      residency without ever touching a page a lane holds or was
      promised.  A shared page is in-use like any other: sharing pins
      pages against shrink exactly as a live lane does.
    """

    def __init__(self, n_pages: int, page_size: int):
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._in_use: set = set()
        self._refcount: Dict[int, int] = {}
        self._disabled: List[int] = []
        self._reserved = 0
        self.hwm = 0                 # high-water mark: in-use + reserved
        self.alloc_count = 0
        self.free_count = 0
        self.share_count = 0
        self.cow_count = 0
        # optional lifecycle monitor (repro.analysis.sanitizer): every
        # mutator forwards its op through ONE attribute check -- the
        # entire cost of running unsanitized
        self.monitor = None

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_use(self) -> int:
        return len(self._in_use)

    @property
    def n_disabled(self) -> int:
        return len(self._disabled)

    @property
    def n_active(self) -> int:
        """Pages currently part of the pool (physical minus disabled)."""
        return self.n_pages - len(self._disabled)

    def available(self) -> int:
        """Pages admissible to NEW requests (free minus promised)."""
        return len(self._free) - self._reserved

    def reserve(self, n: int) -> bool:
        """Promise ``n`` pages to a request; False if over-committed."""
        ok = n <= self.available()
        if ok:
            self._reserved += n
            self.hwm = max(self.hwm, self.n_in_use + self._reserved)
        m = self.monitor
        if m is not None:
            m.record("reserve", n=n, ok=ok)
        return ok

    def unreserve(self, n: int) -> None:
        invariant(0 <= n <= self._reserved,
                  "unreserve exceeds reservation",
                  n=n, reserved=self._reserved)
        self._reserved -= n
        m = self.monitor
        if m is not None:
            m.record("unreserve", n=n)

    def alloc(self, n: int, holder: Any = None) -> List[int]:
        """Take ``n`` previously reserved pages off the free list.
        ``holder`` is an opaque owner tag (a lane index, the prefix
        cache ...) forwarded to the lifecycle monitor when one is
        attached."""
        invariant(n <= self._reserved, "alloc without reservation",
                  n=n, reserved=self._reserved)
        invariant(n <= len(self._free), "free list underflow",
                  n=n, n_free=len(self._free))
        self._reserved -= n
        pages = [self._free.pop() for _ in range(n)]
        self._in_use.update(pages)
        for p in pages:
            self._refcount[p] = 1
        self.alloc_count += n
        m = self.monitor
        if m is not None:
            m.record("alloc", pages=list(pages), holder=holder)
        return pages

    def free(self, pages: List[int], holder: Any = None) -> None:
        """Drop one reference per page; a page returns to the free list
        only when its LAST holder releases it (``free_count`` counts
        physical returns, not reference drops)."""
        for p in pages:
            invariant(p in self._in_use, f"double free of page {p}",
                      page=p)
            self._refcount[p] -= 1
            if self._refcount[p] == 0:
                del self._refcount[p]
                self._in_use.remove(p)
                self._free.append(p)
                self.free_count += 1
        m = self.monitor
        if m is not None and pages:
            m.record("free", pages=list(pages), holder=holder)

    def share(self, pages: List[int], holder: Any = None) -> None:
        """Add one reference per page: a second holder (another lane's
        block table, or the prefix cache) now maps the same bytes."""
        for p in pages:
            invariant(p in self._in_use,
                      f"share of unallocated page {p}", page=p)
            self._refcount[p] += 1
        self.share_count += len(pages)
        m = self.monitor
        if m is not None and pages:
            m.record("share", pages=list(pages), holder=holder)

    def cow(self, page: int, holder: Any = None) -> int:
        """Copy-on-write split: the caller gives up its reference on a
        SHARED ``page`` and receives a fresh exclusive page in exchange,
        drawn from its admission-time reservation (which is sized for
        the lane's full footprint, so the split cannot fail mid-flight).
        The caller copies the page contents and rewrites its block-table
        entry; the other holders keep the original."""
        invariant(page in self._in_use,
                  f"cow of unallocated page {page}", page=page)
        invariant(self._refcount[page] >= 2,
                  "cow of an exclusively owned page", page=page,
                  refcount=self._refcount[page])
        invariant(self._reserved >= 1, "cow without a reservation",
                  page=page)
        self._reserved -= 1
        new = self._free.pop()
        self._in_use.add(new)
        self._refcount[new] = 1
        self._refcount[page] -= 1
        self.alloc_count += 1
        self.cow_count += 1
        m = self.monitor
        if m is not None:
            m.record("cow", old=page, new=new, holder=holder)
        return new

    def refcount(self, page: int) -> int:
        """Holders of ``page`` (0 if free/disabled)."""
        return self._refcount.get(page, 0)

    def is_shared(self, page: int) -> bool:
        return self._refcount.get(page, 0) >= 2

    @property
    def n_shared(self) -> int:
        """In-use pages with more than one holder."""
        return sum(1 for c in self._refcount.values() if c >= 2)

    @property
    def n_refs(self) -> int:
        """Total references across all in-use pages."""
        return sum(self._refcount.values())

    def shrink(self, n: int) -> int:
        """Retire up to ``n`` free, unpromised pages from the pool (the
        weight-residency trade: HBM bytes leave the KV pool).  Returns
        the number actually retired -- never a page a lane holds or a
        reservation has promised."""
        take = max(min(int(n), self.available()), 0)
        pages = [self._free.pop() for _ in range(take)]
        self._disabled.extend(pages)
        m = self.monitor
        if m is not None and pages:
            m.record("shrink", pages=pages)
        return take

    def grow(self, n: int) -> int:
        """Return up to ``n`` previously retired pages to the free list
        (weights left the board; the KV pool gets its bytes back)."""
        back = max(min(int(n), len(self._disabled)), 0)
        pages = [self._disabled.pop() for _ in range(back)]
        self._free.extend(pages)
        m = self.monitor
        if m is not None and pages:
            m.record("grow", pages=pages)
        return back

    def bind_registry(self, registry: MetricsRegistry,
                      prefix: str = "pool") -> None:
        """Publish the pool's occupancy as live callback gauges.

        Callback gauges read through to the allocator's own state, so
        the alloc/free hot path pays nothing for being observable."""
        registry.gauge(f"{prefix}.pages.free", fn=lambda: self.n_free,
                       help="free pages (incl. reserved)")
        registry.gauge(f"{prefix}.pages.in_use", fn=lambda: self.n_in_use,
                       help="pages allocated to live lanes")
        registry.gauge(f"{prefix}.pages.reserved",
                       fn=lambda: self._reserved,
                       help="pages promised to admitted requests")
        registry.gauge(f"{prefix}.pages.disabled",
                       fn=lambda: self.n_disabled,
                       help="pages retired for weight residency")
        registry.gauge(f"{prefix}.pages.hwm", fn=lambda: self.hwm,
                       help="high-water mark of in-use + reserved pages")
        registry.gauge(f"{prefix}.pages.allocs",
                       fn=lambda: self.alloc_count,
                       help="cumulative page allocations")
        registry.gauge(f"{prefix}.pages.frees",
                       fn=lambda: self.free_count,
                       help="cumulative page frees")
        registry.gauge(f"{prefix}.pages.shared",
                       fn=lambda: self.n_shared,
                       help="in-use pages with more than one holder")
        registry.gauge(f"{prefix}.pages.cow_splits",
                       fn=lambda: self.cow_count,
                       help="cumulative copy-on-write page splits")

    def check(self) -> None:
        """Raise unless the conservation invariants hold (test hook)."""
        invariant(len(self._free) + len(self._in_use)
                  + len(self._disabled) == self.n_pages,
                  "page conservation broken", n_free=len(self._free),
                  n_in_use=len(self._in_use),
                  n_disabled=len(self._disabled), n_pages=self.n_pages)
        invariant(len(set(self._free)) == len(self._free),
                  "duplicate page on the free list")
        invariant(len(set(self._disabled)) == len(self._disabled),
                  "duplicate page on the disabled list")
        invariant(not self._in_use.intersection(self._free),
                  "page both in use and free")
        invariant(not self._in_use.intersection(self._disabled),
                  "page both in use and disabled")
        invariant(not set(self._free).intersection(self._disabled),
                  "page both free and disabled")
        invariant(0 <= self._reserved <= len(self._free),
                  "reservation exceeds the free list",
                  reserved=self._reserved, n_free=len(self._free))
        invariant(set(self._refcount) == self._in_use,
                  "refcounts out of sync with the in-use set")
        invariant(all(c >= 1 for c in self._refcount.values()),
                  "in-use page with zero refcount")


# ----------------------------------------------------------------------
# continuous-batching engine
# ----------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: which registered model serves this request (multi-model engines;
    #: a single-model ServeEngine ignores it)
    model_id: Optional[str] = None
    #: degradation ladder victim ordering: LOWER priority is evicted
    #: first when the engine sheds load (ties broken by highest lane
    #: context, i.e. the most page-hungry request goes first)
    priority: int = 0


@dataclasses.dataclass
class LaneCheckpoint:
    """Host-side snapshot of one live lane, taken at a dispatch boundary.

    Everything a request needs to resume BIT-IDENTICALLY -- on this
    engine or another one built with the same config, ``rng_seed`` and
    ``temperature``:

    * ``req`` -- the request itself (uid, prompt, tokens generated so
      far keep accumulating in place across engines);
    * ``lane_seed`` / ``tok_idx`` -- the sampling identity: the stream
      is a pure function of (key lineage, token index), so restoring
      both replays the exact RNG stream the request would have drawn;
    * ``next_token`` -- the already-sampled token the next decode step
      consumes (sampled before eviction, must not be re-drawn);
    * ``remaining`` / ``ctx_len`` -- generation budget left and live
      context length;
    * ``kv_pages`` -- the lane's live KV pages gathered from the pool
      through its block table, in logical order ``(L, n_pages, Hkv,
      ps[, D|1])`` per pool key (int8 caches carry their scale pages);
      the engine's scratch page is never captured;
    * ``ssm_state`` -- recurrent per-lane state for ssm/hybrid families.

    The payload is plain numpy: it is exactly what a fleet would ship
    over the host link, ``ceil(ctx/page_size)`` pages at a time.
    """

    req: Request
    lane_seed: int
    tok_idx: int
    remaining: int
    ctx_len: int
    next_token: int
    page_size: int
    kv_pages: Dict[str, np.ndarray]
    ssm_state: Dict[str, np.ndarray]

    @property
    def uid(self) -> int:
        return self.req.uid

    @property
    def n_pages(self) -> int:
        for v in self.kv_pages.values():
            return int(v.shape[1])
        return 0

    def nbytes(self) -> int:
        """Bytes a migration must move over the link (KV + state)."""
        return sum(int(v.nbytes) for v in self.kv_pages.values()) + sum(
            int(v.nbytes) for v in self.ssm_state.values())


def _bucket_len(n: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor) -- the prefill shape bucket."""
    b = floor
    while b < n:
        b <<= 1
    return b


#: cache keys holding the shared page pool (no lane axis)
_POOL_KEYS = ("k_pages", "v_pages", "k_scale_pages", "v_scale_pages")
#: cache keys indexed by lane on axis 0 (everything else stacks (L, B, ...))
_LANE0_KEYS = ("len", "block_tables")


def prefix_sharing_supported(cfg: ModelConfig) -> bool:
    """Whether ``cfg`` can serve with ``prefix_sharing=True``: the whole
    prompt context must be page-resident and append-only.  Sliding-
    window lanes rewrite their fixed page set in place (a shared page
    would corrupt under the donor); recurrent families (ssm/hybrid)
    keep prompt state outside the pool, so a mapped prefix would skip
    rebuilding it."""
    return (not cfg.is_encdec and not cfg.attn_free
            and cfg.family != "hybrid" and cfg.sliding_window is None)


@dataclasses.dataclass
class PrefixHit:
    """One admission's radix-cache match (see :class:`PrefixCache`)."""

    pages: List[int]                     # full shared pages, block order
    matched_len: int                     # prompt tokens covered in total
    partial: Optional[Tuple[int, int]]   # (page, n_tokens) tail page

    @property
    def n_full(self) -> int:
        """Full matched pages -- the lane's allocation discount (the
        partial page is NOT discounted: its copy-on-write split draws a
        fresh page from the reservation)."""
        return len(self.pages)


class ServeEngine:
    """Continuous batcher around the LM decode step (fixed-lane or paged).

    ``dispatch_n`` is the decode granularity: tokens generated per Python
    dispatch (per lane).  ``stats`` tracks dispatches, decode steps,
    generated tokens, and prefill compiles for the perf regression
    benches; a paged engine adds page-pool high-water mark and
    page-blocked admission counts.

    Paged mode: ``n_lanes`` bounds the decode batch width, ``n_pages``
    bounds KV bytes (default: dense-equivalent, ``n_lanes`` full
    contexts' worth).  Size ``n_lanes`` above ``n_pages / (max_len /
    page_size)`` and short-context admission exceeds the dense lane
    count -- the BENCH_decode paged section measures exactly this.
    """

    #: legacy stats key -> namespaced metric suffix (the authoritative
    #: telemetry schema; full names prepend the engine's ``name``)
    STATS_SCHEMA = {
        "decode_dispatches": "decode.dispatches",
        "decode_steps": "decode.steps",
        "decode_compiles": "decode.compiles",
        "generated_tokens": "tokens.generated",
        "prefill_compiles": "prefill.compiles",
        "ssm_prefill_compiles": "prefill.ssm_compiles",
        "kv_pages_hwm": "kv.pages_hwm",
        "kv_admit_blocked": "kv.admit_blocked",
        "preemptions": "preempt.evictions",
        "restores": "preempt.restores",
        "pages_migrated": "preempt.pages_migrated",
        "retry_attempts": "retry.attempts",
        "retry_hedges": "retry.hedges",
        "admit_rejected": "admit.rejected",
        "degrade_transitions": "degrade.transitions",
        "degrade_sheds": "degrade.sheds",
        "prefix_hits": "prefix.hits",
        "prefix_misses": "prefix.misses",
        "prefix_tokens_matched": "prefix.tokens_matched",
        "prefix_pages_shared": "prefix.pages_shared",
        "prefix_pages_saved": "prefix.pages_saved",
        "prefix_cow_copies": "prefix.cow_copies",
        "prefix_evictions": "prefix.evictions",
    }

    def __init__(self, cfg: ModelConfig, params, n_lanes: int = 4,
                 max_len: int = 512, temperature: float = 0.0,
                 rng_seed: int = 0, dispatch_n: int = 8,
                 prefill_bucketing: bool = True, paged: bool = False,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 prefix_sharing: bool = False, sanitize: bool = False,
                 tracer: Optional[SpanTracer] = None,
                 registry: Optional[MetricsRegistry] = None,
                 name: str = "serve",
                 ladder: Optional[DegradationLadder] = None,
                 clock: Optional[Callable[[], float]] = None,
                 flight: Optional[FlightRecorder] = None,
                 slo=None):
        self.cfg = cfg
        # graceful-degradation ladder (None = legacy behavior: run()
        # never sheds, and only raises in the never-admissible case)
        self.ladder = ladder
        if ladder is not None:
            ladder.name = name
        self.model = build_model(cfg)
        self.params = params
        self.n_lanes = n_lanes
        self.max_len = max_len
        # fixed at construction: the value is baked into the jitted
        # dispatch below, so post-hoc mutation would silently desync the
        # prefill-sampled first token from the decode stream
        self.temperature = float(temperature)
        self.dispatch_n = max(1, dispatch_n)
        self.prefill_bucketing = prefill_bucketing
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self._sanitizer: Optional[PageSanitizer] = None
        if self.paged:
            invariant(not cfg.is_encdec,
                      "paged cache: decoder-only families",
                      family=cfg.family)
            if cfg.attn_free:
                self._bt_width = 0      # O(1) recurrent state, no pages
            else:
                self._bt_width = paged_capacity(max_len, cfg) // page_size
            if n_pages is None:
                n_pages = n_lanes * self._bt_width
            invariant(n_pages >= self._bt_width, (
                "page pool smaller than one full context: no request "
                "could ever be admitted"), n_pages=n_pages,
                bt_width=self._bt_width)
            self.pool = PagePool(n_pages, page_size)
            # one extra physical page the allocator never hands out: a
            # DEAD lane still steps inside the jitted batch and writes
            # its (frozen) slot through its block table -- pointing dead
            # rows at the scratch page keeps that write off pages the
            # allocator may have re-issued to a live lane
            self._scratch_page = n_pages
            if sanitize:
                self._sanitizer = PageSanitizer(strict=True)
                self.pool.monitor = self._sanitizer
                self._sanitizer.record(
                    "init", n_pages=n_pages, page_size=page_size,
                    scratch=self._scratch_page)
            self.cache = init_paged_cache(cfg, n_lanes, max_len,
                                          page_size=page_size,
                                          n_pages=n_pages + 1)
            if "block_tables" in self.cache:
                self.cache["block_tables"] = jnp.full_like(
                    self.cache["block_tables"], self._scratch_page)
            self._lane_pages: List[List[int]] = [[] for _ in range(n_lanes)]
            self._lane_reserved = [0] * n_lanes
            self._blocked_uids: set = set()
            self.prefix_cache: Optional[PrefixCache] = None
            if prefix_sharing:
                invariant(prefix_sharing_supported(cfg), (
                    "prefix sharing needs the whole prompt context "
                    "page-resident and append-only (no sliding window, "
                    "no recurrent state)"), family=cfg.family)
                invariant("ssm_h" not in self.cache,
                          "prefix sharing: attention-backed paged "
                          "caches only")
                self.prefix_cache = PrefixCache(self.pool, page_size)
        else:
            self.pool = None
            self._bt_width = 0
            self.prefix_cache = None
            self.cache = init_cache(cfg, n_lanes, max_len)
        self._len_host = np.zeros((n_lanes,), np.int64)
        self.lane_req: List[Optional[Request]] = [None] * n_lanes
        base = jax.random.PRNGKey(rng_seed)
        self._rng_decode = jax.random.fold_in(base, 0)
        self._rng_prefill = jax.random.fold_in(base, 1)
        self._next_token = jnp.zeros((n_lanes,), jnp.int32)
        self._remaining = jnp.zeros((n_lanes,), jnp.int32)
        self._remaining_host = np.zeros((n_lanes,), np.int64)
        # per-lane sampling identity: the admission index seeds the
        # lane's key lineage, tok_idx is its generated-token counter --
        # streams depend only on (admission order, token index)
        self._lane_seed = jnp.zeros((n_lanes,), jnp.int32)
        self._tok_idx = jnp.zeros((n_lanes,), jnp.int32)
        self._admit_count = 0        # admission counter (key lineages)
        # telemetry: every counter lives in the registry under
        # "<name>.<suffix>"; self.stats is a MutableMapping view keyed
        # by the legacy flat names, so existing call sites (and the
        # bench's reset idiom) keep working against the shared registry
        self.name = name
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else SpanTracer(
            enabled=False, clock=clock, registry=self.registry)
        # one shared clock per engine: spans, SLO observations, and any
        # request timestamps all read THIS callable, so a timeline mixing
        # tracer spans with admit/first-token marks is skew-free (the
        # EventLog default matches; lint R003 patrols regressions)
        self.clock = clock if clock is not None else self.tracer.clock
        # flight recorder: taps the tracer's span/instant hooks; dumped
        # by flight_guard when a sanitizer/invariant error escapes an op
        self.flight = flight
        if flight is not None:
            flight.attach(tracer=self.tracer)
        # SLO burn-rate control loop (an SLOController); fed per-lane
        # TTFT/tpot at dispatch drain, stepped once per dispatch
        self.slo = slo
        self._admit_t: Dict[int, float] = {}
        keymap = {k: f"{name}.{suffix}"
                  for k, suffix in self.STATS_SCHEMA.items()}
        for metric_name in keymap.values():
            # a fresh engine starts its counters at zero even on a
            # shared registry (modelpool reloads accumulate history in
            # the entry, not in the live counters)
            self.registry.counter(metric_name).set(0)
        self._stats = StatsView(self.registry, keymap)
        if self.paged:
            self.pool.bind_registry(self.registry, prefix=f"{name}.pool")
        if self.prefix_cache is not None:
            self.registry.gauge(
                f"{name}.prefix.cached_pages",
                fn=lambda: self.prefix_cache.n_pages,
                help="pool pages the radix prompt cache holds a ref on")
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t))
        self._temperature = self.temperature      # captured, see above
        self._decode_n = jax.jit(
            functools.partial(self._decode_n_fn,
                              temperature=self._temperature,
                              len_cap=self.max_len - 1),
            static_argnames=("n_steps",))

        def prefill_fn(p, tokens, last_pos):
            # Python side effect fires once per trace == once per shape
            # bucket; the recompile regression test pins this counter.
            self.stats["prefill_compiles"] += 1
            return lm_prefill_batched(p, tokens, self.cfg,
                                      last_pos=last_pos)

        self._prefill = jax.jit(prefill_fn)

        def ssm_prefill_fn(p, lane_cache, tokens, plen):
            self.stats["ssm_prefill_compiles"] += 1
            return self._ssm_prefill_scan(p, lane_cache, tokens, plen)

        self._ssm_prefill = jax.jit(ssm_prefill_fn)

    def _decode_n_fn(self, params, cache, tokens, rng, remaining,
                     lane_seed, tok_idx, *, n_steps, temperature, len_cap):
        # Python side effect fires once per XLA trace == once per
        # distinct n_steps; the telemetry overhead-budget test pins this
        # counter traced-vs-untraced.
        self.stats["decode_compiles"] += 1
        return self.model.decode_n_steps(
            params, cache, tokens, rng, remaining, lane_seed, tok_idx,
            n_steps=n_steps, temperature=temperature, len_cap=len_cap)

    # -- telemetry --------------------------------------------------------
    @property
    def stats(self) -> StatsView:
        """Legacy stats mapping, backed by the metrics registry."""
        return self._stats

    @stats.setter
    def stats(self, values: Dict[str, Any]) -> None:
        # the bench reset idiom (`eng.stats = {k: 0 for k in eng.stats}`)
        # writes values through the view; the schema itself is fixed
        for k, v in values.items():
            self._stats[k] = v

    def lane_track(self, lane: int) -> str:
        """Trace track name for one lane of this engine."""
        return f"{self.name}/lane{lane}"

    # -- admission --------------------------------------------------------
    def free_lanes(self) -> List[int]:
        return [i for i, r in enumerate(self.lane_req) if r is None]

    def _pages_needed(self, positions: int) -> int:
        """Pages backing ``positions`` cache slots; a sliding-window lane
        rotates within its fixed ``bt_width`` page set, so the need is
        capped there."""
        if self._bt_width == 0:
            return 0
        ps = self.page_size
        return min(-(-int(positions) // ps), self._bt_width)

    def _trunc_plen(self, req: Request) -> int:
        return min(int(req.prompt.shape[0]), self.max_len - 1)

    def admission_pages(self, req: Request) -> int:
        """Worst-case page need of ``req`` (prompt + full budget + the
        trailing write slot) -- what admission gates on.  The worst case
        is CLAMPED to ``max_len`` positions: generation stops at the
        ``len_cap`` regardless of budget, so a request whose budget
        exceeds the cache must not demand more pages than the cache can
        ever back (it could otherwise never be admitted)."""
        worst = min(self._trunc_plen(req) + req.max_new_tokens + 1,
                    self.max_len)
        return self._pages_needed(worst)

    def can_admit(self, req: Request) -> bool:
        if not self.free_lanes():
            return False
        if not self.paged:
            return True
        return self.admission_pages(req) <= self.pool.available()

    def admit(self, req: Request) -> bool:
        lanes = self.free_lanes()
        if not lanes:
            return False
        lane = lanes[0]
        hit: Optional[PrefixHit] = None
        if self.paged:
            need = self.admission_pages(req)
            reserve = need
            if self.prefix_cache is not None:
                hit = self._prefix_match(req)
                # every FULL matched page is a page this request never
                # allocates: the reservation (what admission gates on)
                # shrinks by exactly that, which is the effective-
                # admission gain the bench measures
                reserve = need - hit.n_full
            if not self.pool.reserve(reserve):
                if self.prefix_cache is not None \
                        and self.prefix_cache.n_pages:
                    # under pool pressure, cached-but-unmapped prefix
                    # pages are the first bytes to go; eviction may
                    # drop matched nodes (their pages can be reissued
                    # once the last holder lets go), so re-match after
                    self._trim_prefix_cache(reserve)
                    hit = self._prefix_match(req)
                    reserve = need - hit.n_full
                if not self.pool.reserve(reserve):
                    # a lane is free but the KV bytes are not: admission
                    # is gated on pages, the caller retries after
                    # retirements.  Counted once per blocked EPISODE
                    # (not per retry), so the stat is dispatch-
                    # granularity invariant.
                    if req.uid not in self._blocked_uids:
                        self._blocked_uids.add(req.uid)
                        self.stats["kv_admit_blocked"] += 1
                        self.tracer.instant("admit.blocked",
                                            track=self.lane_track(lane),
                                            uid=req.uid, need_pages=need)
                    return False
            self._blocked_uids.discard(req.uid)
        if self.slo is not None and req.uid not in self._admit_t:
            # TTFT starts at first successful admission (re-admission
            # after evict/restore keeps the original mark)
            self._admit_t[req.uid] = self.clock()
        with flight_guard(self.flight, op="admit",
                          registry=self.registry), \
                self.tracer.span("admit", track=self.lane_track(lane),
                                 uid=req.uid):
            if self.paged:
                self._lane_reserved[lane] = reserve
                self._lane_pages[lane] = []
                if hit is None or hit.matched_len == 0:
                    # map the prompt's pages (plus the first decode
                    # write slot); generation growth maps the rest at
                    # dispatch boundaries
                    self._map_pages(lane, self._pages_needed(
                        self._trunc_plen(req) + 1))
            self._lane_seed = self._lane_seed.at[lane].set(
                self._admit_count)
            self._tok_idx = self._tok_idx.at[lane].set(0)
            if hit is not None and hit.matched_len > 0:
                self._prefill_hit(req, lane, hit)
            else:
                if self.prefix_cache is not None:
                    self.stats["prefix_misses"] += 1
                self._prefill_into_lane(req, lane)
            if self.prefix_cache is not None:
                self._cache_lane_prefix(req, lane)
            self.lane_req[lane] = req
            self._remaining = self._remaining.at[lane].set(
                req.max_new_tokens)
            self._remaining_host[lane] = req.max_new_tokens
        return True

    def _map_pages(self, lane: int, target: int) -> None:
        """Grow ``lane``'s block table to ``target`` mapped pages, drawing
        on the reservation made at admission (which makes this infallible
        mid-flight).  Lane reuse is copy-free: the row is simply
        rewritten, pages of the previous occupant were freed at its
        retirement."""
        have = len(self._lane_pages[lane])
        if target <= have:
            return
        new = self.pool.alloc(target - have, holder=lane)
        self._lane_reserved[lane] -= len(new)
        self._lane_pages[lane].extend(new)
        self.cache["block_tables"] = (
            self.cache["block_tables"].at[lane, have:target]
            .set(jnp.asarray(new, jnp.int32)))
        s = self._sanitizer
        if s is not None:
            s.record("map", lane=lane, pages=list(new))
        self.stats["kv_pages_hwm"] = max(self.stats["kv_pages_hwm"],
                                         self.pool.hwm)

    # -- prefix sharing ----------------------------------------------------
    def _trunc_prompt(self, req: Request) -> np.ndarray:
        """The prompt as the lane will actually hold it: a fixed cache
        cannot back more than ``max_len - 1`` prompt positions and still
        decode, so over-long prompts keep their TAIL (coherent
        positions/KV, llama.cpp-style truncation)."""
        prompt = req.prompt
        limit = self.max_len - 1
        if prompt.shape[0] > limit:
            prompt = prompt[-limit:]
        return prompt

    def _prefix_match(self, req: Request) -> PrefixHit:
        """Match the (truncated) prompt against the radix cache.  int8
        caches match FULL pages only: the hit path replays the batched
        full-precision prefill for the logits (see ``_prefill_hit``),
        and a partial page would save nothing while still costing a
        copy-on-write split."""
        prompt = self._trunc_prompt(req)
        pages, matched, partial = self.prefix_cache.match(
            np.asarray(prompt),
            allow_partial=self.cfg.kv_quant != "int8")
        return PrefixHit(pages=pages, matched_len=matched, partial=partial)

    def _trim_prefix_cache(self, target_available: int) -> int:
        """Evict LRU cache entries until the pool can cover a
        ``target_available``-page reservation (or the cache is empty).
        A dropped page only refills the free list if no live lane still
        maps it, hence the loop on actual availability."""
        dropped = 0
        while (self.pool.available() < target_available
               and self.prefix_cache.n_pages):
            if not self.prefix_cache.evict_lru():
                break
            dropped += 1
            self.stats["prefix_evictions"] += 1
        return dropped

    def _prefill_hit(self, req: Request, lane: int, hit: PrefixHit) -> None:
        """Admit ``req`` over a radix-cache hit: map the matched pages
        into the lane's block table (refcount bump, zero copies), then
        produce the prompt's last-token logits.

        * full-precision KV: only the unmatched TAIL streams through
          the decode step (the masked-scan prefill path) -- zero new
          prefill work for the matched span.  A matched partial tail
          page is copy-on-written first: this lane's first append
          diverges from the donor's.
        * int8 KV: the decode step reads DEQUANTIZED pages, so a
          streamed tail would attend to lossy prefix KV while the
          non-shared engine's batched prefill attends at full
          precision -- the first token would drift.  The batched
          prefill replays for the logits (bit-exact by construction)
          and only the tail pages are scattered; the page/admission
          saving stands, the prefill-compute saving does not.
        """
        prompt = self._trunc_prompt(req)
        plen = int(prompt.shape[0])
        shared = list(hit.pages)
        if hit.partial is not None:
            shared.append(hit.partial[0])
        # the lane takes its own reference on every matched page; the
        # block-table row is written in logical order, so evict's
        # position-ordered gather needs no special case
        self.pool.share(shared, holder=lane)
        self._lane_pages[lane] = list(shared)
        self.cache["block_tables"] = (
            self.cache["block_tables"].at[lane, :len(shared)]
            .set(jnp.asarray(shared, jnp.int32)))
        s = self._sanitizer
        if s is not None:
            s.record("map", lane=lane, pages=list(shared))
        if hit.partial is not None:
            self._cow_lane_page(lane, len(hit.pages))
        self._map_pages(lane, self._pages_needed(plen + 1))
        self._len_host[lane] = plen
        self.stats["prefix_hits"] += 1
        self.stats["prefix_tokens_matched"] += hit.matched_len
        self.stats["prefix_pages_shared"] += len(shared)
        self.stats["prefix_pages_saved"] += hit.n_full
        self.tracer.instant("prefix.hit", track=self.lane_track(lane),
                            uid=req.uid, matched_tokens=hit.matched_len,
                            shared_pages=len(shared))
        if self.cfg.kv_quant == "int8":
            self._prefill_hit_quant(prompt, lane, plen, hit)
        else:
            self._prefill_hit_stream(prompt, lane, plen, hit.matched_len)

    def _cow_lane_page(self, lane: int, idx: int) -> None:
        """Copy-on-write split of the lane's shared block ``idx``: swap
        in a fresh page from the reservation, snapshot the shared
        page's contents into it (jax arrays are immutable, so the copy
        is a true point-in-time snapshot even while the donor keeps
        appending to the original), and retarget the block table."""
        old = self._lane_pages[lane][idx]
        with self.tracer.span("prefix.cow", track=self.lane_track(lane),
                              page=old):
            new = self.pool.cow(old, holder=lane)
            self._lane_reserved[lane] -= 1
            self._lane_pages[lane][idx] = new
            for key in _POOL_KEYS:
                if key in self.cache:
                    self.cache[key] = self.cache[key].at[:, new].set(
                        self.cache[key][:, old])
            self.cache["block_tables"] = (
                self.cache["block_tables"].at[lane, idx].set(new))
            s = self._sanitizer
            if s is not None:
                s.record("write", lane=lane, pages=[new],
                         kind="cow_copy")
        self.stats["prefix_cow_copies"] += 1
        self.stats["kv_pages_hwm"] = max(self.stats["kv_pages_hwm"],
                                         self.pool.hwm)

    def _prefill_hit_stream(self, prompt: np.ndarray, lane: int,
                            plen: int, matched_len: int) -> None:
        """Full-precision hit path: stream only the unmatched tail
        through the masked-scan decode path, attending over the shared
        span already page-resident.  Bit-exactness vs the batched
        prefill is pinned by the prefix exactness tests."""
        tail = np.asarray(prompt[matched_len:], np.int32)
        tlen = int(tail.shape[0])
        invariant(tlen >= 1, "prefix match must leave a tail token",
                  plen=plen, matched_len=matched_len)
        s = self._sanitizer
        if s is not None:
            # the streamed tail writes positions [matched_len, plen)
            # plus the frozen write slot at plen (pad steps)
            pages = self._lane_pages[lane][matched_len // self.page_size:
                                           self._pages_needed(plen + 1)]
            if pages:
                s.record("write", lane=lane, pages=list(pages),
                         kind="prefill")
        lane_cache = self._slice_lane_cache(lane)
        lane_cache["len"] = jnp.full((1,), matched_len, jnp.int32)
        bucket = _bucket_len(tlen) if self.prefill_bucketing else tlen
        padded = np.zeros((bucket,), np.int32)
        padded[:tlen] = tail
        with self.tracer.span("prefix.tail_prefill",
                              track=self.lane_track(lane),
                              bucket=bucket, tlen=tlen):
            logits, lane_cache = self._ssm_prefill(
                self.params, lane_cache, jnp.asarray(padded),
                jnp.asarray(tlen, jnp.int32))
        self._merge_lane_cache(lane_cache, lane)
        self._set_first_token(logits, lane)

    def _prefill_hit_quant(self, prompt: np.ndarray, lane: int,
                           plen: int, hit: PrefixHit) -> None:
        """int8 hit path: batched prefill for exact logits, scatter
        only the blocks the match did not cover."""
        bucket = _bucket_len(plen) if self.prefill_bucketing else plen
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = prompt
        with self.tracer.span("prefill.bucket",
                              track=self.lane_track(lane),
                              bucket=bucket, plen=plen):
            logits, kv = self._prefill(self.params, jnp.asarray(padded),
                                       jnp.asarray([plen - 1], jnp.int32))
            self._scatter_prompt_paged(kv, lane, plen,
                                       first_block=hit.n_full)
        self.cache["len"] = self.cache["len"].at[lane].set(plen)
        self._set_first_token(logits, lane)

    def _cache_lane_prefix(self, req: Request, lane: int) -> None:
        """Offer the freshly prefilled lane's prompt pages to the radix
        cache (the cache takes its own refs on pages it keeps)."""
        prompt = self._trunc_prompt(req)
        self.prefix_cache.insert(
            np.asarray(prompt), int(prompt.shape[0]),
            self._lane_pages[lane],
            allow_partial=self.cfg.kv_quant != "int8")

    def _prefill_into_lane(self, req: Request, lane: int) -> None:
        prompt = req.prompt
        # a fixed-lane cache cannot hold more than max_len - 1 prompt
        # positions and still decode: keep the TAIL of over-long prompts
        # (coherent positions/KV, llama.cpp-style truncation) instead of
        # recording a length the cache cannot back.
        limit = self.max_len - 1
        if prompt.shape[0] > limit:
            prompt = prompt[-limit:]
        plen = int(prompt.shape[0])
        self._len_host[lane] = plen
        bucket = _bucket_len(plen) if self.prefill_bucketing else plen
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = prompt
        with self.tracer.span("prefill.bucket",
                              track=self.lane_track(lane),
                              bucket=bucket, plen=plen):
            logits, kv = self._prefill(self.params, jnp.asarray(padded),
                                       jnp.asarray([plen - 1], jnp.int32))
            if kv is not None:
                if self.paged:
                    self._scatter_prompt_paged(kv, lane, plen)
                else:
                    self._scatter_prompt_dense(kv, lane, plen)
        if "ssm_h" in self.cache:
            # SSM state is rebuilt by streaming the prompt through the
            # decode path (exactly once, O(len) state updates).
            self._stream_ssm_prompt(prompt, lane)
            return
        self.cache["len"] = self.cache["len"].at[lane].set(plen)
        self._set_first_token(logits, lane)

    def _prompt_kv_views(self, kv, plen: int, smax: int):
        """Last ``min(plen, smax)`` prompt positions of the prefill KV,
        laid out at their ring slots (``slot = position mod smax``) and
        quantized when the cache is int8 (via ``quantize_kv_token``, the
        same per-(token, head) scales the decode write path uses).

        Returns (entries, take): ``entries`` maps cache key -> a
        (L, Hkv, take[, pad], ...) array in the cache's dtype.
        """
        from repro.models.attention import quantize_kv_token

        k, v = kv                       # (L, 1, Hkv, S_bucket, D)
        take = min(plen, smax)
        k = k[:, 0, :, plen - take:plen, :]
        v = v[:, 0, :, plen - take:plen, :]
        if take == smax:
            # window cache and the prompt filled (or wrapped) it: place
            # position p at slot p % smax, so the decode step's ring
            # write (same formula) evicts the true oldest position
            shift = plen % smax
            if shift:
                k = jnp.roll(k, shift, axis=2)
                v = jnp.roll(v, shift, axis=2)
        if self.cfg.kv_quant == "int8":
            kq, ks = quantize_kv_token(k)
            vq, vs = quantize_kv_token(v)
            return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}, take
        return {"k": k, "v": v}, take

    def _scatter_prompt_dense(self, kv, lane: int, plen: int) -> None:
        smax = self.cache["k"].shape[3]
        entries, take = self._prompt_kv_views(kv, plen, smax)
        for key, val in entries.items():
            self.cache[key] = jax.lax.dynamic_update_slice(
                self.cache[key], val[:, None].astype(self.cache[key].dtype),
                (0, lane, 0, 0, 0))

    def _scatter_prompt_paged(self, kv, lane: int, plen: int,
                              first_block: int = 0) -> None:
        """Write the prompt KV into the lane's mapped pages (one
        dynamic_update_slice per page -- pages are not contiguous in the
        pool, that is the point).  ``first_block`` skips blocks already
        backed by shared prefix pages: their bytes are the donor's, and
        writing them would corrupt every other lane mapping them."""
        ps = self.page_size
        entries, take = self._prompt_kv_views(kv, plen, ps * self._bt_width)
        n_pg = -(-take // ps)
        pad = n_pg * ps - take
        if pad:
            entries = {key: jnp.pad(val, ((0, 0), (0, 0), (0, pad), (0, 0)))
                       for key, val in entries.items()}
        key_map = {"k": "k_pages", "v": "v_pages",
                   "k_scale": "k_scale_pages", "v_scale": "v_scale_pages"}
        for i, page in enumerate(self._lane_pages[lane][:n_pg]):
            if i < first_block:
                continue
            for key, val in entries.items():
                pk = key_map[key]
                seg = val[:, None, :, i * ps:(i + 1) * ps]
                self.cache[pk] = jax.lax.dynamic_update_slice(
                    self.cache[pk], seg.astype(self.cache[pk].dtype),
                    (0, page, 0, 0, 0))
        s = self._sanitizer
        if s is not None:
            written = self._lane_pages[lane][first_block:n_pg]
            if written:
                s.record("write", lane=lane, pages=list(written),
                         kind="prefill")

    def _set_first_token(self, logits: jnp.ndarray, lane: int) -> None:
        key = jax.random.fold_in(self._rng_prefill, self._admit_count)
        self._admit_count += 1
        tok = sample_tokens(logits, key, self._temperature)[0]
        self._next_token = self._next_token.at[lane].set(tok)

    def _slice_lane_cache(self, lane: int) -> Dict[str, jnp.ndarray]:
        """One lane's view of the cache: per-lane state is sliced to
        batch 1; the shared page pool passes through whole (the lane's
        block-table row names its pages)."""
        out = {}
        for key, x in self.cache.items():
            if key in _POOL_KEYS:
                out[key] = x
            elif key in _LANE0_KEYS:
                out[key] = x[lane:lane + 1]
            else:
                out[key] = x[:, lane:lane + 1]
        return out

    def _merge_lane_cache(self, lane_cache: Dict[str, jnp.ndarray],
                          lane: int) -> None:
        for key, x in lane_cache.items():
            if key in _POOL_KEYS:
                self.cache[key] = x
            elif key in _LANE0_KEYS:
                self.cache[key] = self.cache[key].at[lane].set(x[0])
            else:
                self.cache[key] = jax.lax.dynamic_update_slice(
                    self.cache[key], x, (0, lane) + (0,) * (x.ndim - 2))

    def _ssm_prefill_scan(self, params, lane_cache, tokens, plen):
        """Prompt streaming as ONE ``lax.scan`` over a shape bucket.

        The recurrent families have no batched cache-build path, so the
        prompt must flow through the decode step; doing it eagerly cost
        one host dispatch per prompt token.  Here the padded bucket is
        scanned on device with *state masking*: a pad position computes
        a decode step but its per-lane state update (length, recurrent
        state, lane KV) is discarded, so the carry after the scan equals
        the eager per-token stream exactly, and the logits captured at
        ``plen - 1`` are the real last-token logits.  Shared page pools
        are deliberately NOT masked (a pool-wide select per position
        would stream the whole pool ``bucket`` times): a pad step writes
        its garbage token at the frozen slot ``plen`` -- exactly where
        the first real decode token writes next, and nothing surviving
        the mask reads it first.  One compile per bucket, one dispatch
        per prompt.
        """
        logits0 = jnp.zeros((1, self.cfg.padded_vocab), jnp.float32)

        def body(carry, inp):
            cache, logits = carry
            tok, idx = inp
            live = idx < plen
            new_logits, new_cache = self.model.decode_step(
                params, cache, tok[None])
            cache = {
                key: (new_cache[key] if key in _POOL_KEYS
                      else jax.tree_util.tree_map(
                          lambda new, old: jnp.where(live, new, old),
                          new_cache[key], cache[key]))
                for key in cache}
            logits = jnp.where(idx == plen - 1, new_logits, logits)
            return (cache, logits), None

        (lane_cache, logits), _ = jax.lax.scan(
            body, (lane_cache, logits0),
            (tokens, jnp.arange(tokens.shape[0], dtype=jnp.int32)))
        return logits, lane_cache

    def _stream_ssm_prompt(self, prompt: np.ndarray, lane: int) -> None:
        lane_cache = self._slice_lane_cache(lane)
        lane_cache["len"] = jnp.zeros((1,), jnp.int32)
        # a re-admitted lane must NOT inherit the previous request's
        # recurrent state (init_mamba2_state is all-zeros)
        for key in ("ssm_h", "ssm_conv"):
            if key in lane_cache:
                lane_cache[key] = jnp.zeros_like(lane_cache[key])
        plen = int(prompt.shape[0])
        bucket = _bucket_len(plen) if self.prefill_bucketing else plen
        padded = np.zeros((bucket,), np.int32)
        padded[:plen] = prompt
        logits, lane_cache = self._ssm_prefill(
            self.params, lane_cache, jnp.asarray(padded),
            jnp.asarray(plen, jnp.int32))
        self._merge_lane_cache(lane_cache, lane)
        self._set_first_token(logits, lane)
        s = self._sanitizer
        if s is not None and self.paged and self._lane_pages[lane]:
            # hybrid lanes stream the whole prompt through the decode
            # path; every mapped page is exclusively this lane's
            s.record("write", lane=lane,
                     pages=list(self._lane_pages[lane]), kind="prefill")

    # -- stepping ----------------------------------------------------------
    def _dispatch_size(self, n: Optional[int]) -> int:
        """Tokens per dispatch: dispatch_n, shrunk (to a power of two, to
        bound recompiles) when every live lane owes fewer tokens."""
        n = n or self.dispatch_n
        live = [i for i, r in enumerate(self.lane_req) if r is not None]
        max_rem = int(self._remaining_host[live].max()) if live else 0
        return min(n, _bucket_len(max(max_rem, 1), floor=1))

    def decode_n(self, n: Optional[int] = None) -> Dict[int, List[int]]:
        """Advance all live lanes up to ``n`` tokens in ONE dispatch.

        Returns {uid: [tokens]} for this block; requests that exhaust
        their budget (or the cache) are retired at the boundary.
        """
        live = [i for i, r in enumerate(self.lane_req) if r is not None]
        if not live:
            return {}
        n = self._dispatch_size(n)
        t_disp0 = self.clock() if self.slo is not None else 0.0
        with flight_guard(self.flight, op="decode.dispatch",
                          registry=self.registry), \
                self.tracer.span(
                    "decode.dispatch", track=self.name, n_steps=n,
                    n_live=len(live),
                    uids=tuple(self.lane_req[i].uid for i in live)):
            if self.paged:
                # map the pages this block can write into BEFORE the
                # jitted dispatch (the scan itself never touches the
                # allocator); the admission-time reservation makes this
                # infallible
                for lane in live:
                    steps = min(n, int(self._remaining_host[lane]))
                    self._map_pages(lane, self._pages_needed(
                        int(self._len_host[lane]) + steps + 1))
                s = self._sanitizer
                if s is not None:
                    for lane in live:
                        steps = min(n, int(self._remaining_host[lane]))
                        start = int(self._len_host[lane])
                        if self.cfg.sliding_window is not None:
                            # ring writes rotate within the fixed set
                            pages = list(self._lane_pages[lane])
                        else:
                            pages = self._lane_pages[lane][
                                start // self.page_size:
                                self._pages_needed(start + steps + 1)]
                        if pages:
                            s.record("write", lane=lane, pages=pages,
                                     kind="decode")
            (toks, valid, self._next_token, self.cache, self._remaining,
             self._tok_idx) = self._decode_n(
                self.params, self.cache, self._next_token,
                self._rng_decode, self._remaining, self._lane_seed,
                self._tok_idx, n_steps=n)
            self.stats["decode_dispatches"] += 1
            self.stats["decode_steps"] += n
            # one host transfer drains the whole block
            toks_h, valid_h, rem_h = jax.device_get(
                (toks, valid, self._remaining))
        self._remaining_host = np.asarray(rem_h, np.int64)
        slo = self.slo
        if slo is not None:
            now = self.clock()
            disp_s = now - t_disp0
        out: Dict[int, List[int]] = {}
        for lane in live:
            req = self.lane_req[lane]
            seq = [int(t) for t in toks_h[valid_h[:, lane], lane]]
            first = not req.generated and bool(seq)
            req.generated.extend(seq)
            out[req.uid] = seq
            self.stats["generated_tokens"] += len(seq)
            # the lane's device-side length advanced once per valid
            # sample (exhausted lanes freeze it), so the host mirror
            # tracks it without an extra transfer
            self._len_host[lane] += len(seq)
            if first:
                self.tracer.instant("first_token",
                                    track=self.lane_track(lane),
                                    uid=req.uid)
                if slo is not None:
                    t_admit = self._admit_t.pop(req.uid, None)
                    if t_admit is not None:
                        slo.monitor.observe_ttft(now - t_admit, t=now)
            if slo is not None and seq:
                slo.monitor.observe_tpot(disp_s / len(seq), t=now)
            if self._remaining_host[lane] <= 0:
                req.done = True
                self.tracer.instant("retire",
                                    track=self.lane_track(lane),
                                    uid=req.uid,
                                    gen=len(req.generated))
                self._release_lane(lane)
        if slo is not None:
            slo.step(now)
        if self._sanitizer is not None:
            # dispatch boundary: shadow state must equal the real pool
            with flight_guard(self.flight, op="sanitizer.crosscheck",
                              registry=self.registry):
                self._sanitizer.crosscheck(self.pool)
        return out

    def _release_lane(self, lane: int) -> None:
        """Return a lane to the DEAD state (retirement and eviction both
        end here): zero its cache length so the length-aware kernel pins
        a single key block instead of streaming the stale context, drop
        the lane's reference on its pages (a page another lane or the
        prefix cache still maps survives; exclusively-owned pages return
        to the free list), and point the dead block-table row at the
        scratch page -- its page ids may be re-issued to another lane,
        but the dead lane keeps stepping (and writing its frozen slot)
        until re-admission."""
        self.lane_req[lane] = None
        self.cache["len"] = self.cache["len"].at[lane].set(0)
        self._len_host[lane] = 0
        if self.paged:
            self.pool.free(self._lane_pages[lane], holder=lane)
            self.pool.unreserve(self._lane_reserved[lane])
            self._lane_pages[lane] = []
            self._lane_reserved[lane] = 0
            if "block_tables" in self.cache:
                self.cache["block_tables"] = (
                    self.cache["block_tables"].at[lane]
                    .set(self._scratch_page))

    # -- preemption: evict-and-replay checkpointing ------------------------
    def live_lanes(self) -> List[int]:
        return [i for i, r in enumerate(self.lane_req) if r is not None]

    def lane_context(self, lane: int) -> int:
        """Live context length of ``lane`` (host mirror, no sync)."""
        return int(self._len_host[lane])

    def evict(self, lane: int) -> LaneCheckpoint:
        """Checkpoint and release a live lane at a dispatch boundary.

        The checkpoint captures the request, its sampling identity
        (``lane_seed``, ``tok_idx``), the pre-sampled next token, and
        the lane's live KV pages gathered from the pool through its
        block table -- everything :meth:`restore` needs to resume the
        exact token stream, here or on another engine built with the
        same config / ``rng_seed`` / ``temperature``.  The lane's pages
        return to the pool immediately (that is the point: a page-
        exhausted board sheds the decode without losing its tokens).

        The scratch page is dead-lane plumbing, not request state: it is
        never captured, never freed, never migrated.

        Prefix-shared pages: the gather is a DEEP COPY through the block
        table, so a page this lane maps but does not exclusively own
        (refcount > 1: the radix cache or a sibling lane also holds it)
        is captured by value and never stolen -- releasing the lane
        merely drops its reference, the other holders keep the bytes,
        and :meth:`restore` re-anchors the checkpoint onto fresh
        exclusively-owned pages.  Cross-engine restore of a prefix-hit
        lane is pinned bit-exact by the prefix test tier.
        """
        invariant(self.paged, "evict/restore: paged engines only")
        req = self.lane_req[lane]
        invariant(req is not None, f"evict of idle lane {lane}",
                  lane=lane)
        with flight_guard(self.flight, op="preempt.evict",
                          registry=self.registry), \
                self.tracer.span("preempt.evict",
                                 track=self.lane_track(lane), uid=req.uid,
                                 n_pages=len(self._lane_pages[lane])):
            pages = list(self._lane_pages[lane])
            invariant(self._scratch_page not in pages,
                      "scratch page leaked into a live block table",
                      lane=lane)
            s = self._sanitizer
            if s is not None:
                s.record("capture", lane=lane, pages=pages)
            idx = jnp.asarray(pages, jnp.int32)
            kv = {key: jnp.take(self.cache[key], idx, axis=1)
                  for key in _POOL_KEYS if key in self.cache}
            ssm = {key: self.cache[key][:, lane]
                   for key in ("ssm_h", "ssm_conv") if key in self.cache}
            kv, ssm, nxt, seed, idx_t = jax.device_get(
                (kv, ssm, self._next_token[lane], self._lane_seed[lane],
                 self._tok_idx[lane]))
            ckpt = LaneCheckpoint(
                req=req, lane_seed=int(seed), tok_idx=int(idx_t),
                remaining=int(self._remaining_host[lane]),
                ctx_len=int(self._len_host[lane]), next_token=int(nxt),
                page_size=self.page_size,
                kv_pages={k: np.asarray(v) for k, v in kv.items()},
                ssm_state={k: np.asarray(v) for k, v in ssm.items()})
            # the evicted lane is DEAD: freeze its budget so a dispatch
            # that runs before re-admission samples only invalid tokens
            # for it
            self._remaining = self._remaining.at[lane].set(0)
            self._remaining_host[lane] = 0
            self._release_lane(lane)
            self.stats["preemptions"] += 1
        return ckpt

    def restore_pages(self, ckpt: LaneCheckpoint) -> int:
        """Pages :meth:`restore` will reserve for ``ckpt`` -- the
        checkpointed pages plus headroom for the remaining budget,
        clamped (like admission) to what the cache can back."""
        worst = min(ckpt.ctx_len + ckpt.remaining + 1, self.max_len)
        return max(self._pages_needed(worst), ckpt.n_pages)

    def can_restore(self, ckpt: LaneCheckpoint) -> bool:
        if not self.free_lanes():
            return False
        return self.restore_pages(ckpt) <= self.pool.available()

    def restore(self, ckpt: LaneCheckpoint) -> bool:
        """Re-admit a checkpointed request through the normal
        reserve/alloc route and scatter its pages into a fresh block
        table.  Returns False when no lane or pages are available (the
        caller retries after retirements, exactly like admission).

        Restoration does NOT consume an admission index: the lane
        inherits the checkpoint's ``lane_seed``/``tok_idx``, so the
        resumed RNG stream continues bit-identically, and the first
        resumed step consumes the checkpoint's pre-sampled token
        instead of re-sampling from a prefill.
        """
        invariant(self.paged, "evict/restore: paged engines only")
        invariant(ckpt.page_size == self.page_size,
                  "checkpoint page size does not match this engine",
                  ckpt_page_size=ckpt.page_size,
                  page_size=self.page_size)
        lanes = self.free_lanes()
        if not lanes:
            return False
        lane = lanes[0]
        need = self.restore_pages(ckpt)
        if not self.pool.reserve(need):
            if ckpt.uid not in self._blocked_uids:
                self._blocked_uids.add(ckpt.uid)
                self.stats["kv_admit_blocked"] += 1
            return False
        self._blocked_uids.discard(ckpt.uid)
        self._lane_reserved[lane] = need
        self._lane_pages[lane] = []
        restore_span = self.tracer.span(
            "preempt.restore", track=self.lane_track(lane),
            uid=ckpt.uid, n_pages=ckpt.n_pages)
        try:
            with restore_span:
                self._map_pages(lane, ckpt.n_pages)
                for i, page in enumerate(self._lane_pages[lane]):
                    for key, val in ckpt.kv_pages.items():
                        seg = jnp.asarray(val[:, i:i + 1])
                        self.cache[key] = jax.lax.dynamic_update_slice(
                            self.cache[key],
                            seg.astype(self.cache[key].dtype),
                            (0, page, 0, 0, 0))
                for key, val in ckpt.ssm_state.items():
                    self.cache[key] = self.cache[key].at[:, lane].set(
                        jnp.asarray(val))
                s = self._sanitizer
                if s is not None and self._lane_pages[lane]:
                    s.record("write", lane=lane,
                             pages=list(self._lane_pages[lane]),
                             kind="restore")
        except Exception:
            # scatter failure (e.g. a checkpoint whose payload does not
            # match this engine's cache layout): the reservation and any
            # already-mapped pages MUST return to the pool, or they leak
            # -- the lane looks free but its pages stay in-use forever
            self.pool.free(self._lane_pages[lane], holder=lane)
            self.pool.unreserve(self._lane_reserved[lane])
            self._lane_pages[lane] = []
            self._lane_reserved[lane] = 0
            self.cache["len"] = self.cache["len"].at[lane].set(0)
            if "block_tables" in self.cache:
                self.cache["block_tables"] = (
                    self.cache["block_tables"].at[lane]
                    .set(self._scratch_page))
            raise
        self.cache["len"] = self.cache["len"].at[lane].set(ckpt.ctx_len)
        self._len_host[lane] = ckpt.ctx_len
        self._lane_seed = self._lane_seed.at[lane].set(ckpt.lane_seed)
        self._tok_idx = self._tok_idx.at[lane].set(ckpt.tok_idx)
        self._next_token = self._next_token.at[lane].set(ckpt.next_token)
        self._remaining = self._remaining.at[lane].set(ckpt.remaining)
        self._remaining_host[lane] = ckpt.remaining
        self.lane_req[lane] = ckpt.req
        self.stats["restores"] += 1
        self.stats["pages_migrated"] += ckpt.n_pages
        return True

    def decode_step(self) -> Dict[int, int]:
        """Single-token compatibility wrapper; returns {uid: token}."""
        return {uid: seq[0] for uid, seq in self.decode_n(1).items() if seq}

    def _never_admissible(self, head: Request) -> AdmissionRejected:
        """Structured terminal refusal: the head request was refused with
        NOTHING in flight, so no retirement can ever free a lane or a
        page.  ``retry_after_s`` is None -- retrying cannot help."""
        self.stats["admit_rejected"] += 1
        return AdmissionRejected(
            uid=head.uid, reason="never_admissible", retry_after_s=None,
            need_pages=(self.admission_pages(head) if self.paged else None),
            pool_pages=(self.pool.n_pages if self.paged else None),
            n_lanes=self.n_lanes)

    def _shed_victim(self) -> Optional[int]:
        """Lane the degradation ladder evicts next: lowest request
        priority first, then largest live context (most pages back)."""
        live = self.live_lanes()
        if len(live) < 2:
            return None          # never shed the last live lane
        return min(live, key=lambda i: (self.lane_req[i].priority,
                                        -self.lane_context(i), i))

    def run(self, requests: List[Request],
            dispatch_n: Optional[int] = None) -> List[Request]:
        """Serve a workload to completion with continuous admission.

        Retirement rides the done-flags returned by the batched dispatch
        (no per-step completion scan over the request list).

        With a :class:`DegradationLadder` attached, sustained page
        pressure or repeated page-blocked admissions escalate load
        shedding: the dispatch knob shrinks, new admissions are deferred
        (backpressure), and at the top rung the lowest-priority lane is
        evicted to a checkpoint and re-admitted once pressure clears.

        Raises :class:`AdmissionRejected` (a ``RuntimeError``) when the
        head request can never be admitted and nothing is in flight.
        """
        ladder = self.ladder
        pending = list(requests)
        shed: deque = deque()        # evicted-by-ladder checkpoints
        while pending or shed or any(r is not None for r in self.lane_req):
            # ladder-evicted checkpoints re-enter first (their tokens
            # are paid for; finishing them frees pages fastest) -- but
            # not while the ladder is still at the evict rung with live
            # work, or restore/evict would thrash
            while shed and self.free_lanes():
                if ladder is not None and ladder.should_evict \
                        and self.live_lanes():
                    break
                if not self.restore(shed[0]):
                    break
                shed.popleft()
            while pending and self.free_lanes():
                if ladder is not None and ladder.refusing_admissions \
                        and (self.live_lanes() or shed):
                    # backpressure rung: finish in-flight work before
                    # taking on new requests
                    break
                if not self.admit(pending[0]):
                    # paged: a lane is free but the pages are not --
                    # wait for retirements to refill the pool (a single
                    # request always fits an empty engine, see __init__)
                    if ladder is not None and self.paged:
                        ladder.note_admission_blocked(pending[0].uid)
                        self.stats["degrade_transitions"] = \
                            len(ladder.transitions)
                    break
                if ladder is not None:
                    ladder.note_ok()
                pending.pop(0)
            if not any(r is not None for r in self.lane_req):
                if shed:
                    # every live lane was shed and none can restore:
                    # force the head checkpoint back in (it fit before,
                    # so it fits an empty engine)
                    restored = self.restore(shed[0])
                    invariant(restored, "shed checkpoint no longer "
                              "fits an empty engine",
                              uid=shed[0].uid)
                    shed.popleft()
                    continue
                raise self._never_admissible(pending[0])
            n = dispatch_n if dispatch_n is not None else self.dispatch_n
            if ladder is not None:
                n = ladder.dispatch_n(n)
            self.decode_n(n)
            if ladder is not None:
                if self.paged:
                    pool = self.pool
                    ladder.note_pressure(
                        (pool.n_pages - pool.available()) / pool.n_pages)
                else:
                    ladder.note_ok()
                self.stats["degrade_transitions"] = len(ladder.transitions)
                if ladder.should_evict and self.paged:
                    victim = self._shed_victim()
                    if victim is not None:
                        uid = self.lane_req[victim].uid
                        shed.append(self.evict(victim))
                        self.stats["degrade_sheds"] += 1
                        self.tracer.instant(
                            "degrade.shed", track=self.lane_track(victim),
                            uid=uid, level=ladder.level_name)
        return requests
