"""Multi-model serving on one HBM budget: weights page over the host link.

The paper's board is defined by two scarcities -- 8 GB of HBM2e and a
PCIe 1.1 x4 host link (~1 GB/s) -- so hosting *several* small models on
one CMP 170HX means weight bytes and KV pages compete for the same HBM
and every model swap crawls over the same bottleneck link the KV-page
migrations already cross.  This module is that economy made explicit:

* :class:`ModelPool` owns ONE byte budget per board.  Registered models
  (``ModelConfig`` + params, quantized or dense) are *resident* or
  *paged out*; ``load`` prices the weight transfer over the host link
  (the same :func:`~repro.serving.phase_model.link_transfer_seconds`
  model the fleet's KV migrations use) and ``unload`` is free (weights
  are clean -- the master copy lives in host RAM, nothing writes back).
* :class:`MultiModelServeEngine` hosts one paged
  :class:`~repro.serving.engine.ServeEngine` per resident model.  Every
  engine's KV :class:`~repro.serving.engine.PagePool` is carved from
  the shared budget: loading another model's weights ``shrink``\\ s the
  free pages of the least-recently-used residents, and unloading
  ``grow``\\ s them back toward the dense target -- weight residency
  and KV capacity visibly trade off, page by page.

Exactness contract (pinned in ``tests/test_modelpool.py``): a model's
token streams under multi-model serving are BIT-IDENTICAL to the same
requests served alone by a single-model ``ServeEngine`` with the same
config/seed/temperature.  This holds by construction: each inner engine
is a real ServeEngine (streams depend only on per-model admission order
and token index, never on pool size, lane neighbors, or dispatch
timing), requests are admitted per-model FIFO, and an unload preserves
the engine's admission counter so a reload continues the exact sampling
lineage.

Pinning: a model serving live lanes (or holding page reservations) is
never unloaded -- eviction only considers idle residents, LRU first.
Shrinking is always safe: it only retires pages that are free AND
unpromised, so in-flight lanes keep their reservation guarantee.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

from repro.analysis.invariants import invariant
from repro.core.device_profile import DeviceProfile, get_profile
from repro.models.common import ModelConfig
from repro.obs.metrics import MetricsRegistry, StatsView
from repro.obs.trace import SpanTracer
from repro.quant.quantize import QTensor
from repro.serving.engine import (Request, ServeEngine,
                                  prefix_sharing_supported)
from repro.serving.phase_model import link_transfer_seconds
from repro.serving.resilience import AdmissionRejected


def params_nbytes(params) -> int:
    """HBM bytes a parameter tree occupies (QTensor-aware)."""
    import jax

    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes()
        else:
            total += int(leaf.size) * leaf.dtype.itemsize
    return total


def kv_page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """HBM bytes one KV page of ``cfg`` holds (k + v, every layer and
    kv-head; int8 caches carry their f32 per-(token, head) scales) --
    the same per-row accounting the decode-bench byte model uses."""
    if cfg.attn_free:
        return 0
    if cfg.kv_quant == "int8":
        per_row = cfg.hd * 1 + 4          # int8 values + f32 scale
    else:
        per_row = cfg.hd * cfg.compute_dtype.itemsize
    return 2 * cfg.n_layers * cfg.n_kv_heads * per_row * page_size


@dataclasses.dataclass
class ModelEntry:
    """One registered model: identity, bytes, and the host-side
    continuation state that survives unload/reload round-trips."""

    model_id: str
    cfg: ModelConfig
    params: Any
    weight_bytes: int
    page_bytes: int
    spec: Any = None              # optional LLMSpec for fleet modeling
    loads: int = 0
    #: admission counter preserved across unload -> reload so the
    #: sampling lineage (admission index seeds each lane's key) of a
    #: reloaded model continues bit-identically
    admit_count: int = 0
    #: engine stats accumulated across residencies
    stats: Dict[str, int] = dataclasses.field(default_factory=dict)


class ModelPool:
    """Registry + HBM byte budget + host-link swap model for one board.

    Pure accounting -- it never touches jax.  ``load``/``unload`` keep
    the resident set and the LRU clock; the caller decides WHEN to swap
    and carries the returned transfer seconds into its own time model.

    Scope note: this registry prices REAL parameter trees
    (``params_nbytes``), which is what the execution-backed engine
    serves.  The fleet simulator's :class:`~repro.fleet.node.SimNode`
    keeps a deliberately separate, ``LLMSpec``-analytic residency model
    (sim nodes have no parameter trees and their eviction predicate is
    sim-slot-based, not engine-lane-based); the two share ONE transfer
    model, :func:`~repro.serving.phase_model.link_transfer_seconds`.
    """

    #: legacy stats key -> metric name (the modelpool telemetry schema)
    STATS_SCHEMA = {
        "model_swaps": "modelpool.swaps",
        "swap_bytes": "modelpool.swap_bytes",
        "swap_seconds": "modelpool.swap_seconds",
        "unloads": "modelpool.unloads",
    }

    def __init__(self, hbm_bytes: float, page_size: int = 16,
                 profile: Optional[DeviceProfile] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.hbm_bytes = int(hbm_bytes)
        self.page_size = int(page_size)
        self.profile = profile or get_profile("cmp-170hx-nofma")
        self.entries: Dict[str, ModelEntry] = {}
        self._resident: Dict[str, int] = {}      # model_id -> last-used tick
        self._kv_charge: Dict[str, int] = {}     # model_id -> charged KV bytes
        self._tick = 0
        self.registry = registry if registry is not None else MetricsRegistry()
        for metric_name in self.STATS_SCHEMA.values():
            self.registry.counter(metric_name)
        self._stats = StatsView(self.registry, dict(self.STATS_SCHEMA))
        self.registry.gauge("modelpool.bytes.used", fn=self.used_bytes,
                            help="HBM bytes held by weights + KV charges")
        self.registry.gauge("modelpool.bytes.free", fn=self.free_bytes,
                            help="HBM bytes left in the board budget")
        self.registry.gauge("modelpool.residents",
                            fn=lambda: len(self._resident),
                            help="models currently resident")

    @property
    def stats(self) -> StatsView:
        """Legacy stats mapping, backed by the metrics registry."""
        return self._stats

    @stats.setter
    def stats(self, values: Dict[str, Any]) -> None:
        for k, v in values.items():
            self._stats[k] = v

    # -- registry -------------------------------------------------------
    def register(self, model_id: str, cfg: ModelConfig, params,
                 spec=None) -> ModelEntry:
        invariant(model_id not in self.entries,
                  f"duplicate model {model_id}")
        entry = ModelEntry(model_id=model_id, cfg=cfg, params=params,
                           weight_bytes=params_nbytes(params),
                           page_bytes=kv_page_bytes(cfg, self.page_size),
                           spec=spec)
        invariant(entry.weight_bytes <= self.hbm_bytes, (
            f"{model_id} weights ({entry.weight_bytes}B) exceed the board "
            f"budget ({self.hbm_bytes}B)"),
            weight_bytes=entry.weight_bytes, hbm_bytes=self.hbm_bytes)
        self.entries[model_id] = entry
        return entry

    def __contains__(self, model_id: str) -> bool:
        return model_id in self.entries

    # -- residency ------------------------------------------------------
    def is_resident(self, model_id: str) -> bool:
        return model_id in self._resident

    def resident_lru(self) -> List[str]:
        """Resident model ids, least-recently-used first."""
        return sorted(self._resident, key=lambda m: (self._resident[m], m))

    def touch(self, model_id: str) -> None:
        self._tick += 1
        self._resident[model_id] = self._tick

    # -- budget ---------------------------------------------------------
    def weight_bytes_resident(self) -> int:
        return sum(self.entries[m].weight_bytes for m in self._resident)

    def kv_bytes_resident(self) -> int:
        return sum(self._kv_charge.values())

    def used_bytes(self) -> int:
        return self.weight_bytes_resident() + self.kv_bytes_resident()

    def free_bytes(self) -> int:
        return self.hbm_bytes - self.used_bytes()

    def charge_kv(self, model_id: str, nbytes: int) -> None:
        """Record the KV bytes ``model_id``'s page pool currently pins
        (active pages x page bytes; the engine calls this after every
        shrink/grow/build)."""
        invariant(self.is_resident(model_id),
                  f"kv charge for non-resident model {model_id}")
        self._kv_charge[model_id] = int(nbytes)

    # -- swaps ----------------------------------------------------------
    def load(self, model_id: str) -> float:
        """Mark ``model_id`` resident; returns the modeled seconds its
        quantized weight shards spend crossing the host link."""
        entry = self.entries[model_id]
        if self.is_resident(model_id):
            self.touch(model_id)
            return 0.0
        invariant(entry.weight_bytes <= self.free_bytes(), (
            f"load({model_id}): {entry.weight_bytes}B of weights do not "
            f"fit in {self.free_bytes()}B free -- evict or shrink first"),
            weight_bytes=entry.weight_bytes, free_bytes=self.free_bytes())
        self.touch(model_id)
        self._kv_charge[model_id] = 0
        entry.loads += 1
        seconds = link_transfer_seconds(self.profile, entry.weight_bytes)
        self.stats["model_swaps"] += 1
        self.stats["swap_bytes"] += entry.weight_bytes
        self.stats["swap_seconds"] += seconds
        return seconds

    def unload(self, model_id: str) -> float:
        """Drop ``model_id`` from residency.  Weights are read-only (the
        master copy lives in host RAM), so nothing writes back: the cost
        of an unload is paid later, by the reload."""
        invariant(self.is_resident(model_id),
                  f"unload of non-resident model {model_id}")
        invariant(self._kv_charge.get(model_id, 0) == 0, (
            f"unload({model_id}) with live KV charge -- release pages "
            "first"), kv_charge=self._kv_charge.get(model_id, 0))
        del self._resident[model_id]
        del self._kv_charge[model_id]
        self.stats["unloads"] += 1
        return 0.0


class MultiModelServeEngine:
    """Continuous batching over N models sharing one board's HBM.

    One inner paged :class:`ServeEngine` per resident model, all built
    with the same ``n_lanes``/``max_len``/``temperature``/``rng_seed``/
    ``dispatch_n``/``page_size`` -- so each model's streams match the
    single-model reference bit for bit.  Every inner engine's physical
    page array is allocated at the dense target (``n_lanes`` full
    contexts) and its PagePool is immediately ``shrink``-ed to what the
    byte budget affords; later loads shrink it further (free pages
    only), unloads ``grow`` it back.

    Admission is head-of-line FIFO over the submitted request list
    (which preserves per-model FIFO, the exactness requirement): the
    head request's model is made resident -- shrinking, then LRU-
    evicting idle models -- before its admission is attempted.
    """

    #: legacy stats key -> metric name (the multi-model telemetry schema)
    STATS_SCHEMA = {
        "model_swaps": "mm.weights.swaps",
        "swap_bytes": "mm.weights.swap_bytes",
        "swap_seconds": "mm.weights.swap_seconds",
        "weight_evictions": "mm.weights.evictions",
        "kv_pages_shrunk": "mm.kv.pages_shrunk",
        "kv_pages_grown": "mm.kv.pages_grown",
    }

    def __init__(self, pool: ModelPool, n_lanes: int = 2,
                 max_len: int = 64, temperature: float = 0.0,
                 rng_seed: int = 0, dispatch_n: int = 8,
                 prefill_bucketing: bool = True,
                 prefix_sharing: bool = False, sanitize: bool = False,
                 tracer: Optional[SpanTracer] = None,
                 registry: Optional[MetricsRegistry] = None,
                 name: str = "mm"):
        self.pool = pool
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.temperature = temperature
        self.rng_seed = rng_seed
        self.dispatch_n = dispatch_n
        self.prefill_bucketing = prefill_bucketing
        # per-model radix prompt caches: each inner engine gets its own
        # (prefixes never match across models), dropped whole when the
        # model's weights unload
        self.prefix_sharing = bool(prefix_sharing)
        # forwarded to every inner engine: each gets its own strict
        # PageSanitizer over its own PagePool
        self.sanitize = bool(sanitize)
        self.engines: Dict[str, ServeEngine] = {}
        # one registry for the whole board: the byte pool, this engine,
        # and every inner per-model ServeEngine (namespaced by model id)
        # publish into it
        self.name = name
        self.registry = registry if registry is not None else pool.registry
        self.tracer = tracer if tracer is not None else SpanTracer(
            enabled=False, registry=self.registry)
        for metric_name in self.STATS_SCHEMA.values():
            self.registry.counter(metric_name)
        self._stats = StatsView(self.registry, dict(self.STATS_SCHEMA))

    @property
    def stats(self) -> StatsView:
        """Legacy stats mapping, backed by the metrics registry."""
        return self._stats

    @stats.setter
    def stats(self, values: Dict[str, Any]) -> None:
        for k, v in values.items():
            self._stats[k] = v

    # -- geometry -------------------------------------------------------
    def _bt_width(self, cfg: ModelConfig) -> int:
        from repro.models.transformer import paged_capacity
        if cfg.attn_free:
            return 0
        return paged_capacity(self.max_len, cfg) // self.pool.page_size

    def _dense_pages(self, cfg: ModelConfig) -> int:
        return self.n_lanes * self._bt_width(cfg)

    def _charge(self, model_id: str) -> None:
        """Sync the pool's KV byte charge with the engine's ACTIVE pages
        (+1 for the scratch page, which is real HBM)."""
        eng = self.engines[model_id]
        entry = self.pool.entries[model_id]
        self.pool.charge_kv(model_id,
                            (eng.pool.n_active + 1) * entry.page_bytes)

    # -- residency ------------------------------------------------------
    @property
    def resident_models(self) -> List[str]:
        return list(self.engines)

    def live_models(self) -> List[str]:
        return [m for m, e in self.engines.items() if e.live_lanes()]

    def _pinned(self, model_id: str) -> bool:
        """A model serving live lanes is never unloaded."""
        eng = self.engines[model_id]
        return bool(eng.live_lanes())

    def _unload(self, model_id: str) -> None:
        eng = self.engines.pop(model_id)
        invariant(not eng.live_lanes(),
                  f"unload of live model {model_id}",
                  live_lanes=eng.live_lanes())
        if eng.prefix_cache is not None:
            # cache invalidation on weight unload: cached pages index
            # KV this model computed -- a reload gets a cold cache, and
            # the refs must drop NOW or the zero-KV-charge assert below
            # (and the byte budget) would see phantom in-use pages
            eng.prefix_cache.flush()
            eng.pool.check()
            invariant(eng.pool.n_in_use == 0,
                      f"unload of {model_id} with pages still referenced",
                      n_in_use=eng.pool.n_in_use)
        entry = self.pool.entries[model_id]
        # preserve the sampling lineage and accumulate stats so a
        # reload continues exactly where this residency stopped
        entry.admit_count = eng._admit_count
        for k, v in eng.stats.items():
            entry.stats[k] = entry.stats.get(k, 0) + v
        self.pool.charge_kv(model_id, 0)
        self.pool.unload(model_id)
        self.stats["weight_evictions"] += 1

    def _shrink_other(self, keep: str, need_bytes: int) -> None:
        """Retire free KV pages of other residents, LRU first, until
        ``need_bytes`` fit (or nothing shrinkable remains).  Every
        resident keeps a FLOOR of one full context (its ``bt_width``):
        below that a model could never admit another request, so the
        shrink would trade a visible page for a livelock."""
        for other in self.pool.resident_lru():
            if self.pool.free_bytes() >= need_bytes:
                return
            if other == keep or other not in self.engines:
                continue
            entry = self.pool.entries[other]
            if entry.page_bytes <= 0:
                continue
            lack = -(-(need_bytes - self.pool.free_bytes())
                     // entry.page_bytes)
            floor = self._bt_width(entry.cfg)
            oeng = self.engines[other]
            want = min(lack, max(oeng.pool.n_active - floor, 0))
            if oeng.prefix_cache is not None \
                    and oeng.pool.available() < want:
                # shrink only takes free unpromised pages; pages pinned
                # by the victim's prefix cache are reclaimable bytes --
                # drop cache entries (LRU) until the shrink can land
                oeng._trim_prefix_cache(want)
            shrunk = oeng.pool.shrink(want)
            if shrunk:
                self.stats["kv_pages_shrunk"] += shrunk
                self._charge(other)

    def _evict_idle(self, keep: str, need_bytes: int) -> None:
        """LRU-unload idle residents until ``need_bytes`` fit."""
        for other in self.pool.resident_lru():
            if self.pool.free_bytes() >= need_bytes:
                return
            if other == keep or other not in self.engines:
                continue
            if self._pinned(other):
                continue
            self._unload(other)

    def _rebalance(self) -> None:
        """Grow residents' page pools back toward the dense target,
        most-recently-used first, while the budget allows."""
        for mid in reversed(self.pool.resident_lru()):
            eng = self.engines.get(mid)
            entry = self.pool.entries[mid]
            if eng is None or entry.page_bytes <= 0:
                continue
            afford = self.pool.free_bytes() // entry.page_bytes
            grown = eng.pool.grow(min(eng.pool.n_disabled, max(afford, 0)))
            if grown:
                self.stats["kv_pages_grown"] += grown
                self._charge(mid)

    def ensure_resident(self, model_id: str) -> Optional[ServeEngine]:
        """Make ``model_id`` resident (shrinking, then LRU-evicting idle
        models for budget) and return its engine; ``None`` when pinned
        residents hold too much HBM right now -- the caller retries
        after retirements, exactly like page-blocked admission."""
        if model_id not in self.pool.entries:
            raise KeyError(f"model {model_id!r} is not registered")
        if model_id in self.engines:
            self.pool.touch(model_id)
            return self.engines[model_id]
        entry = self.pool.entries[model_id]
        bt = self._bt_width(entry.cfg)
        # minimum viable residency: weights + one full context of pages
        # + the scratch page (an engine below this could never admit)
        need = entry.weight_bytes + (bt + 1) * entry.page_bytes
        if self.pool.free_bytes() < need:
            self._shrink_other(model_id, need)
        if self.pool.free_bytes() < need:
            self._evict_idle(model_id, need)
        if self.pool.free_bytes() < need:
            return None
        with self.tracer.span("weights.swap", track=self.name,
                              model_id=model_id,
                              weight_bytes=entry.weight_bytes):
            seconds = self.pool.load(model_id)
            # the pool's counters are the single source of truth for
            # swap accounting; the engine's stats mirror them for
            # reporting
            for k in ("model_swaps", "swap_bytes", "swap_seconds"):
                self.stats[k] = self.pool.stats[k]
            dense = self._dense_pages(entry.cfg)
            if entry.page_bytes > 0:
                # load() already moved the weights into the resident
                # charge: what is free now is all KV headroom (minus the
                # scratch page)
                afford = self.pool.free_bytes() // entry.page_bytes - 1
                target = max(min(dense, afford), bt)
            else:
                target = dense
            eng = ServeEngine(entry.cfg, entry.params,
                              n_lanes=self.n_lanes, max_len=self.max_len,
                              temperature=self.temperature,
                              rng_seed=self.rng_seed,
                              dispatch_n=self.dispatch_n,
                              prefill_bucketing=self.prefill_bucketing,
                              paged=True, page_size=self.pool.page_size,
                              n_pages=dense if dense else None,
                              prefix_sharing=(
                                  self.prefix_sharing
                                  and prefix_sharing_supported(entry.cfg)),
                              sanitize=self.sanitize,
                              tracer=self.tracer, registry=self.registry,
                              name=model_id)
            # physical array at the dense target, pool shrunk to the
            # byte budget: later unloads can grow it back without
            # reallocating
            eng.pool.shrink(dense - target)
            # restore the sampling lineage of a previous residency so
            # the reloaded model's next admission continues the exact
            # stream
            eng._admit_count = entry.admit_count
            self.engines[model_id] = eng
            self._charge(model_id)
        self.tracer.instant("weights.swap.done", track=self.name,
                            model_id=model_id, link_seconds=seconds)
        return eng

    def load(self, model_id: str) -> bool:
        """Explicit load (no admission); True when resident after."""
        return self.ensure_resident(model_id) is not None

    def unload(self, model_id: str) -> bool:
        """Explicit unload; refused (False) while the model serves live
        lanes.  Freed bytes grow the remaining residents' page pools."""
        if model_id not in self.engines:
            return False
        if self._pinned(model_id):
            return False
        self._unload(model_id)
        self._rebalance()
        return True

    # -- serving --------------------------------------------------------
    def admit(self, req: Request) -> bool:
        eng = self.ensure_resident(req.model_id)
        if eng is None:
            return False
        return bool(eng.free_lanes()) and eng.admit(req)

    def decode_n(self, n: Optional[int] = None) -> Dict[str, Dict[int, List[int]]]:
        """Advance every resident model's live lanes one dispatch."""
        out: Dict[str, Dict[int, List[int]]] = {}
        for mid, eng in self.engines.items():
            if eng.live_lanes():
                out[mid] = eng.decode_n(n)
        return out

    def run(self, requests: Sequence[Request],
            dispatch_n: Optional[int] = None) -> List[Request]:
        """Serve a multi-model workload to completion.

        Head-of-line FIFO admission (preserves per-model order, the
        exactness contract); raises instead of livelocking when the
        head request can never be admitted and nothing is in flight.
        """
        for r in requests:
            invariant(r.model_id in self.pool.entries, (
                f"request uid={r.uid} names unregistered model "
                f"{r.model_id!r}"), uid=r.uid, model_id=r.model_id)
        pending: Deque[Request] = deque(requests)
        while pending or self.live_models():
            while pending and self.admit(pending[0]):
                pending.popleft()
            if not self.live_models():
                head = pending[0]
                raise AdmissionRejected(
                    uid=head.uid, reason="never_admissible",
                    retry_after_s=None,
                    message=(
                        f"request uid={head.uid} (model "
                        f"{head.model_id!r}) can never be admitted: "
                        f"hbm={self.pool.hbm_bytes}B, "
                        f"resident={self.resident_models} and nothing "
                        "is in flight to retire"))
            self.decode_n(dispatch_n)
        return list(requests)

    # -- reporting ------------------------------------------------------
    def model_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-model engine stats, merged across residencies."""
        out: Dict[str, Dict[str, int]] = {}
        for mid, entry in self.pool.entries.items():
            merged = dict(entry.stats)
            eng = self.engines.get(mid)
            if eng is not None:
                for k, v in eng.stats.items():
                    merged[k] = merged.get(k, 0) + v
            out[mid] = merged
        return out

    def kv_pages_active(self) -> Dict[str, int]:
        """Active (non-disabled) KV pages per resident model -- the
        visible side of the weights-vs-pages trade-off."""
        return {mid: eng.pool.n_active
                for mid, eng in self.engines.items()}
