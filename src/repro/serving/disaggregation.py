"""Heterogeneous prefill/decode disaggregation (paper SS6.2, operationalized).

The paper's recommendation -- use bandwidth-rich compute-poor boards for
the memory-bound phase -- becomes a fleet scheduler: given device pools
(e.g. a few A100s + many reclaimed CMP 170HXs), assign the compute-bound
prefill phase and the bandwidth-bound decode phase to the pools that
maximize served tokens/s (or minimize $/Mtok), with the KV handoff cost
modeled over the host interconnect.

This is an analytic *steady-state* scheduler; the shared per-phase
throughput/handoff/cost primitives live in `repro.serving.phase_model`
so the trace-driven simulator (`repro.fleet`) uses the exact same model
with queueing dynamics on top.  The execution half is
`repro.serving.engine` on each pool.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Optional, Tuple

from repro.core.device_profile import get_profile
from repro.core.perf_model import LLMSpec, QWEN25_1P5B
from repro.serving.phase_model import (Workload, capex_usd_per_hour,
                                       effective_prefill_tps,
                                       energy_usd_per_hour, phase_tps)

__all__ = ["Workload", "PoolAssignment", "FleetPlan", "plan_fleet",
           "homogeneous_baseline"]


@dataclasses.dataclass(frozen=True)
class PoolAssignment:
    profile: str
    count: int
    role: str                 # "prefill" | "decode" | "both"
    phase_tokens_per_s: float


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    assignments: Tuple[PoolAssignment, ...]
    prefill_tps: float
    decode_tps: float
    requests_per_s: float
    watts: float
    usd_per_hour: float
    usd_per_mtok: float


def plan_fleet(pools: Mapping[str, int], wl: Workload = Workload(),
               spec: LLMSpec = QWEN25_1P5B,
               power_usd_per_kwh: float = 0.10,
               amortization_years: float = 3.0) -> FleetPlan:
    """Choose per-pool roles maximizing sustained requests/s.

    Enumerates role assignments (each pool: prefill / decode / both) --
    the pool count is tiny so brute force is exact.
    """
    names = list(pools)
    best: Optional[FleetPlan] = None
    for roles in itertools.product(("prefill", "decode", "both"),
                                   repeat=len(names)):
        pre_tps = dec_tps = watts = usd_hour = 0.0
        assignments = []
        for name, role in zip(names, roles):
            prof = get_profile(name)
            n = pools[name]
            # a "prefill" board loses the KV handoff time per request
            eff_p, p_w = effective_prefill_tps(prof, wl, spec)
            d_tps, d_w = phase_tps(prof, wl, "decode", spec)
            if role == "prefill":
                pre_tps += n * eff_p
                watts += n * p_w
            elif role == "decode":
                dec_tps += n * d_tps
                watts += n * d_w
            else:  # both: split time between phases optimally (50/50 seed);
                # decode is colocated, the KV never leaves HBM -> no
                # handoff derating (same model as the simulator's
                # local-decode path)
                raw_p, _ = phase_tps(prof, wl, "prefill", spec)
                pre_tps += n * raw_p * 0.5
                dec_tps += n * d_tps * 0.5
                watts += n * (p_w + d_w) / 2
            usd_hour += n * capex_usd_per_hour(prof, amortization_years)
            assignments.append(PoolAssignment(
                profile=name, count=n, role=role,
                phase_tokens_per_s=eff_p if role == "prefill" else d_tps))
        usd_hour += energy_usd_per_hour(watts, power_usd_per_kwh)
        # steady state: requests/s limited by the slower phase
        req_s = min(pre_tps / max(wl.prompt_len, 1),
                    dec_tps / max(wl.gen_len, 1))
        if req_s <= 0:
            continue
        gen_tok_s = req_s * wl.gen_len
        plan = FleetPlan(
            assignments=tuple(assignments), prefill_tps=pre_tps,
            decode_tps=dec_tps, requests_per_s=req_s, watts=watts,
            usd_per_hour=usd_hour,
            usd_per_mtok=usd_hour / max(gen_tok_s * 3600 / 1e6, 1e-9))
        if best is None or plan.requests_per_s > best.requests_per_s:
            best = plan
    assert best is not None
    return best


def homogeneous_baseline(profile_name: str, count: int,
                         wl: Workload = Workload(),
                         spec: LLMSpec = QWEN25_1P5B) -> FleetPlan:
    """All boards run both phases -- the non-disaggregated reference."""
    return plan_fleet({profile_name: count}, wl, spec)
