"""Shared phase-throughput model for fleet planning and simulation.

Both the *static* steady-state planner (`repro.serving.disaggregation`)
and the *dynamic* trace-driven simulator (`repro.fleet`) need the same
primitives: what a device pool sustains in each serving phase, what the
prefill->decode KV handoff costs over the board's host link, and how a
board's price amortizes into $/hour.  Keeping them here guarantees the
planner and the simulator agree in steady state (tested in
``tests/test_fleet_sim.py``) -- the simulator adds queueing dynamics on
top of this model, it does not fork it.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.device_profile import DeviceProfile
from repro.core.energy import capex_usd_per_hour, energy_usd_per_hour
from repro.core.perf_model import InferencePerfModel, LLMSpec, QWEN25_1P5B

__all__ = ["Workload", "phase_tps", "kv_handoff_seconds",
           "link_transfer_seconds", "effective_prefill_tps",
           "capex_usd_per_hour", "energy_usd_per_hour"]


@dataclasses.dataclass(frozen=True)
class Workload:
    """A serving workload cell: prompt/gen lengths and weight format."""

    prompt_len: int = 512
    gen_len: int = 128
    fmt: str = "q8_0"


def phase_tps(profile: DeviceProfile, wl: Workload, phase: str,
              spec: LLMSpec = QWEN25_1P5B) -> Tuple[float, float]:
    """(tokens/s, watts) of one board running ``phase`` on ``wl``.

    Decode is evaluated at the mid-generation context
    (``prompt + gen/2``), matching the planner's steady-state view.
    """
    m = InferencePerfModel(profile, spec)
    est = (m.prefill(wl.fmt, wl.prompt_len) if phase == "prefill"
           else m.decode(wl.fmt, wl.prompt_len + wl.gen_len // 2))
    return est.tokens_per_s, est.watts


def link_transfer_seconds(profile: DeviceProfile, nbytes: float,
                          peer: DeviceProfile | None = None) -> float:
    """Seconds to move ``nbytes`` over the board's host link,
    bottlenecked by the slower endpoint when ``peer`` is given.

    This is the ONE transfer model every byte crossing a board boundary
    goes through -- prefill KV handoffs, preemption page migrations,
    and multi-model weight swaps all price against the same PCIe 1.1 x4
    (~1 GB/s) constraint on the CMP 170HX.
    """
    gbps = profile.total_interconnect_gbps()
    if peer is not None:
        gbps = min(gbps, peer.total_interconnect_gbps())
    return nbytes / (gbps * 1e9)


def kv_handoff_seconds(profile: DeviceProfile, prompt_len: int,
                       spec: LLMSpec = QWEN25_1P5B,
                       peer: DeviceProfile | None = None) -> float:
    """Prefill->decode KV transfer time over the host link.

    The transfer is bottlenecked by the slower endpoint when ``peer``
    (the decode-side board) is given -- for the CMP 170HX the PCIe 1.1
    x4 link (~1 GB/s) dominates either way.
    """
    return link_transfer_seconds(
        profile, spec.kv_bytes_per_token() * prompt_len, peer=peer)


def effective_prefill_tps(profile: DeviceProfile, wl: Workload,
                          spec: LLMSpec = QWEN25_1P5B) -> Tuple[float, float]:
    """Prefill tokens/s net of the per-request KV handoff, plus watts.

    A prefill board spends ``prompt/tps + handoff`` per request: the KV
    lives in its HBM until shipped, so the handoff is charged to the
    board's occupancy.  Equivalent to a throughput derating of
    ``1 / (1 + handoff * tps / prompt)``.
    """
    p_tps, p_w = phase_tps(profile, wl, "prefill", spec)
    handoff = kv_handoff_seconds(profile, wl.prompt_len, spec)
    return p_tps / (1.0 + handoff * p_tps / max(wl.prompt_len, 1)), p_w


