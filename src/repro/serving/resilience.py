"""Request-layer resilience: retry/hedging policy, structured admission
backpressure, and the engine-side graceful-degradation ladder.

Salvaged mining boards fail in mundane ways -- a board drops off the
bus, the PCIe-1.1-x4 host link flaps, HBM pressure spikes under a burst.
This module holds the pieces that are shared between the fleet simulator
(`repro.fleet.sim` / `repro.fleet.faults`) and the real engine replay
(`repro.fleet.execution`, `repro.serving.engine`):

* :class:`RetryPolicy` -- deadline + capped exponential backoff + max
  attempts, with optional tail-latency hedging (launch a duplicate after
  ``hedge_after_s`` of queueing; first to start wins, the loser is
  cancelled).
* :class:`AdmissionRejected` -- structured replacement for the bare
  ``RuntimeError`` the engine used to raise when the head request could
  never be admitted.  It still subclasses ``RuntimeError`` (and keeps
  the "can never be admitted" phrase) so existing ``except`` clauses and
  test matches keep working; new callers read ``reason`` /
  ``retry_after_s`` instead of parsing the message.
* :class:`DegradationLadder` -- under sustained page pressure or
  repeated admission failure the engine sheds load in a FIXED order:
  shrink the dispatch (batch) knob, then refuse new admissions with a
  Retry-After hint instead of livelocking, then evict-and-checkpoint the
  lowest-priority lanes.  Every transition is emitted as a
  ``repro.obs`` event and counted under ``engine.degrade.*``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.obs import events as obs_events

__all__ = [
    "AdmissionRejected",
    "DegradationLadder",
    "RetryPolicy",
    "DEGRADE_LEVELS",
]


# ----------------------------------------------------------------------
# retry / hedging policy
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with capped exponential backoff and optional hedging.

    ``attempt`` is 1-based: attempt 1 is the first RETRY (the initial
    try is attempt 0 and always allowed).  ``backoff_s(1)`` is
    ``base_backoff_s``; each further attempt doubles it up to
    ``backoff_cap_s``.  A request whose total sojourn exceeds
    ``deadline_s`` is not retried again (it is reported lost).

    ``hedge_after_s`` enables tail-latency hedging: a request still
    QUEUED (prefill not started) after this long gets a duplicate
    launched elsewhere; whichever copy starts first wins and the loser
    is cancelled before it consumes compute.
    """

    max_attempts: int = 3
    base_backoff_s: float = 0.05
    backoff_cap_s: float = 1.0
    deadline_s: Optional[float] = None
    hedge_after_s: Optional[float] = None

    def backoff_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based)."""
        return float(min(self.base_backoff_s * (2.0 ** max(attempt - 1, 0)),
                         self.backoff_cap_s))

    def allows(self, attempt: int, waited_s: float) -> bool:
        """May retry ``attempt`` fire, given the request has already been
        in the system for ``waited_s``?"""
        if attempt > self.max_attempts:
            return False
        if self.deadline_s is not None and waited_s >= self.deadline_s:
            return False
        return True


# ----------------------------------------------------------------------
# structured admission backpressure
# ----------------------------------------------------------------------

class AdmissionRejected(RuntimeError):
    """The engine refuses (or can never grant) an admission.

    Subclasses ``RuntimeError`` and keeps the historical "can never be
    admitted" phrase in the terminal case, so pre-existing
    ``except RuntimeError`` / ``pytest.raises(..., match=...)`` call
    sites are unaffected.  Structured fields:

    * ``uid`` -- the refused request;
    * ``reason`` -- ``"never_admissible"`` (the request exceeds what the
      engine can EVER back; retrying is pointless) or ``"backpressure"``
      (the engine is shedding load; retry after ``retry_after_s``);
    * ``retry_after_s`` -- Retry-After-style hint, ``None`` when
      retrying cannot help;
    * ``need_pages`` / ``pool_pages`` -- the page arithmetic behind the
      refusal (``None`` for dense engines).
    """

    def __init__(self, uid: int, reason: str,
                 retry_after_s: Optional[float] = None,
                 need_pages: Optional[int] = None,
                 pool_pages: Optional[int] = None,
                 n_lanes: Optional[int] = None,
                 message: Optional[str] = None):
        if message is None:
            if reason == "never_admissible":
                detail = (f"need={need_pages} pages of {pool_pages}"
                          if need_pages is not None else "dense")
                message = (f"request uid={uid} can never be admitted "
                           f"(n_lanes={n_lanes}, {detail}) and no request "
                           f"is in flight to retire")
            else:
                message = (f"request uid={uid} refused: engine under "
                           f"backpressure, retry after {retry_after_s}s")
        super().__init__(message)
        self.uid = uid
        self.reason = reason
        self.retry_after_s = retry_after_s
        self.need_pages = need_pages
        self.pool_pages = pool_pages
        self.n_lanes = n_lanes


# ----------------------------------------------------------------------
# graceful degradation ladder
# ----------------------------------------------------------------------

#: ladder rungs, in escalation order (index == level)
DEGRADE_LEVELS = ("normal", "shed_batch", "backpressure", "evict")


class DegradationLadder:
    """Engine-side load shedding, escalated one rung at a time.

    Levels (``DEGRADE_LEVELS``):

    0. ``normal`` -- no intervention.
    1. ``shed_batch`` -- shrink the dispatch knob (halved per level) so
       page growth per dispatch drops and retirements come sooner.
    2. ``backpressure`` -- stop admitting NEW requests while anything is
       in flight; callers get a Retry-After hint instead of a livelock.
    3. ``evict`` -- evict-and-checkpoint the lowest-priority live lane;
       the checkpoint re-enters admission when pressure clears.

    Escalation: ``trip_after`` consecutive pressure signals (page
    occupancy >= ``page_pressure`` or a page-blocked admission) bump the
    level.  De-escalation: ``cooldown`` consecutive clear signals drop
    one rung.  Transitions are emitted as ``degrade.transition`` obs
    events; the engine counts them under ``engine.degrade.*``.
    """

    def __init__(self, page_pressure: float = 0.92, trip_after: int = 2,
                 cooldown: int = 8, min_dispatch_n: int = 1,
                 name: str = "engine"):
        assert 0.0 < page_pressure <= 1.0
        self.page_pressure = float(page_pressure)
        self.trip_after = max(1, int(trip_after))
        self.cooldown = max(1, int(cooldown))
        self.min_dispatch_n = max(1, int(min_dispatch_n))
        self.name = name
        self.level = 0
        self._strikes = 0
        self._clear = 0
        #: transition log, newest last: (from_level, to_level, reason)
        self.transitions: List[tuple] = []

    # -- signals --------------------------------------------------------
    def note_pressure(self, occupancy: float) -> None:
        """Feed one page-occupancy sample (0..1), typically once per
        dispatch boundary."""
        if occupancy >= self.page_pressure:
            self._strike(f"page_pressure={occupancy:.2f}")
        else:
            self._relax()

    def note_admission_blocked(self, uid: int) -> None:
        """An admission was refused for pages while lanes were free."""
        self._strike(f"admission_blocked uid={uid}")

    def note_ok(self) -> None:
        """One clear signal (admission succeeded / pressure is low)."""
        self._relax()

    def escalate(self, reason: str) -> bool:
        """Force one rung UP (an external controller's call -- e.g. the
        SLO burn-rate monitor paging on latency, not page pressure).
        Bypasses the strike counter; returns True if the level moved."""
        if self.level >= 3:
            return False
        self._move(self.level + 1, reason)
        self._strikes = 0
        return True

    def deescalate(self, reason: str) -> bool:
        """Force one rung DOWN (external controller's all-clear).
        Bypasses the cooldown counter; returns True if the level
        moved."""
        if self.level <= 0:
            return False
        self._move(self.level - 1, reason)
        self._clear = 0
        return True

    # -- queries --------------------------------------------------------
    @property
    def level_name(self) -> str:
        return DEGRADE_LEVELS[self.level]

    def dispatch_n(self, base: int) -> int:
        """Dispatch size under the current level (halved per rung)."""
        return max(self.min_dispatch_n, base >> self.level)

    @property
    def refusing_admissions(self) -> bool:
        return self.level >= 2

    @property
    def should_evict(self) -> bool:
        return self.level >= 3

    def retry_after_s(self, base: float = 0.05) -> float:
        """Retry-After hint: grows with the ladder level."""
        return float(base * (2.0 ** max(self.level - 1, 0)))

    # -- internals ------------------------------------------------------
    def _strike(self, reason: str) -> None:
        self._clear = 0
        self._strikes += 1
        if self._strikes >= self.trip_after and self.level < 3:
            self._move(self.level + 1, reason)
            self._strikes = 0

    def _relax(self) -> None:
        self._strikes = 0
        if self.level == 0:
            return
        self._clear += 1
        if self._clear >= self.cooldown:
            self._move(self.level - 1, "cooldown")
            self._clear = 0

    def _move(self, new_level: int, reason: str) -> None:
        old = self.level
        self.level = new_level
        self.transitions.append((old, new_level, reason))
        obs_events.emit("degrade.transition", engine=self.name,
                        from_level=DEGRADE_LEVELS[old],
                        to_level=DEGRADE_LEVELS[new_level], reason=reason)
