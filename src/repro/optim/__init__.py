from repro.optim.adamw import (AdamWConfig, AdamWState, adamw_update,
                               global_norm, init_adamw, lr_schedule)
from repro.optim.compression import compress_roundtrip_error, compressed_psum

__all__ = ["AdamWConfig", "AdamWState", "adamw_update", "global_norm",
           "init_adamw", "lr_schedule", "compress_roundtrip_error",
           "compressed_psum"]
