"""int8 error-feedback gradient compression for the cross-pod axis.

The multi-pod mesh's `pod` axis crosses the slowest links (DCN between
pods); this module provides a compressed all-reduce for exactly that
axis: per-chunk absmax int8 quantization, int32-accumulated psum, f32
dequantize, with an error-feedback residual carried between steps so the
compression bias vanishes over time (1-bit-Adam-family result).

Usage (inside shard_map over the pod axis, or standalone in tests):

    g_hat, resid = compressed_psum(g + resid_prev, axis="pod")
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

CHUNK = 2048


def _quantize_chunks(x: jnp.ndarray, chunk: int):
    n = x.size
    pad = (-n) % chunk
    flat = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, chunk)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale, n, pad


def compressed_psum(x: jnp.ndarray, axis: str = "pod",
                    chunk: int = CHUNK) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8 all-reduce over a named axis; returns (mean, residual error).

    Must run inside shard_map/pmap with ``axis`` bound.  Traffic is
    ~4x smaller than f32 psum (int8 payload + one f32 scale / 2048).
    """
    q, scale, n, pad = _quantize_chunks(x.astype(jnp.float32), chunk)
    # each participant contributes its locally-quantized grads; the sum
    # happens in f32 after dequantize (scales differ per participant, so
    # dequant-then-psum: payload on the wire is the int8 tensor + scales).
    local = q.astype(jnp.float32) * scale
    total = jax.lax.psum(local, axis)
    size = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    mean = (total / size).reshape(-1)[:n].reshape(x.shape)
    resid = x.astype(jnp.float32) - (local.reshape(-1)[:n].reshape(x.shape))
    return mean.astype(x.dtype), resid.astype(x.dtype)


def compress_roundtrip_error(x: jnp.ndarray, chunk: int = CHUNK) -> float:
    """Relative RMS error of one quantize/dequantize pass (tests)."""
    q, scale, n, pad = _quantize_chunks(x.astype(jnp.float32), chunk)
    back = (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(x.shape)
    num = jnp.sqrt(jnp.mean((x - back) ** 2))
    den = jnp.sqrt(jnp.mean(x ** 2)) + 1e-12
    return float(num / den)
