"""Sharded AdamW with decoupled weight decay and global-norm clipping.

Optimizer state mirrors the parameter tree, so GSPMD shards it with the
same FSDP(+TP) specs as the parameters -- ZeRO-3-equivalent memory
(params f32 + 2 moments, all fully sharded).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # moment storage: "f32" | "bf16" | "int8".  "int8" = blockwise
    # int8 momentum + bf16 variance: linear int8 cannot span the second
    # moment's dynamic range (tiny nu quantizes to 0 and updates
    # explode), while bf16's 8-bit exponent holds it -- 4+1+2 B/param,
    # what lets the 480B MoE's optimizer state fit the mesh (the
    # paper's C4 applied to training state).
    moment_dtype: str = "f32"


@dataclasses.dataclass
class Q8Moment:
    """Row-wise int8-encoded optimizer momentum (8-bit Adam storage).

    ``q`` keeps the parameter's shape (so it inherits the parameter's
    FSDP/TP sharding with no reshapes -- a flat layout would force
    unshardable reshapes and full gathers in the update); ``scale`` is
    one f32 absmax per last-axis row.  No static metadata: per-layer
    scan slices must keep an identical treedef.
    """

    q: jnp.ndarray          # int8, same shape as the parameter
    scale: jnp.ndarray      # f32, shape param.shape[:-1] + (1,)


jax.tree_util.register_pytree_with_keys(
    Q8Moment,
    lambda m: ((("q", m.q), ("scale", m.scale)), None),
    lambda _, children: Q8Moment(q=children[0], scale=children[1]),
)


def _q8_store(x: jnp.ndarray) -> Q8Moment:
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return Q8Moment(q=q, scale=scale.astype(jnp.float32))


def _q8_load(st: Q8Moment) -> jnp.ndarray:
    return st.q.astype(jnp.float32) * st.scale


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init_adamw(params, moment_dtype: str = "f32") -> AdamWState:
    if moment_dtype == "int8":
        mu = jax.tree_util.tree_map(
            lambda x: _q8_store(jnp.zeros(x.shape, jnp.float32)), params)
        nu = jax.tree_util.tree_map(
            lambda x: jnp.zeros_like(x, dtype=jnp.bfloat16), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)
    dt = jnp.bfloat16 if moment_dtype == "bf16" else jnp.float32
    z = lambda t: jax.tree_util.tree_map(  # noqa: E731
        lambda x: jnp.zeros_like(x, dtype=dt), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=z(params),
                      nu=z(params))


def lr_schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


_NO_DECAY = ("scale", "bias", "a_log", "dt_bias", "d_skip", "norm_scale",
             "conv_b", "bq", "bk", "bv", "b1", "b2")


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step. Returns (new_params, new_state, metrics).

    With ``moment_dtype="int8"`` the moments are dequantized, updated in
    f32, and re-quantized blockwise each step (8-bit Adam).
    """
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    q8 = cfg.moment_dtype == "int8"

    def leaf(path, p, g, mu, nu):
        name = ""
        for pp in path:
            if hasattr(pp, "key"):
                name = str(pp.key)
        decay = (cfg.weight_decay
                 if name not in _NO_DECAY and p.ndim >= 2 else 0.0)

        def core(p_i, g_i, mu_i, nu_i):
            g_f = g_i.astype(jnp.float32) * clip
            mu_f = _q8_load(mu_i) if q8 else mu_i.astype(jnp.float32)
            nu_f = nu_i.astype(jnp.float32)
            mu_f = b1 * mu_f + (1 - b1) * g_f
            nu_f = b2 * nu_f + (1 - b2) * g_f * g_f
            upd = (mu_f / c1) / (jnp.sqrt(nu_f / c2) + cfg.eps)
            if decay:
                upd = upd + decay * p_i.astype(jnp.float32)
            new_p = (p_i.astype(jnp.float32) - lr * upd).astype(p_i.dtype)
            if q8:
                return new_p, _q8_store(mu_f), nu_f.astype(jnp.bfloat16)
            mdt = (jnp.bfloat16 if cfg.moment_dtype == "bf16"
                   else jnp.float32)
            return new_p, mu_f.astype(mdt), nu_f.astype(mdt)

        # stacked-layer tensors: apply the elementwise update one layer
        # at a time (layer_scan) -- the f32 intermediate chain then peaks
        # at 1/L of the tensor instead of several full copies (what
        # keeps the 480B MoE optimizer step inside HBM).
        if p.ndim >= 3 and p.size > (1 << 24):
            from repro.models.common import layer_scan

            def body(carry, xs):
                return carry, core(*xs)

            _, out = layer_scan(body, 0, (p, g, mu, nu))
            return out
        return core(p, g, mu, nu)

    out = jax.tree_util.tree_map_with_path(
        lambda path, p, g, mu, nu: leaf(path, p, g, mu, nu),
        params, grads, state.mu, state.nu,
        is_leaf=(lambda t: isinstance(t, Q8Moment)) if q8 else None)
    # unzip the 3-tuples
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics
