"""Training step: remat + microbatched gradient accumulation + AdamW.

``make_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
function suitable for ``jax.jit`` with FSDP/TP shardings.  Gradient
accumulation splits the per-device batch into microbatches with a
``lax.scan`` (compute of microbatch i+1 overlaps the reduction of i via
XLA's latency-hiding scheduler -- the collective/compute overlap knob).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import Model, build_model
from repro.optim.adamw import (AdamWConfig, AdamWState, adamw_update,
                               init_adamw)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    remat: bool = True
    microbatches: int = 1


def init_train_state(model: Model, rng,
                     moment_dtype: str = "f32") -> TrainState:
    params = model.init(rng)
    return TrainState(params=params,
                      opt=init_adamw(params, moment_dtype))


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    model = build_model(cfg)

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=tcfg.remat)

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + l,
                    jax.tree_util.tree_map(jnp.add, grad_acc, g)), None

        n = tcfg.microbatches
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)
        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zero), mbs)
        scale = 1.0 / n
        return loss * scale, jax.tree_util.tree_map(
            lambda g: g * scale, grads)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        loss, grads = grads_of(state.params, batch)
        params, opt, metrics = adamw_update(tcfg.optimizer, state.params,
                                            grads, state.opt)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=params, opt=opt), metrics

    return train_step
