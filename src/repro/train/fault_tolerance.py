"""Fault tolerance: resumable loop, straggler detection, elastic re-mesh.

Three mechanisms for the 1000+-node posture:

* **Checkpoint/restart** -- ``run_resumable`` wires the async
  checkpointer into the training loop and restarts from the last
  committed step after a (simulated or real) failure; data determinism
  (counter-based PRNG keyed by step) makes restarts bit-stable.
* **Straggler detection** -- :class:`StragglerMonitor` keeps a per-host
  EWMA of step times and flags hosts slower than ``threshold`` x the
  fleet median; the orchestrator reacts by evicting/replacing the host
  (here: callback).
* **Elastic re-mesh** -- :func:`elastic_remesh_plan` computes the
  largest (data', model) mesh that fits the surviving host set, so the
  job resumes from checkpoint on fewer nodes instead of dying (model
  axis is preserved; the data axis shrinks -- batch is re-sharded).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


# ----------------------------------------------------------------------
# straggler detection
# ----------------------------------------------------------------------

class StragglerMonitor:
    """Per-host EWMA of step times; flags hosts slower than ``threshold``
    x the fleet median.

    The clock is INJECTABLE (``clock``, defaults to ``time.monotonic``):
    under the fleet simulator the monitor runs on the sim clock, so
    derate detection is deterministic and testable.  Interval timing is
    explicit -- ``begin(host)`` marks the start of a host's step,
    ``end(host)`` reads the clock, records the elapsed interval and
    returns it; ``record`` remains for callers that measure externally.
    """

    def __init__(self, n_hosts: int, alpha: float = 0.2,
                 threshold: float = 1.5, warmup: int = 3,
                 clock: Callable[[], float] = time.monotonic):
        self.ewma = np.zeros(n_hosts)
        self.count = np.zeros(n_hosts, dtype=int)
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.clock = clock
        self._open: Dict[int, float] = {}

    @property
    def n_hosts(self) -> int:
        return int(self.ewma.shape[0])

    def add_host(self) -> int:
        """Grow the host set by one (elastic fleets); returns the new
        host index."""
        self.ewma = np.append(self.ewma, 0.0)
        self.count = np.append(self.count, 0)
        return self.n_hosts - 1

    def reset(self, host: int) -> None:
        """Forget a host's history: a crashed/replaced host must neither
        be flagged on stale data nor skew the fleet median (it re-warms
        from scratch if it comes back)."""
        self.ewma[host] = 0.0
        self.count[host] = 0
        self._open.pop(host, None)

    def begin(self, host: int) -> None:
        """Mark the start of ``host``'s step on the injected clock."""
        self._open[host] = self.clock()

    def end(self, host: int) -> float:
        """Close the open interval for ``host``, record it, return it."""
        dt = self.clock() - self._open.pop(host)
        self.record(host, dt)
        return dt

    def record(self, host: int, step_seconds: float) -> None:
        if self.count[host] == 0:
            self.ewma[host] = step_seconds
        else:
            self.ewma[host] = (self.alpha * step_seconds
                               + (1 - self.alpha) * self.ewma[host])
        self.count[host] += 1

    def stragglers(self) -> List[int]:
        ready = self.count >= self.warmup
        if not np.any(ready):
            return []
        med = float(np.median(self.ewma[ready]))
        if med <= 0.0:
            return []
        return [int(i) for i in np.nonzero(
            ready & (self.ewma > self.threshold * med))[0]]


# ----------------------------------------------------------------------
# elastic re-mesh
# ----------------------------------------------------------------------

def elastic_remesh_plan(n_alive_chips: int, model_parallel: int,
                        min_data: int = 1) -> Optional[Tuple[int, int]]:
    """Largest (data, model) mesh on surviving chips, preserving TP width.

    TP degree must not change (weight shards are per-TP-rank); the data
    axis absorbs the loss.  Returns None if fewer than one TP group
    survives.
    """
    data = n_alive_chips // model_parallel
    if data < min_data:
        return None
    return (data, model_parallel)


# ----------------------------------------------------------------------
# resumable loop (single-host demonstration harness; the multi-host
# version differs only in where save/restore run)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class LoopReport:
    steps_run: int
    restarts: int
    final_step: int
    losses: List[float]


def run_resumable(train_step: Callable, init_state: Callable,
                  make_batch: Callable, ckpt, total_steps: int,
                  ckpt_every: int = 10,
                  failure_injector: Optional[Callable[[int], bool]] = None,
                  max_restarts: int = 5) -> LoopReport:
    """Run to ``total_steps`` surviving injected failures.

    ``failure_injector(step) -> bool`` raises a simulated preemption when
    True; the loop restores from the last committed checkpoint and
    continues.  Used by tests and examples/fault_tolerant_training.py.
    """
    restarts = 0
    losses: List[float] = []

    while True:
        step, state = ckpt.directory and _try_restore(ckpt, init_state) \
            or (0, init_state())
        try:
            while step < total_steps:
                batch = make_batch(step)
                if failure_injector is not None and failure_injector(step):
                    raise RuntimeError(f"injected failure at step {step}")
                state, metrics = train_step(state, batch)
                losses.append(float(metrics["loss"]))
                step += 1
                if step % ckpt_every == 0 or step == total_steps:
                    ckpt.save(step, state)
            ckpt.wait()
            return LoopReport(steps_run=len(losses), restarts=restarts,
                              final_step=step, losses=losses)
        except RuntimeError:
            restarts += 1
            ckpt.wait()
            if restarts > max_restarts:
                raise


def _try_restore(ckpt, init_state):
    from repro.checkpoint import restore_latest
    template = init_state()
    step, state = restore_latest(ckpt.directory, template)
    if step is None:
        return (0, template)
    return (step, state)
