"""Request-scoped timelines reconstructed from the span stream.

PR 6's tracer records *boundary*-scoped telemetry: one track per engine
and per lane, spans named for the phase (``admit``, ``decode.dispatch``,
``preempt.evict`` ...).  That answers "what is the engine doing" but not
"what happened to request 17" -- a request hops lanes on re-admission,
hops ENGINES on a crash migration, and its decode work hides inside
batch-scoped ``decode.dispatch`` spans.

This module closes that gap without adding per-request spans to the hot
path.  The correlation key is the request ``uid``, which every span and
instant the engine emits already carries (``uid=...``), and which
``decode.dispatch`` spans now carry as a ``uids`` tuple (the lanes live
in that batch).  :func:`RequestTimeline.from_tracer` selects the events
belonging to one uid, orders them causally, and derives the per-request
facts the SLO layer consumes: TTFT, a tpot series (per-dispatch
seconds/token), pages touched, and the engine hops the request survived
(evict/restore, cross-engine crash migration, sim migrations).

``export_request_tracks`` re-projects the same events onto one Perfetto
track per request (``req/<uid>``), so a trace viewer shows each
request's life as a single lane regardless of how many engines served
it.  ``spans_from_chrome`` inverts ``export_chrome_trace`` -- the
round-trip the exporter tests pin.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import Instant, Span, SpanTracer

__all__ = [
    "RequestTimeline",
    "request_ids",
    "request_timelines",
    "export_request_tracks",
    "spans_from_chrome",
]

#: span/instant names that open or close a request's residency on an
#: engine -- the hop detector keys on these
_HOP_OPENERS = ("admit", "preempt.restore", "sim.prefill", "sim.decode")


def _span_uids(args: Dict[str, object]) -> Tuple[int, ...]:
    """Request uids an event's args attribute it to (``uid`` scalar,
    ``uids`` batch tuple, or nothing)."""
    out: List[int] = []
    uid = args.get("uid")
    if uid is not None:
        out.append(int(uid))
    uids = args.get("uids")
    if uids is not None:
        out.extend(int(u) for u in uids)
    return tuple(out)


def _engine_of(track: str) -> str:
    """Engine/board a track belongs to: ``serve/lane0`` -> ``serve``,
    ``node0/u3`` -> ``node0``, ``serve`` -> ``serve``."""
    return track.split("/", 1)[0]


@dataclasses.dataclass
class RequestTimeline:
    """Causally ordered per-request view over one tracer's records.

    ``spans``/``instants`` are the tracer's own objects (shared, do not
    mutate), sorted by start time.  Derived fields:

    * ``engines`` -- boards that served the request, in first-touch
      order; ``hops`` is ``len(engines) - 1``;
    * ``ttft_s`` -- admit-start to first generated token (needs a
      ``first_token`` / ``sim.first_token`` instant);
    * ``tpot_series`` -- ``(t_end, seconds_per_token)`` per decode
      dispatch the request was live in (sim: one entry per decode span);
    * ``pages_touched`` -- high-water page count seen in any of the
      request's span args.
    """

    request_id: int
    spans: List[Span] = dataclasses.field(default_factory=list)
    instants: List[Instant] = dataclasses.field(default_factory=list)

    # -- derived --------------------------------------------------------
    @property
    def engines(self) -> Tuple[str, ...]:
        seen: Dict[str, None] = {}
        for s in self.spans:
            seen.setdefault(_engine_of(s.track))
        for e in self.instants:
            seen.setdefault(_engine_of(e.track))
        return tuple(seen)

    @property
    def hops(self) -> int:
        return max(len(self.engines) - 1, 0)

    @property
    def t_admit(self) -> Optional[float]:
        for s in self.spans:
            if s.name in ("admit", "sim.prefill"):
                return s.t0
        return None

    @property
    def t_first_token(self) -> Optional[float]:
        for e in self.instants:
            if e.name in ("first_token", "sim.first_token"):
                return e.t
        return None

    @property
    def t_retire(self) -> Optional[float]:
        for e in reversed(self.instants):
            if e.name == "retire":
                return e.t
        for s in reversed(self.spans):
            if s.name == "sim.decode":
                return s.t1
        return None

    @property
    def ttft_s(self) -> Optional[float]:
        t0, t1 = self.t_admit, self.t_first_token
        if t0 is None or t1 is None:
            return None
        return t1 - t0

    @property
    def tpot_series(self) -> List[Tuple[float, float]]:
        out: List[Tuple[float, float]] = []
        for s in self.spans:
            if s.name == "decode.dispatch":
                steps = int(s.args.get("n_steps", 1)) or 1
                out.append((s.t1, s.duration_s / steps))
            elif s.name == "sim.decode":
                gen = int(s.args.get("gen_len", 1)) or 1
                out.append((s.t1, s.duration_s / gen))
        return out

    @property
    def tpot_mean_s(self) -> Optional[float]:
        series = self.tpot_series
        if not series:
            return None
        return sum(v for _, v in series) / len(series)

    @property
    def pages_touched(self) -> int:
        pages = 0
        for s in self.spans:
            for key in ("n_pages", "pages"):
                v = s.args.get(key)
                if isinstance(v, (int, float)):
                    pages = max(pages, int(v))
        return pages

    # -- completeness ---------------------------------------------------
    def gaps(self) -> List[str]:
        """Reasons this timeline is NOT gap-free (empty == complete).

        Gap-free means: the request was admitted, produced a first
        token, retired, every evict has a matching restore (migration
        hops included), and no decode work precedes admission.
        """
        issues: List[str] = []
        if self.t_admit is None:
            issues.append("no admit/prefill span")
        if self.t_first_token is None:
            issues.append("no first_token instant")
        if self.t_retire is None:
            issues.append("no retire record")
        evicts = sum(1 for s in self.spans if s.name == "preempt.evict")
        restores = sum(1 for s in self.spans
                       if s.name == "preempt.restore")
        # a crash migration restores a HOST-HELD checkpoint on the
        # survivor with no matching evict span (the board died before
        # it could checkpoint), so each engine hop may add one
        # unmatched restore; anything beyond that -- or an evict that
        # never came back -- is a genuine gap
        if evicts > restores or restores > evicts + self.hops:
            issues.append(f"evict/restore imbalance ({evicts} evicts, "
                          f"{restores} restores, {self.hops} hops)")
        if self.t_admit is not None:
            early = [s.name for s in self.spans
                     if s.name in ("decode.dispatch", "sim.decode")
                     and s.t1 < self.t_admit]
            if early:
                issues.append(f"decode before admission: {early}")
        return issues

    @property
    def complete(self) -> bool:
        return not self.gaps()

    def as_dict(self) -> Dict[str, object]:
        """JSON-friendly summary (the request-timeline schema the docs
        catalog and ``repro.obs.dump`` renders)."""
        return {
            "request_id": self.request_id,
            "engines": list(self.engines),
            "hops": self.hops,
            "t_admit": self.t_admit,
            "t_first_token": self.t_first_token,
            "t_retire": self.t_retire,
            "ttft_s": self.ttft_s,
            "tpot_mean_s": self.tpot_mean_s,
            "n_decode_dispatches": sum(
                1 for s in self.spans
                if s.name in ("decode.dispatch", "sim.decode")),
            "pages_touched": self.pages_touched,
            "complete": self.complete,
            "gaps": self.gaps(),
        }

    # -- construction ---------------------------------------------------
    @classmethod
    def from_tracer(cls, tracer_or_spans, request_id: int,
                    instants: Optional[Sequence[Instant]] = None
                    ) -> "RequestTimeline":
        """Select and order the records belonging to ``request_id``.

        Accepts a :class:`SpanTracer` or an explicit span list (plus
        ``instants``).  A span belongs to the request when its args
        carry ``uid == request_id`` or a ``uids`` batch containing it.
        """
        if isinstance(tracer_or_spans, SpanTracer):
            spans = tracer_or_spans.spans
            instants = tracer_or_spans.instants
        else:
            spans = list(tracer_or_spans)
            instants = list(instants or [])
        mine_s = sorted((s for s in spans
                         if request_id in _span_uids(s.args)),
                        key=lambda s: (s.t0, s.t1))
        mine_i = sorted((e for e in instants
                         if request_id in _span_uids(e.args)),
                        key=lambda e: e.t)
        return cls(request_id=request_id, spans=mine_s, instants=mine_i)


def request_ids(tracer: SpanTracer) -> List[int]:
    """Every request uid the tracer saw, sorted."""
    seen: set = set()
    for s in tracer.spans:
        seen.update(_span_uids(s.args))
    for e in tracer.instants:
        seen.update(_span_uids(e.args))
    return sorted(seen)


def request_timelines(tracer: SpanTracer) -> Dict[int, RequestTimeline]:
    """One :class:`RequestTimeline` per uid the tracer saw."""
    return {uid: RequestTimeline.from_tracer(tracer, uid)
            for uid in request_ids(tracer)}


def export_request_tracks(timelines: Dict[int, RequestTimeline]
                          ) -> Dict[str, object]:
    """Chrome-trace JSON with ONE track per request (``req/<uid>``).

    The same Perfetto schema ``SpanTracer.export_chrome_trace`` emits;
    each event keeps its original engine track in ``args["src_track"]``
    so the hop is readable from the viewer.  Batch-scoped spans appear
    on every member request's track.
    """
    out = SpanTracer(enabled=True)
    for uid in sorted(timelines):
        tl = timelines[uid]
        track = f"req/{uid}"
        for s in tl.spans:
            args = {k: v for k, v in s.args.items() if k != "src_track"}
            out.add_span(s.name, s.t0, s.t1, track=track,
                         src_track=s.track, **args)
        for e in tl.instants:
            args = {k: v for k, v in e.args.items() if k != "src_track"}
            out.add_instant(e.name, e.t, track=track,
                            src_track=e.track, **args)
    return out.export_chrome_trace()


def save_request_tracks(timelines: Dict[int, RequestTimeline],
                        path: str) -> None:
    with open(path, "w") as f:
        json.dump(export_request_tracks(timelines), f, indent=2)


def spans_from_chrome(obj: Dict[str, object]
                      ) -> Tuple[List[Span], List[Instant]]:
    """Invert :meth:`SpanTracer.export_chrome_trace`.

    Timestamps come back in SECONDS relative to the export's own base
    (the exporter subtracts it), so re-derived durations are exact but
    absolute times are trace-relative.
    """
    track_of: Dict[int, str] = {}
    for e in obj["traceEvents"]:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            track_of[e["tid"]] = e["args"]["name"]
    spans: List[Span] = []
    instants: List[Instant] = []
    for e in obj["traceEvents"]:
        track = track_of.get(e.get("tid"), str(e.get("tid")))
        if e.get("ph") == "X":
            t0 = e["ts"] / 1e6
            spans.append(Span(name=e["name"], track=track, t0=t0,
                              t1=t0 + e["dur"] / 1e6,
                              args=dict(e.get("args", {}))))
        elif e.get("ph") == "i":
            instants.append(Instant(name=e["name"], track=track,
                                    t=e["ts"] / 1e6,
                                    args=dict(e.get("args", {}))))
    return spans, instants
