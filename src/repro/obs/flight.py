"""Fault flight recorder: a bounded ring of recent telemetry per engine.

PR 7 made faults recoverable; it did not make them *explainable*.  When
a board crashes, a sanitizer trips, or an invariant fires, the evidence
-- the spans, events, and metric values leading up to the faulting op --
lives in process memory and dies with it.  The flight recorder keeps the
last ``capacity`` records in a ring buffer (bounded, so an always-on
recorder costs O(capacity) memory and one append per record) and dumps
them to ``flight_<engine>.jsonl`` at the faulting op:

* :class:`~repro.fleet.faults.FaultInjector` crashes and the
  ``run_trace_with_faults`` crash replay dump the dying engine's ring;
* :class:`~repro.analysis.sanitizer.SanitizerError` and
  :class:`~repro.analysis.invariants.InvariantError` raised inside a
  :func:`flight_guard`-wrapped engine op dump before re-raising.

The dump is JSONL like ``pages.jsonl``: a header line (engine, reason,
drop count), then one record per line, oldest first -- replayable
offline with :meth:`FlightRecorder.load`.
"""

from __future__ import annotations

import json
from collections import deque
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = ["FlightRecorder", "flight_guard"]


class FlightRecorder:
    """Bounded ring buffer of spans / events / metric snapshots.

    Attach to a tracer/event log with :meth:`attach` (tap hooks -- no
    per-call-site plumbing), snapshot a registry with
    :meth:`snapshot_metrics`, dump with :meth:`dump`.  Records older
    than ``capacity`` fall off the front; ``n_dropped`` counts them so a
    dump is honest about what it no longer holds.
    """

    def __init__(self, name: str = "engine", capacity: int = 256):
        self.name = name
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self.n_seen = 0
        self.n_dumps = 0
        self.dump_paths: List[str] = []

    # -- recording ------------------------------------------------------
    def record(self, kind: str, **payload: Any) -> None:
        self.n_seen += 1
        self._ring.append({"kind": kind, **payload})

    def record_span(self, span) -> None:
        self.record("span", name=span.name, track=span.track,
                    t0=span.t0, t1=span.t1, args=dict(span.args))

    def record_instant(self, instant) -> None:
        self.record("instant", name=instant.name, track=instant.track,
                    t=instant.t, args=dict(instant.args))

    def record_event(self, event) -> None:
        self.record("event", name=event.name, t=event.t,
                    fields=dict(event.fields))

    def snapshot_metrics(self, registry, t: Optional[float] = None) -> None:
        """Record one full registry snapshot (typically at a dispatch
        boundary or right before a dump)."""
        self.record("metrics", t=t, values=registry.collect())

    def attach(self, tracer=None, log=None) -> "FlightRecorder":
        """Tap a tracer's span/instant hooks and/or an event log's emit
        hook.  Chains any hook already installed (tap fan-out)."""
        if tracer is not None:
            prev_s, prev_i = tracer.on_span, tracer.on_instant
            tracer.on_span = (self.record_span if prev_s is None else
                              lambda sp: (prev_s(sp),
                                          self.record_span(sp)))
            tracer.on_instant = (self.record_instant if prev_i is None
                                 else lambda ev: (prev_i(ev),
                                                  self.record_instant(ev)))
        if log is not None:
            prev_e = log.on_emit
            log.on_emit = (self.record_event if prev_e is None else
                           lambda ev: (prev_e(ev),
                                       self.record_event(ev)))
        return self

    # -- introspection --------------------------------------------------
    @property
    def n_dropped(self) -> int:
        return max(self.n_seen - len(self._ring), 0)

    def __len__(self) -> int:
        return len(self._ring)

    def records(self) -> List[Dict[str, Any]]:
        return list(self._ring)

    def tail(self, n: int) -> List[Dict[str, Any]]:
        return list(self._ring)[-n:]

    # -- dump / load ----------------------------------------------------
    def default_path(self) -> str:
        return f"flight_{self.name}.jsonl"

    def dump(self, path: Optional[str] = None, reason: str = "",
             registry=None, **extra: Any) -> str:
        """Write header + ring to ``path`` (default
        ``flight_<name>.jsonl``), oldest record first.  With a
        ``registry``, a final metrics snapshot is appended first so the
        dump carries the counters at the faulting op.  Returns the
        path written."""
        if registry is not None:
            self.snapshot_metrics(registry)
        path = path or self.default_path()
        header = {"flight": self.name, "reason": reason,
                  "capacity": self.capacity, "n_records": len(self._ring),
                  "n_dropped": self.n_dropped, **extra}
        with open(path, "w") as f:
            f.write(json.dumps(header) + "\n")
            for rec in self._ring:
                f.write(json.dumps(rec) + "\n")
        self.n_dumps += 1
        self.dump_paths.append(path)
        return path

    @staticmethod
    def load(path) -> Tuple[Dict[str, Any], List[Dict[str, Any]]]:
        """Offline replay: returns ``(header, records)`` from a dump."""
        with open(path) as f:
            lines = [json.loads(line) for line in f if line.strip()]
        if not lines:
            return {}, []
        return lines[0], lines[1:]


class flight_guard:
    """Context manager dumping ``recorder`` when a lifecycle error
    escapes the guarded op, then re-raising.

    Triggers on ``AssertionError`` subclasses -- which is exactly the
    family :class:`~repro.analysis.invariants.InvariantError` and
    :class:`~repro.analysis.sanitizer.SanitizerError` belong to (both
    deliberately subclass it for call-site compatibility) -- so the
    guard needs no import of the analysis layer.  ``recorder=None`` is
    a no-op guard, letting call sites stay branch-free.
    """

    def __init__(self, recorder: Optional[FlightRecorder],
                 op: str = "", registry=None):
        self.recorder = recorder
        self.op = op
        self.registry = registry

    def __enter__(self) -> Optional[FlightRecorder]:
        return self.recorder

    def __exit__(self, exc_type, exc, tb) -> bool:
        if (self.recorder is not None and exc_type is not None
                and issubclass(exc_type, AssertionError)):
            self.recorder.dump(reason=f"{exc_type.__name__}: {exc}",
                               registry=self.registry, op=self.op)
        return False


def iter_flight_dumps(recorders) -> Iterator[str]:
    """All dump paths written by a collection of recorders."""
    for rec in recorders:
        for path in rec.dump_paths:
            yield path
