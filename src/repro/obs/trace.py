"""Dispatch-span tracer: host-clock spans around the real hot paths.

A :class:`SpanTracer` records closed intervals (``Span``) on named
*tracks* -- one per engine and one per lane, or one per simulated board
-- plus instant events.  Two clock disciplines:

* **host clock** (default ``time.perf_counter``): spans opened with the
  :meth:`span` context manager time real host work *outside* jit -- the
  Python dispatch, the device_get drain, the page gather/scatter.
  Nothing is ever inserted into a jitted computation, so tracing cannot
  change what XLA compiles or what tokens come out (pinned by
  ``tests/test_obs.py``).
* **sim clock**: :meth:`add_span` records explicit ``(t0, t1)``
  intervals, which is how :class:`~repro.fleet.sim.FleetSim` emits
  deterministic spans stamped with simulated seconds.

Exports Chrome-trace / Perfetto JSON (:meth:`export_chrome_trace`,
load the file at https://ui.perfetto.dev) and feeds per-span durations
into a :class:`~repro.obs.metrics.MetricsRegistry` histogram
(``span.<name>.seconds``) when one is attached, which is where the
bench's per-phase p50/p99 come from.

A disabled tracer (``enabled=False``) costs one attribute check and a
shared null context manager per call site -- engines construct one by
default so call sites never branch.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional

from repro.obs.metrics import MetricsRegistry

_NULL_CM = contextlib.nullcontext()


@dataclasses.dataclass
class Span:
    """One closed interval on a track (seconds; ``args`` is free-form)."""

    name: str
    track: str
    t0: float
    t1: Optional[float] = None
    args: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        assert self.t1 is not None, f"span {self.name!r} still open"
        return self.t1 - self.t0


@dataclasses.dataclass(frozen=True)
class Instant:
    """A zero-duration marker on a track."""

    name: str
    track: str
    t: float
    args: Dict[str, object] = dataclasses.field(default_factory=dict)


class SpanTracer:
    """Span recorder with per-track stacks (see module docstring)."""

    def __init__(self, enabled: bool = True,
                 clock: Optional[Callable[[], float]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 span_metric_prefix: str = "span"):
        self.enabled = enabled
        self.clock = clock or time.perf_counter
        self.registry = registry
        self.span_metric_prefix = span_metric_prefix
        self.spans: List[Span] = []
        self.instants: List[Instant] = []
        self._stacks: Dict[str, List[Span]] = {}
        #: optional taps (e.g. a flight recorder's ring buffer), called
        #: with each closed Span / recorded Instant
        self.on_span: Optional[Callable[[Span], None]] = None
        self.on_instant: Optional[Callable[[Instant], None]] = None

    # -- recording ------------------------------------------------------
    def span(self, name: str, track: str = "main", **args):
        """Context manager timing a host-side block; no-op when disabled."""
        if not self.enabled:
            return _NULL_CM
        return self._span_cm(name, track, args)

    @contextlib.contextmanager
    def _span_cm(self, name: str, track: str, args: Dict[str, object]):
        sp = Span(name=name, track=track, t0=self.clock(), args=args)
        stack = self._stacks.setdefault(track, [])
        stack.append(sp)
        try:
            yield sp
        finally:
            sp.t1 = self.clock()
            popped = stack.pop()
            assert popped is sp, f"span nesting violated on {track!r}"
            self.spans.append(sp)
            self._observe(sp)

    def add_span(self, name: str, t0: float, t1: float,
                 track: str = "main", **args) -> Optional[Span]:
        """Record an explicit interval (the sim-clock path)."""
        if not self.enabled:
            return None
        assert t1 >= t0, f"span {name!r}: t1 < t0"
        sp = Span(name=name, track=track, t0=t0, t1=t1, args=args)
        self.spans.append(sp)
        self._observe(sp)
        return sp

    def instant(self, name: str, track: str = "main",
                **args) -> Optional[Instant]:
        if not self.enabled:
            return None
        ev = Instant(name=name, track=track, t=self.clock(), args=args)
        self.instants.append(ev)
        if self.on_instant is not None:
            self.on_instant(ev)
        return ev

    def add_instant(self, name: str, t: float, track: str = "main",
                    **args) -> Optional[Instant]:
        """Record an instant at an explicit timestamp (the sim-clock
        path -- :meth:`instant` reads the host clock)."""
        if not self.enabled:
            return None
        ev = Instant(name=name, track=track, t=t, args=args)
        self.instants.append(ev)
        if self.on_instant is not None:
            self.on_instant(ev)
        return ev

    def instants_named(self, name: str) -> List[Instant]:
        return [e for e in self.instants if e.name == name]

    def _observe(self, sp: Span) -> None:
        if self.registry is not None:
            self.registry.histogram(
                f"{self.span_metric_prefix}.{sp.name}.seconds",
                help=f"host seconds inside {sp.name!r} spans",
            ).observe(sp.duration_s)
        if self.on_span is not None:
            self.on_span(sp)

    # -- queries --------------------------------------------------------
    def spans_named(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def tracks(self) -> List[str]:
        seen: Dict[str, None] = {}
        for s in self.spans:
            seen.setdefault(s.track)
        for e in self.instants:
            seen.setdefault(e.track)
        return list(seen)

    def check_well_nested(self) -> bool:
        """Per track: any two spans are disjoint or strictly contained
        (no partial overlap), and every span is closed and monotone."""
        by_track: Dict[str, List[Span]] = {}
        for s in self.spans:
            if s.t1 is None or s.t1 < s.t0:
                return False
            by_track.setdefault(s.track, []).append(s)
        for spans in by_track.values():
            spans = sorted(spans, key=lambda s: (s.t0, -s.t1))
            stack: List[Span] = []
            for s in spans:
                while stack and stack[-1].t1 <= s.t0:
                    stack.pop()
                if stack and s.t1 > stack[-1].t1:
                    return False                 # partial overlap
                stack.append(s)
        return True

    # -- export ---------------------------------------------------------
    def export_chrome_trace(self) -> Dict[str, object]:
        """Chrome-trace ("trace event") JSON object, Perfetto-loadable.

        Timestamps are microseconds relative to the earliest event, one
        ``tid`` per track (named via metadata events), complete events
        (``ph: "X"``) for spans and thread-scoped instants (``ph: "i"``).
        """
        tids = {tr: i for i, tr in enumerate(sorted(self.tracks()))}
        t_base = min(
            [s.t0 for s in self.spans] + [e.t for e in self.instants],
            default=0.0)

        def us(t: float) -> float:
            return (t - t_base) * 1e6

        events: List[Dict[str, object]] = [
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
             "args": {"name": tr}}
            for tr, tid in tids.items()]
        for s in self.spans:
            assert s.t1 is not None, f"open span {s.name!r} at export"
            events.append({
                "name": s.name, "ph": "X", "pid": 0,
                "tid": tids[s.track], "ts": us(s.t0),
                "dur": (s.t1 - s.t0) * 1e6, "cat": "serving",
                "args": dict(s.args)})
        for e in self.instants:
            events.append({
                "name": e.name, "ph": "i", "s": "t", "pid": 0,
                "tid": tids[e.track], "ts": us(e.t), "cat": "serving",
                "args": dict(e.args)})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        return json.dumps(self.export_chrome_trace())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export_chrome_trace(), f, indent=2)
