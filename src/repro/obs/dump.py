"""Terminal summaries for the stack's trace artifacts.

One entry point over the three file shapes the serving stack writes::

    python -m repro.obs.dump fault_drill_trace.json   # Chrome trace
    python -m repro.obs.dump flight_node0.jsonl       # flight recorder
    python -m repro.obs.dump pages.jsonl              # page op-stream

The shape is sniffed from the content, not the filename: a JSON object
with ``traceEvents`` is a Chrome trace (summarized as a per-request
TTFT/tpot table via :mod:`repro.obs.requests`), a JSONL whose header
carries ``flight`` is a flight-recorder dump (header + last-N tail),
and a JSONL of ``op`` records is a page-lifecycle stream.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.flight import FlightRecorder
from repro.obs.requests import RequestTimeline, spans_from_chrome

__all__ = ["sniff", "summarize_trace", "summarize_flight",
           "summarize_pages", "main"]

_TAIL_N = 10


def sniff(path: str) -> str:
    """``"trace"`` / ``"flight"`` / ``"pages"`` / ``"unknown"``."""
    with open(path) as f:
        head = f.read(1 << 20)
    try:
        obj = json.loads(head)
        if isinstance(obj, dict) and "traceEvents" in obj:
            return "trace"
    except ValueError:
        pass
    first = head.splitlines()[0] if head.strip() else ""
    try:
        rec = json.loads(first)
    except ValueError:
        return "unknown"
    if isinstance(rec, dict) and "flight" in rec:
        return "flight"
    if isinstance(rec, dict) and "op" in rec:
        return "pages"
    return "unknown"


def _fmt(v: Optional[float], scale: float = 1e3,
         unit: str = "ms") -> str:
    return "-" if v is None else f"{v * scale:.2f}{unit}"


def summarize_trace(path: str) -> List[str]:
    with open(path) as f:
        obj = json.load(f)
    spans, instants = spans_from_chrome(obj)
    uids = sorted({u for s in spans
                   for u in _uids_of(s.args)}
                  | {u for e in instants for u in _uids_of(e.args)})
    lines = [f"{path}: chrome trace, {len(spans)} spans, "
             f"{len(instants)} instants, {len(uids)} request(s)"]
    if not uids:
        return lines
    header = (f"{'uid':>5} {'engines':<18} {'hops':>4} {'ttft':>10} "
              f"{'tpot':>10} {'disp':>5} {'pages':>5} {'complete':>8}")
    lines.append(header)
    lines.append("-" * len(header))
    for uid in uids:
        tl = RequestTimeline.from_tracer(spans, uid, instants=instants)
        lines.append(
            f"{uid:>5} {','.join(tl.engines):<18} {tl.hops:>4} "
            f"{_fmt(tl.ttft_s):>10} {_fmt(tl.tpot_mean_s):>10} "
            f"{sum(1 for s in tl.spans if s.name in ('decode.dispatch', 'sim.decode')):>5} "
            f"{tl.pages_touched:>5} "
            f"{'yes' if tl.complete else 'NO':>8}")
        for gap in tl.gaps():
            lines.append(f"      ^ gap: {gap}")
    return lines


def _uids_of(args: Dict[str, Any]) -> List[int]:
    out = []
    if args.get("uid") is not None:
        out.append(int(args["uid"]))
    for u in args.get("uids") or ():
        out.append(int(u))
    return out


def summarize_flight(path: str, tail_n: int = _TAIL_N) -> List[str]:
    header, records = FlightRecorder.load(path)
    lines = [f"{path}: flight dump of engine "
             f"{header.get('flight', '?')!r}",
             f"  reason: {header.get('reason', '')}",
             f"  records: {header.get('n_records', len(records))} "
             f"(capacity {header.get('capacity', '?')}, "
             f"{header.get('n_dropped', 0)} dropped)"]
    kinds: Dict[str, int] = {}
    for rec in records:
        kinds[rec.get("kind", "?")] = kinds.get(rec.get("kind", "?"), 0) + 1
    lines.append("  by kind: " + ", ".join(
        f"{k}={n}" for k, n in sorted(kinds.items())))
    lines.append(f"  last {min(tail_n, len(records))} record(s):")
    for rec in records[-tail_n:]:
        kind = rec.get("kind", "?")
        if kind == "span":
            dur = (rec["t1"] - rec["t0"]) * 1e3
            lines.append(f"    span    {rec['name']:<24} "
                         f"{rec['track']:<16} {dur:8.2f}ms "
                         f"{rec.get('args', {})}")
        elif kind == "instant":
            lines.append(f"    instant {rec['name']:<24} "
                         f"{rec['track']:<16} {rec.get('args', {})}")
        elif kind == "event":
            lines.append(f"    event   {rec['name']:<24} "
                         f"{rec.get('fields', {})}")
        elif kind == "metrics":
            lines.append(f"    metrics snapshot "
                         f"({len(rec.get('values', {}))} series)")
        else:
            lines.append(f"    {kind} {rec}")
    return lines


def summarize_pages(path: str, tail_n: int = _TAIL_N) -> List[str]:
    with open(path) as f:
        records = [json.loads(line) for line in f if line.strip()]
    ops: Dict[str, int] = {}
    for rec in records:
        ops[rec.get("op", "?")] = ops.get(rec.get("op", "?"), 0) + 1
    lines = [f"{path}: page op-stream, {len(records)} record(s)",
             "  by op: " + ", ".join(
                 f"{k}={n}" for k, n in sorted(ops.items())),
             f"  last {min(tail_n, len(records))} record(s):"]
    for rec in records[-tail_n:]:
        lines.append("    " + json.dumps(rec))
    return lines


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0 if argv else 1
    status = 0
    for path in argv:
        kind = sniff(path)
        if kind == "trace":
            out = summarize_trace(path)
        elif kind == "flight":
            out = summarize_flight(path)
        elif kind == "pages":
            out = summarize_pages(path)
        else:
            out = [f"{path}: unrecognized trace artifact"]
            status = 1
        print("\n".join(out))
    return status


if __name__ == "__main__":
    sys.exit(main())
