"""repro.obs: unified serving telemetry.

Zero-dependency observability for the serving stack: a typed
:class:`MetricsRegistry` every layer publishes into under one
dot-namespaced schema, a :class:`SpanTracer` wrapping the real hot-path
boundaries (host clock outside jit; sim clock inside ``FleetSim``) with
Chrome-trace/Perfetto and Prometheus-style exports, an append-only
:class:`EventLog` for validator verdicts, and a sim-to-real calibration
gate (:func:`predict_replay` / :func:`calibrate_replay`) that fits the
scheduling model against ``fleet.execution`` replay telemetry.
"""

from repro.obs.calibration import (
    GATED_METRICS,
    CalibrationReport,
    PredictedReplay,
    calibrate_replay,
    fit_dispatch_time_model,
    fit_linear,
    predict_replay,
    rel_err,
)
from repro.obs.events import DEFAULT_LOG, Event, EventLog, emit
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    StatsView,
)
from repro.obs.trace import Instant, Span, SpanTracer

__all__ = [
    "CalibrationReport",
    "Counter",
    "DEFAULT_LOG",
    "Event",
    "EventLog",
    "GATED_METRICS",
    "Gauge",
    "Histogram",
    "Instant",
    "MetricsRegistry",
    "PredictedReplay",
    "Span",
    "SpanTracer",
    "StatsView",
    "calibrate_replay",
    "emit",
    "fit_dispatch_time_model",
    "fit_linear",
    "predict_replay",
    "rel_err",
]
