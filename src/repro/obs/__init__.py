"""repro.obs: unified serving telemetry.

Zero-dependency observability for the serving stack: a typed
:class:`MetricsRegistry` every layer publishes into under one
dot-namespaced schema, a :class:`SpanTracer` wrapping the real hot-path
boundaries (host clock outside jit; sim clock inside ``FleetSim``) with
Chrome-trace/Perfetto and Prometheus-style exports, an append-only
:class:`EventLog` for validator verdicts, and a sim-to-real calibration
gate (:func:`predict_replay` / :func:`calibrate_replay`) that fits the
scheduling model against ``fleet.execution`` replay telemetry.

On top of those primitives: request-scoped timelines reconstructed
from the span stream (:class:`RequestTimeline`), a bounded
:class:`FlightRecorder` dumped at faulting ops, and an SLO burn-rate
control loop (:class:`BurnRateMonitor` / :class:`SLOController`)
closing the loop into the degradation ladder.  ``repro.obs.schema``
catalogs the full namespace; ``python -m repro.obs.dump`` summarizes
the artifacts.
"""

from repro.obs.calibration import (
    GATED_METRICS,
    CalibrationReport,
    PredictedReplay,
    calibrate_replay,
    fit_dispatch_time_model,
    fit_linear,
    predict_replay,
    rel_err,
)
from repro.obs.events import DEFAULT_LOG, Event, EventLog, emit
from repro.obs.flight import FlightRecorder, flight_guard
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    StatsView,
)
from repro.obs.requests import (
    RequestTimeline,
    export_request_tracks,
    request_ids,
    request_timelines,
    save_request_tracks,
    spans_from_chrome,
)
from repro.obs.slo import BurnRateMonitor, SLOController, SLOObjective
from repro.obs.trace import Instant, Span, SpanTracer

__all__ = [
    "BurnRateMonitor",
    "CalibrationReport",
    "Counter",
    "DEFAULT_LOG",
    "Event",
    "EventLog",
    "FlightRecorder",
    "GATED_METRICS",
    "Gauge",
    "Histogram",
    "Instant",
    "MetricsRegistry",
    "PredictedReplay",
    "RequestTimeline",
    "SLOController",
    "SLOObjective",
    "Span",
    "SpanTracer",
    "StatsView",
    "calibrate_replay",
    "emit",
    "export_request_tracks",
    "fit_dispatch_time_model",
    "fit_linear",
    "flight_guard",
    "predict_replay",
    "rel_err",
    "request_ids",
    "request_timelines",
    "save_request_tracks",
    "spans_from_chrome",
]
