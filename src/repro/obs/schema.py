"""The observability namespace, declared in one place.

Every span, instant, event, and metric name the serving stack emits is
cataloged here, plus the request-timeline schema keys
(:meth:`~repro.obs.requests.RequestTimeline.as_dict`).  Two consumers:

* ``docs/observability.md`` must mention every declared name -- the
  schema snapshot test fails ``make check`` when a new name ships
  undocumented (or a documented name disappears from this catalog);
* ``repro.obs.dump`` uses the catalogs to classify records when it
  summarizes a trace/flight/pages file.

Names with a ``<engine>`` / ``<model>`` placeholder are PREFIX
families: the live name substitutes the engine or model id (e.g.
``serve.decode_dispatches``, ``node0.pool.pages.free``).
"""

from __future__ import annotations

from typing import Dict, List

__all__ = [
    "SPAN_NAMES",
    "INSTANT_NAMES",
    "EVENT_NAMES",
    "METRIC_FAMILIES",
    "TIMELINE_KEYS",
    "FLIGHT_RECORD_KINDS",
    "all_names",
]

#: closed intervals on engine/lane/board tracks
SPAN_NAMES: Dict[str, str] = {
    "admit": "one admission: prefill + lane/page setup (uid)",
    "prefill.bucket": "batched prefill at a padded bucket length",
    "prefix.tail_prefill": "prefix-hit tail streamed through decode",
    "prefix.cow": "copy-on-write split of a shared page",
    "decode.dispatch": "one multi-step jitted decode dispatch "
                       "(n_steps, n_live, uids)",
    "preempt.evict": "lane checkpointed off the board (uid, n_pages)",
    "preempt.restore": "checkpoint scattered back onto a lane "
                       "(uid, n_pages)",
    "weights.swap": "model-pool weight swap on the serving engine",
    "sim.prefill": "simulated prefill residency (uid, prompt_len)",
    "sim.decode": "simulated decode residency (uid, gen_len)",
    "sim.swap": "simulated model swap",
    "sim.migrate": "simulated checkpoint migration (uid, pages, dst)",
    "sim.recover": "simulated crash-recovery transfer",
    "sim.fault.derate": "injected thermal derate window",
    "sim.fault.link": "injected host-link degradation window",
    "sim.fault.transient": "injected dispatch stall window",
}

#: zero-duration markers
INSTANT_NAMES: Dict[str, str] = {
    "admit.blocked": "admission refused for pages (uid, need_pages)",
    "prefix.hit": "radix prompt-cache hit (uid, matched_tokens)",
    "first_token": "first generated token surfaced host-side (uid)",
    "retire": "request completed and lane released (uid, gen)",
    "degrade.shed": "ladder-driven eviction of a victim lane (uid)",
    "weights.swap.done": "model-pool swap completed",
    "sim.first_token": "simulated first token (uid)",
    "sim.request_lost": "retry budget exhausted, request dropped (uid)",
    "sim.straggler_detected": "derate flagged by the straggler monitor",
    "sim.fault.crash": "injected fail-stop board crash",
}

#: structured events on the shared EventLog
EVENT_NAMES: Dict[str, str] = {
    "degrade.transition": "degradation-ladder level change",
    "slo.alert": "multi-window burn-rate alert fired",
    "slo.clear": "burn-rate alert cleared (short window recovered)",
    "slo.escalate": "SLO controller escalated the ladder",
    "slo.deescalate": "SLO controller de-escalated the ladder",
    "validate.preemption_exactness": "preemption exactness verdict",
    "validate.recovery_exactness": "crash-recovery exactness verdict",
    "validate.multimodel_exactness": "multi-model exactness verdict",
}

#: metric-name families (prefixes substitute the engine/pool name)
METRIC_FAMILIES: Dict[str, str] = {
    "<engine>.*": "ServeEngine counters (STATS_SCHEMA legacy keys)",
    "<engine>.pool.pages.*": "page-pool gauges (free, in_use, reserved, "
                             "disabled, hwm, allocs, frees, shared, "
                             "cow_splits)",
    "<engine>.prefix.cached_pages": "pages the radix prompt cache holds",
    "modelpool.*": "weight-pool gauges (bytes.used, bytes.free, "
                   "residents)",
    "fleet.*": "fleet-sim gauges and fault counters (retry.attempts, "
               "retry.hedges, faults.requests_lost)",
    "slo.*": "burn-rate gauges (burn_rate.short, burn_rate.long) and "
             "counters (violations.ttft, violations.tpot, alerts)",
    "span.<name>.seconds": "per-span duration histograms",
}

#: keys of RequestTimeline.as_dict() -- the request-timeline schema
TIMELINE_KEYS: List[str] = [
    "request_id", "engines", "hops", "t_admit", "t_first_token",
    "t_retire", "ttft_s", "tpot_mean_s", "n_decode_dispatches",
    "pages_touched", "complete", "gaps",
]

#: record kinds inside a flight_<engine>.jsonl dump
FLIGHT_RECORD_KINDS: List[str] = ["span", "instant", "event", "metrics"]


def all_names() -> List[str]:
    """Every declared name, for the docs snapshot test."""
    return (sorted(SPAN_NAMES) + sorted(INSTANT_NAMES)
            + sorted(EVENT_NAMES) + sorted(METRIC_FAMILIES)
            + TIMELINE_KEYS + FLIGHT_RECORD_KINDS)
