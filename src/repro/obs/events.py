"""Append-only event log: an auditable record of serving verdicts.

Exactness validators (``validate_preemption_exactness``,
``validate_multimodel_exactness``) used to return their verdict to the
caller and otherwise pass silently; an execution replay left no record
that the check ran at all.  The event log fixes that: every validator
emits a structured event (name + verdict + counters) into the default
log, so a replay session can be audited after the fact --
``repro.obs.events().records("validate.preemption_exactness")`` -- and
exported alongside the trace.

The log is deliberately dumb: timestamped dicts, no levels, no
handlers.  ``clear()`` between test cases; the default instance is
process-global so validators need no plumbing.

Clock discipline: the default clock is ``time.perf_counter`` -- the
SAME clock :class:`~repro.obs.trace.SpanTracer` stamps spans with, so
a merged span/event timeline lines up without translation.  (It used
to be ``time.time``, which skewed merged timelines by the wall-clock
epoch; lint rule R003 now flags an obs constructor handed a wall
clock.)  An engine that owns both a tracer and a log injects ONE
shared clock into both.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Event:
    """One audit record: timestamp (span-clock seconds), name, fields."""

    t: float
    name: str
    fields: Dict[str, object]

    def as_dict(self) -> Dict[str, object]:
        return {"t": self.t, "name": self.name, **self.fields}


class EventLog:
    """Append-only list of :class:`Event` with name filtering."""

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._events: List[Event] = []
        #: optional tap (e.g. a flight recorder's ring buffer): called
        #: with each Event right after it is appended
        self.on_emit = None

    @property
    def clock(self):
        """The clock this log stamps events with (shared-clock checks)."""
        return self._clock

    def emit(self, name: str, **fields) -> Event:
        ev = Event(t=self._clock(), name=name, fields=fields)
        self._events.append(ev)
        if self.on_emit is not None:
            self.on_emit(ev)
        return ev

    def records(self, name: Optional[str] = None) -> List[Event]:
        if name is None:
            return list(self._events)
        return [e for e in self._events if e.name == name]

    def records_prefix(self, prefix: str) -> List[Event]:
        """Events whose name starts with a dotted ``prefix`` -- e.g.
        ``records_prefix("degrade")`` collects every ladder transition."""
        dotted = prefix if prefix.endswith(".") else prefix + "."
        return [e for e in self._events
                if e.name == prefix or e.name.startswith(dotted)]

    def names(self) -> List[str]:
        """Distinct event names seen, in first-emission order."""
        seen: Dict[str, None] = {}
        for e in self._events:
            seen.setdefault(e.name)
        return list(seen)

    def __len__(self) -> int:
        return len(self._events)

    def clear(self) -> None:
        self._events.clear()

    def to_json(self) -> str:
        return json.dumps([e.as_dict() for e in self._events])

    def to_jsonl(self) -> str:
        """One JSON object per line -- the ``pages.jsonl`` format the
        offline sanitizer replay consumes."""
        return "\n".join(json.dumps(e.as_dict()) for e in self._events)

    def dump(self, path, prefix: Optional[str] = None) -> int:
        """Write the log (optionally filtered to a dotted ``prefix``,
        e.g. ``"page"`` for the allocator op stream) as JSONL.  Returns
        the number of records written."""
        events = (self.records_prefix(prefix) if prefix is not None
                  else self._events)
        with open(path, "w") as f:
            for e in events:
                f.write(json.dumps(e.as_dict()) + "\n")
        return len(events)


#: process-global default log -- what the validators emit into
DEFAULT_LOG = EventLog()


def emit(name: str, **fields) -> Event:
    """Emit into the default log (the validators' entry point)."""
    return DEFAULT_LOG.emit(name, **fields)
