"""SLO burn-rate monitor: observability closing the loop to control.

The paper's economic case is per-request latency and goodput on salvage
boards; PR 7 gave the engine a :class:`~repro.serving.resilience.
DegradationLadder` driven by *page pressure* -- an input-side signal.
This module drives the same ladder from the OUTPUT side: declared
TTFT/tpot objectives, sliding-window violation rates, and the standard
multi-window burn-rate alert (both a short and a long window must burn
faster than ``burn_threshold`` times the error budget before the alert
fires; the short window alone clears it).  Fast regressions page
quickly, slow burns still page, recovered systems de-escalate.

Clock discipline matches the tracer: observations are stamped with the
caller's timestamps (sim seconds in :class:`~repro.fleet.sim.FleetSim`,
the engine's shared host clock in :class:`~repro.serving.engine.
ServeEngine`), so one monitor works on either clock.

Everything is published under the ``slo.*`` namespace: burn-rate
gauges, violation/alert counters, and ``slo.alert`` / ``slo.clear`` /
``slo.escalate`` / ``slo.deescalate`` events.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.obs import events as obs_events

__all__ = ["SLOObjective", "BurnRateMonitor", "SLOController"]


@dataclasses.dataclass(frozen=True)
class SLOObjective:
    """Declared latency objectives and the error budget they carry.

    ``error_budget`` is the fraction of requests ALLOWED to violate the
    objective (0.1: one in ten may miss).  Burn rate 1.0 means the
    budget is being consumed exactly at the sustainable rate; rate N
    exhausts it N times too fast.
    """

    ttft_s: Optional[float] = None
    tpot_s: Optional[float] = None
    error_budget: float = 0.1

    def __post_init__(self):
        if not (0.0 < self.error_budget <= 1.0):
            raise ValueError(f"error_budget must be in (0, 1], got "
                             f"{self.error_budget}")


class _Window:
    """Sliding window of (t, violated) samples with O(1) amortized
    pruning and a running violation count."""

    def __init__(self, width_s: float):
        self.width_s = float(width_s)
        self._samples: Deque[Tuple[float, bool]] = deque()
        self._violations = 0

    def add(self, t: float, violated: bool) -> None:
        self._samples.append((t, violated))
        if violated:
            self._violations += 1
        self.prune(t)

    def prune(self, now: float) -> None:
        cutoff = now - self.width_s
        while self._samples and self._samples[0][0] < cutoff:
            _, v = self._samples.popleft()
            if v:
                self._violations -= 1

    def violation_rate(self, now: float) -> float:
        self.prune(now)
        if not self._samples:
            return 0.0
        return self._violations / len(self._samples)

    def __len__(self) -> int:
        return len(self._samples)


class BurnRateMonitor:
    """Multi-window burn-rate alerting over TTFT/tpot observations.

    Feed :meth:`observe_ttft` / :meth:`observe_tpot` with explicit
    timestamps (any monotone clock).  :meth:`update` recomputes burn
    rates and flips :attr:`alert` with hysteresis: it FIRES when both
    the short and long window burn above ``burn_threshold``, and CLEARS
    when the short window burns below ``clear_threshold`` (the long
    window keeps history of the incident; waiting for it would hold the
    alert long after recovery).
    """

    def __init__(self, objective: SLOObjective,
                 short_window_s: float = 5.0,
                 long_window_s: float = 30.0,
                 burn_threshold: float = 2.0,
                 clear_threshold: float = 1.0,
                 registry=None, name: str = "slo"):
        if short_window_s >= long_window_s:
            raise ValueError("short window must be shorter than long")
        self.objective = objective
        self.short = _Window(short_window_s)
        self.long = _Window(long_window_s)
        self.burn_threshold = float(burn_threshold)
        self.clear_threshold = float(clear_threshold)
        self.registry = registry
        self.name = name
        self.alert = False
        self.alerts_fired = 0
        self.t_last: float = 0.0
        if registry is not None:
            registry.gauge(f"{name}.burn_rate.short",
                           help="short-window error-budget burn rate")
            registry.gauge(f"{name}.burn_rate.long",
                           help="long-window error-budget burn rate")
            registry.counter(f"{name}.violations.ttft")
            registry.counter(f"{name}.violations.tpot")
            registry.counter(f"{name}.alerts")

    # -- feeding --------------------------------------------------------
    def _observe(self, kind: str, violated: bool, t: float) -> None:
        self.t_last = max(self.t_last, t)
        self.short.add(t, violated)
        self.long.add(t, violated)
        if violated and self.registry is not None:
            self.registry.counter(
                f"{self.name}.violations.{kind}").inc()

    def observe_ttft(self, value_s: float, t: float) -> bool:
        """Record one request's TTFT at time ``t``; returns violated.
        A ``None`` objective means TTFT carries no budget: the sample
        is dropped entirely (it must not dilute the tpot burn rate)."""
        lim = self.objective.ttft_s
        if lim is None:
            return False
        violated = value_s > lim
        self._observe("ttft", violated, t)
        return violated

    def observe_tpot(self, value_s: float, t: float) -> bool:
        """Record one seconds/token sample at time ``t`` (dropped when
        the objective declares no tpot target)."""
        lim = self.objective.tpot_s
        if lim is None:
            return False
        violated = value_s > lim
        self._observe("tpot", violated, t)
        return violated

    # -- alerting -------------------------------------------------------
    def burn_rates(self, now: Optional[float] = None
                   ) -> Tuple[float, float]:
        now = self.t_last if now is None else now
        budget = self.objective.error_budget
        return (self.short.violation_rate(now) / budget,
                self.long.violation_rate(now) / budget)

    def update(self, now: Optional[float] = None) -> bool:
        """Recompute burn rates, update the alert state (with
        hysteresis), publish gauges/events.  Returns :attr:`alert`."""
        now = self.t_last if now is None else now
        short_burn, long_burn = self.burn_rates(now)
        if self.registry is not None:
            self.registry.gauge(
                f"{self.name}.burn_rate.short").set(short_burn)
            self.registry.gauge(
                f"{self.name}.burn_rate.long").set(long_burn)
        if not self.alert:
            if (short_burn >= self.burn_threshold
                    and long_burn >= self.burn_threshold):
                self.alert = True
                self.alerts_fired += 1
                if self.registry is not None:
                    self.registry.counter(f"{self.name}.alerts").inc()
                obs_events.emit(f"{self.name}.alert", t=now,
                                short_burn=round(short_burn, 4),
                                long_burn=round(long_burn, 4))
        elif short_burn <= self.clear_threshold:
            self.alert = False
            obs_events.emit(f"{self.name}.clear", t=now,
                            short_burn=round(short_burn, 4),
                            long_burn=round(long_burn, 4))
        return self.alert


class SLOController:
    """Close the loop: burn-rate alerts drive the degradation ladder.

    While the monitor is alerting, :meth:`step` escalates the ladder one
    rung every ``escalate_every_s`` (first escalation immediately); once
    the alert clears, it de-escalates one rung every ``relax_every_s``
    until the ladder is back to normal.  Every action lands in
    :attr:`actions` and as an ``slo.escalate`` / ``slo.deescalate``
    event, so a replay demonstrably shows the observability->control
    loop closing.
    """

    def __init__(self, monitor: BurnRateMonitor, ladder,
                 escalate_every_s: float = 1.0,
                 relax_every_s: float = 2.0):
        self.monitor = monitor
        self.ladder = ladder
        self.escalate_every_s = float(escalate_every_s)
        self.relax_every_s = float(relax_every_s)
        #: (t, "escalate"|"deescalate", new_level_name), newest last
        self.actions: List[Tuple[float, str, str]] = []
        self._t_last_action: Optional[float] = None

    def _due(self, now: float, period_s: float) -> bool:
        return (self._t_last_action is None
                or now - self._t_last_action >= period_s)

    def step(self, now: Optional[float] = None) -> Optional[str]:
        """Update the monitor and apply at most one ladder move.
        Returns the action taken (``"escalate"`` / ``"deescalate"`` /
        ``None``)."""
        now = self.monitor.t_last if now is None else now
        alerting = self.monitor.update(now)
        name = self.monitor.name
        if alerting and self.ladder.level < 3 \
                and self._due(now, self.escalate_every_s):
            self.ladder.escalate(f"{name}_burn")
            self._t_last_action = now
            self.actions.append((now, "escalate", self.ladder.level_name))
            obs_events.emit(f"{name}.escalate", t=now,
                            level=self.ladder.level_name)
            return "escalate"
        if not alerting and self.ladder.level > 0 \
                and self._due(now, self.relax_every_s):
            self.ladder.deescalate(f"{name}_recovered")
            self._t_last_action = now
            self.actions.append((now, "deescalate",
                                 self.ladder.level_name))
            obs_events.emit(f"{name}.deescalate", t=now,
                            level=self.ladder.level_name)
            return "deescalate"
        return None

    @property
    def escalated(self) -> bool:
        return any(a == "escalate" for _, a, _ in self.actions)

    @property
    def deescalated(self) -> bool:
        return any(a == "deescalate" for _, a, _ in self.actions)
