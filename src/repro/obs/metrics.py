"""Typed metrics registry: one namespaced schema for serving telemetry.

Every layer of the serving stack used to keep its own ad-hoc stats dict
(``ServeEngine.stats``, ``PagePool`` counters, ``ModelPool.stats``,
``MultiModelServeEngine.stats``, ``SimNode`` attributes).  The registry
replaces them with three typed instruments under one dot-namespaced
schema (``serve.decode.dispatches``, ``pool.pages.in_use``,
``modelpool.swap_bytes``, ``fleet.preempt.evictions``, ...):

* :class:`Counter` -- monotone event count (resettable for bench reuse);
* :class:`Gauge` -- point-in-time value, either set explicitly or read
  live through a zero-cost callback (``fn=``) so hot paths never pay a
  publish (the page pool's occupancy gauges work this way);
* :class:`Histogram` -- value distribution with exact percentiles (span
  durations are few and host-side, so we keep raw samples rather than
  bucketing).

Exports: :meth:`MetricsRegistry.collect` (plain dict, JSON-friendly)
and :meth:`MetricsRegistry.to_prometheus` (text exposition, counters /
gauges / summaries with p50/p99 quantiles).

Backwards compatibility: :class:`StatsView` is a ``MutableMapping``
facade that maps the legacy stats-dict keys onto registry instruments,
so ``engine.stats["decode_dispatches"] += 1``, ``dict(engine.stats)``,
equality against a plain dict, and the bench's counter-reset idiom
(``engine.stats = {k: 0 for k in engine.stats}``) all keep working
while the values live in the registry.

Zero dependencies beyond the standard library.
"""

from __future__ import annotations

import math
import re
from collections.abc import MutableMapping
from typing import Callable, Dict, Iterator, List, Optional, Union

Number = Union[int, float]


class Counter:
    """Monotone count of events (resettable so benches can re-zero)."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value: Number = 0

    @property
    def value(self) -> Number:
        return self._value

    def inc(self, n: Number = 1) -> None:
        self._value += n

    def set(self, v: Number) -> None:
        """Direct write -- the StatsView compat path and bench resets."""
        self._value = v


class Gauge:
    """Point-in-time value; ``fn`` makes it a live read-through gauge."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 fn: Optional[Callable[[], Number]] = None):
        self.name = name
        self.help = help
        self._fn = fn
        self._value: Number = 0

    @property
    def value(self) -> Number:
        return self._fn() if self._fn is not None else self._value

    def set(self, v: Number) -> None:
        assert self._fn is None, f"{self.name} is a callback gauge"
        self._value = v

    def set_max(self, v: Number) -> None:
        assert self._fn is None, f"{self.name} is a callback gauge"
        self._value = max(self._value, v)


class Histogram:
    """Distribution with exact percentiles over the raw samples."""

    kind = "histogram"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.samples: List[float] = []

    def observe(self, v: Number) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return float(sum(self.samples))

    def percentile(self, q: float) -> float:
        """Exact percentile (linear interpolation); NaN when empty."""
        if not self.samples:
            return float("nan")
        xs = sorted(self.samples)
        if len(xs) == 1:
            return xs[0]
        pos = (q / 100.0) * (len(xs) - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(xs) - 1)
        return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum,
                "p50": self.percentile(50), "p99": self.percentile(99)}


Metric = Union[Counter, Gauge, Histogram]


def _prom_name(name: str) -> str:
    """Dots and other separators become underscores (Prometheus rules)."""
    return re.sub(r"[^a-zA-Z0-9_:]", "_", name)


class MetricsRegistry:
    """Get-or-create registry of named instruments.

    Names are dot-namespaced (``layer.subsystem.metric``); re-requesting
    a name returns the existing instrument (and asserts the kind
    matches), so publishers can be wired independently.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, cls, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, **kw)
            self._metrics[name] = m
        else:
            assert isinstance(m, cls), (
                f"metric {name!r} already registered as {m.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "",
              fn: Optional[Callable[[], Number]] = None) -> Gauge:
        g = self._metrics.get(name)
        if g is None:
            g = Gauge(name, help=help, fn=fn)
            self._metrics[name] = g
        else:
            assert isinstance(g, Gauge), (
                f"metric {name!r} already registered as {g.kind}")
            if fn is not None:
                g._fn = fn
        return g

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(name, Histogram, help=help)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def sum_prefix(self, prefix: str) -> float:
        """Sum of every counter/gauge value under a dotted ``prefix``
        (e.g. ``fleet.faults`` aggregates all fault counters) --
        histograms are skipped, they have no single value."""
        dotted = prefix if prefix.endswith(".") else prefix + "."
        total = 0.0
        for name in self.names():
            if name.startswith(dotted) and not isinstance(
                    self._metrics[name], Histogram):
                total += float(self._metrics[name].value)
        return total

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str) -> Metric:
        return self._metrics[name]

    def collect(self) -> Dict[str, object]:
        """Snapshot every instrument into a JSON-friendly dict
        (histograms fold to count/sum/p50/p99)."""
        out: Dict[str, object] = {}
        for name in self.names():
            m = self._metrics[name]
            out[name] = (m.summary() if isinstance(m, Histogram)
                         else m.value)
        return out

    def to_prometheus(self) -> str:
        """Text exposition: counters, gauges, and summary quantiles."""
        lines: List[str] = []
        for name in self.names():
            m = self._metrics[name]
            pn = _prom_name(name)
            if m.help:
                lines.append(f"# HELP {pn} {m.help}")
            if isinstance(m, Histogram):
                lines.append(f"# TYPE {pn} summary")
                lines.append(f'{pn}{{quantile="0.5"}} {m.percentile(50)}')
                lines.append(f'{pn}{{quantile="0.99"}} {m.percentile(99)}')
                lines.append(f"{pn}_sum {m.sum}")
                lines.append(f"{pn}_count {m.count}")
            else:
                lines.append(f"# TYPE {pn} {m.kind}")
                lines.append(f"{pn} {m.value}")
        return "\n".join(lines) + ("\n" if lines else "")


class StatsView(MutableMapping):
    """Legacy stats-dict facade over registry instruments.

    Maps old flat keys (``"decode_dispatches"``) to registered counters
    / gauges so existing call sites -- ``stats[k] += 1``, ``dict(stats)``,
    ``stats == {...}``, ``stats.items()`` -- keep working unchanged.
    New keys cannot be invented through the view (the schema is the
    registry's), which is what makes the namespace authoritative.
    """

    def __init__(self, registry: MetricsRegistry,
                 keymap: Dict[str, str]):
        self._registry = registry
        self._keymap = dict(keymap)

    def metric(self, key: str) -> Metric:
        return self._registry[self._keymap[key]]

    def metric_name(self, key: str) -> str:
        return self._keymap[key]

    def __getitem__(self, key: str) -> Number:
        return self.metric(key).value

    def __setitem__(self, key: str, value: Number) -> None:
        if key not in self._keymap:
            raise KeyError(
                f"{key!r} is not in the telemetry schema; register it "
                "in the engine's keymap instead of inventing dict keys")
        self.metric(key).set(value)

    def __delitem__(self, key: str) -> None:
        raise TypeError("telemetry schema keys cannot be deleted")

    def __iter__(self) -> Iterator[str]:
        return iter(self._keymap)

    def __len__(self) -> int:
        return len(self._keymap)

    def __eq__(self, other) -> bool:
        if isinstance(other, (dict, StatsView)):
            return dict(self) == dict(other)
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return NotImplemented if eq is NotImplemented else not eq

    def __repr__(self) -> str:  # pragma: no cover
        return f"StatsView({dict(self)!r})"
