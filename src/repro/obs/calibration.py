"""Sim-to-real calibration: fit the phase model against real replays.

The ROADMAP's longest-standing open item: the fleet simulator's phase
model (`repro.serving.phase_model` / `repro.core.perf_model`) predicts
what a board does, the execution-backed replay
(:func:`repro.fleet.execution.run_trace_on_engine`) measures what the
real engine actually did.  This module closes the loop in two parts:

* **Structural replay prediction** (:func:`predict_replay`): a pure-host
  mirror of ``ServeEngine.run``'s scheduling -- FIFO admission gated on
  lanes and page reservations, power-of-two-shrunk ``decode_n`` blocks,
  reserve-then-grow page mapping, boundary retirement.  It predicts the
  replay's dispatch counts, decode steps, generated tokens, page-pool
  high-water mark, and blocked-admission episodes WITHOUT touching jax.
  :func:`calibrate_replay` diffs prediction against measurement and
  gates on relative error: drift between the simulator's scheduling
  model and the real allocator/dispatch trace fails loudly
  (``make bench-smoke``), and a deliberately perturbed model
  (mis-modeled ``dispatch_n`` or page geometry) MUST fail -- that is
  the gate's self-test.
* **Host-time constant fitting** (:func:`fit_dispatch_time_model`):
  least-squares fit of per-dispatch span durations against block size,
  yielding the host overhead per dispatch and seconds per decode step
  the real engine exhibits -- the constants a host-aware
  :class:`~repro.core.perf_model.InferencePerfModel` extension needs.
  These are *reported*, not gated: smoke configs on CPU say nothing
  about CMP 170HX silicon, but the fit wiring is identical when the
  replay runs on the real board.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


def _bucket_len(n: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor) -- must mirror the engine."""
    b = floor
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass(frozen=True)
class PredictedReplay:
    """What the scheduling model says a trace replay will measure."""

    decode_dispatches: int
    decode_steps: int
    generated_tokens: int
    kv_pages_hwm: int
    kv_admit_blocked: int

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def predict_replay(trace: Sequence, *, n_lanes: int, max_len: int,
                   dispatch_n: int = 8, paged: bool = False,
                   page_size: int = 16, n_pages: Optional[int] = None,
                   bt_width: Optional[int] = None) -> PredictedReplay:
    """Predict a ``run_trace_on_engine`` replay's counters host-side.

    ``trace`` is any sequence with ``arrival_s`` / ``uid`` /
    ``prompt_len`` / ``gen_len`` fields (the fleet's
    :class:`~repro.fleet.workload.FleetRequest`).  The model mirrors the
    engine's scheduling exactly for traces whose requests fit the cache
    (``prompt + gen + 1 <= max_len``, the replay regime): admission is
    FIFO over free lanes, gated on page reservations when ``paged``;
    each dispatch advances every live lane ``min(block, remaining)``
    tokens with the block shrunk to a power of two when all live lanes
    owe fewer; pages map reserve-then-grow and free at retirement.

    ``bt_width`` defaults to ``max_len // page_size`` (the non-sliding
    block-table width); pass the engine's value for window configs.
    """
    reqs = [(min(int(r.prompt_len), max_len - 1), int(r.gen_len), r.uid)
            for r in sorted(trace, key=lambda r: (r.arrival_s, r.uid))]
    if paged:
        bt = bt_width if bt_width is not None else max_len // page_size
        pool = n_lanes * bt if n_pages is None else int(n_pages)
    else:
        bt = pool = 0

    def pages(positions: int) -> int:
        if not paged or bt == 0:
            return 0
        return min(-(-int(positions) // page_size), bt)

    lane_rem = [0] * n_lanes          # 0 == free lane
    lane_len = [0] * n_lanes
    lane_mapped = [0] * n_lanes       # pages alloc'd to the lane
    lane_reserved = [0] * n_lanes     # promised but not yet mapped
    in_use = reserved = 0
    hwm = 0
    blocked_uids = set()
    dispatches = steps = generated = blocked = 0
    pending = list(reqs)
    live = 0

    def admit(plen: int, gen: int, uid) -> bool:
        nonlocal in_use, reserved, hwm, blocked, live
        free = [i for i in range(n_lanes) if lane_rem[i] == 0]
        if not free:
            return False
        lane = free[0]
        need = pages(min(plen + gen + 1, max_len))
        if paged:
            if need > pool - in_use - reserved:
                if uid not in blocked_uids:
                    blocked_uids.add(uid)
                    blocked += 1
                return False
            blocked_uids.discard(uid)
            reserved += need
            hwm = max(hwm, in_use + reserved)
            lane_reserved[lane] = need
            take = pages(plen + 1)
            lane_mapped[lane] = take
            lane_reserved[lane] -= take
            in_use += take
            reserved -= take
        lane_len[lane] = plen
        lane_rem[lane] = gen
        live += 1
        return True

    while pending or live:
        while pending and admit(*pending[0]):
            pending.pop(0)
        if live == 0:
            raise RuntimeError(
                "predicted replay livelocked: head request can never be "
                "admitted (mirror of ServeEngine.run's failure mode)")
        max_rem = max(r for r in lane_rem if r > 0)
        n = min(dispatch_n, _bucket_len(max_rem, floor=1))
        for i in range(n_lanes):
            if lane_rem[i] <= 0:
                continue
            gen = min(n, lane_rem[i])
            if paged:
                target = pages(lane_len[i] + gen + 1)
                grow = max(target - lane_mapped[i], 0)
                lane_mapped[i] += grow
                lane_reserved[i] -= grow
                in_use += grow
                reserved -= grow
            lane_rem[i] -= gen
            lane_len[i] += gen
            generated += gen
            if lane_rem[i] <= 0:                  # boundary retirement
                in_use -= lane_mapped[i]
                reserved -= lane_reserved[i]
                lane_mapped[i] = lane_reserved[i] = 0
                lane_len[i] = 0
                live -= 1
        dispatches += 1
        steps += n
    return PredictedReplay(decode_dispatches=dispatches,
                           decode_steps=steps,
                           generated_tokens=generated,
                           kv_pages_hwm=hwm,
                           kv_admit_blocked=blocked)


# ----------------------------------------------------------------------
# fit + gate
# ----------------------------------------------------------------------

def rel_err(sim: float, real: float) -> float:
    """|sim - real| / max(|real|, 1) -- counter-friendly relative error."""
    return abs(float(sim) - float(real)) / max(abs(float(real)), 1.0)


#: replay counters the drift gate checks (the acceptance contract:
#: dispatch counts and the page high-water mark must agree)
GATED_METRICS = ("decode_dispatches", "decode_steps",
                 "generated_tokens", "kv_pages_hwm")


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """Sim-vs-real diff plus fitted host-time constants."""

    tolerance: float
    #: metric -> {"real": measured, "sim": predicted, "rel_err": err}
    metrics: Dict[str, Dict[str, float]]
    #: least-squares host-time constants (reported, not gated)
    fitted: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def max_rel_err(self) -> float:
        return max((m["rel_err"] for m in self.metrics.values()),
                   default=0.0)

    @property
    def ok(self) -> bool:
        return self.max_rel_err <= self.tolerance

    def as_dict(self) -> Dict[str, object]:
        return {"tolerance": self.tolerance, "ok": self.ok,
                "max_rel_err": round(self.max_rel_err, 6),
                "metrics": self.metrics, "fitted": self.fitted}


def fit_linear(xs: Sequence[float],
               ys: Sequence[float]) -> Tuple[float, float]:
    """Least-squares ``y ~= a + b*x``; degenerate x collapses to mean."""
    import numpy as np

    x = np.asarray(xs, np.float64)
    y = np.asarray(ys, np.float64)
    assert x.size == y.size and x.size > 0
    if x.size == 1 or float(np.ptp(x)) == 0.0:
        return float(y.mean()), 0.0
    b, a = np.polyfit(x, y, 1)
    return float(a), float(b)


def fit_dispatch_time_model(spans: Iterable) -> Dict[str, float]:
    """Fit host-time constants from ``decode.dispatch`` span durations.

    ``duration ~= t_dispatch_overhead_s + n_steps * t_per_step_s`` over
    the spans' recorded block sizes -- the host-side analogue of the
    perf model's per-token decode step, measured instead of modeled.
    Returns an empty dict when no dispatch spans were recorded.
    """
    pts: List[Tuple[float, float]] = []
    for s in spans:
        if s.name == "decode.dispatch" and "n_steps" in s.args:
            pts.append((float(s.args["n_steps"]), s.duration_s))
    if not pts:
        return {}
    a, b = fit_linear([p[0] for p in pts], [p[1] for p in pts])
    return {"t_dispatch_overhead_s": a, "t_per_step_s": b,
            "n_spans": float(len(pts))}


def calibrate_replay(real, sim: PredictedReplay,
                     tolerance: float = 0.1,
                     spans: Optional[Iterable] = None,
                     gate_on: Sequence[str] = GATED_METRICS
                     ) -> CalibrationReport:
    """Diff a measured replay against the scheduling model's prediction.

    ``real`` is an :class:`~repro.fleet.execution.ExecutionResult` (or
    anything with the gated counter attributes); ``sim`` comes from
    :func:`predict_replay`.  The report's ``ok`` is the bench-smoke
    drift gate: every gated counter's relative error within
    ``tolerance``.  ``spans`` (optional) adds the fitted host-time
    constants to the report.
    """
    pred = sim.as_dict()
    # ExecutionResult spells one counter differently
    real_attr = {"generated_tokens": "gen_tokens"}
    metrics = {}
    for key in gate_on:
        real_v = float(getattr(real, real_attr.get(key, key)))
        sim_v = float(pred[key])
        metrics[key] = {"real": real_v, "sim": sim_v,
                        "rel_err": round(rel_err(sim_v, real_v), 6)}
    fitted = fit_dispatch_time_model(spans) if spans is not None else {}
    return CalibrationReport(tolerance=tolerance, metrics=metrics,
                             fitted=fitted)
