"""Bounded interleaving checker for the lane/page lifecycle.

The fleet's preemption churn interleaves admit / prefix-hit admit /
copy-on-write / evict / restore / retire / cache-flush in orders no
single test scripts.  This module explores those orderings *bounded
exhaustively* against a REAL :class:`~repro.serving.engine.PagePool`
mirrored by a non-strict :class:`~repro.analysis.sanitizer.
PageSanitizer`: after every op the pool's own conservation invariants,
the shadow model, and a shadow-vs-pool crosscheck must all hold.

An order-dependent allocator bug (e.g. a free that ignores refcounts:
harmless until an interleaving shares the page first) surfaces as an
:class:`InterleavingBug` carrying the exact op trace that triggered it
-- a reproducer, not a flake.  :class:`RefcountBlindPool` is the
seeded bug double the detection tests drive through the explorer.

Not imported by ``repro.analysis.__init__`` (it imports the engine;
the engine imports ``repro.analysis.invariants``) -- import it
explicitly: ``from repro.analysis import interleave``.
"""

from __future__ import annotations

import copy
from typing import Any, Callable, List, Optional, Tuple

from repro.analysis.invariants import InvariantError, invariant
from repro.analysis.sanitizer import PageSanitizer
from repro.serving.engine import PagePool

__all__ = ["LifecycleHarness", "InterleavingBug", "RefcountBlindPool",
           "explore"]

Op = Tuple[str, int]


class InterleavingBug(AssertionError):
    """An op ordering broke a lifecycle invariant.  ``trace`` is the
    exact op sequence -- a deterministic reproducer."""

    def __init__(self, trace: Tuple[Op, ...], cause: BaseException):
        self.trace = trace
        self.cause = cause
        pretty = " -> ".join(f"{name}({arg})" for name, arg in trace)
        super().__init__(f"interleaving bug after [{pretty}]: {cause!r}")


class _Lane:
    __slots__ = ("pages", "reserved")

    def __init__(self):
        self.pages: List[int] = []
        self.reserved = 0

    @property
    def live(self) -> bool:
        return bool(self.pages)


class LifecycleHarness:
    """A miniature engine lifecycle over a real PagePool.

    Each op mirrors the corresponding ``ServeEngine`` path (same
    reserve/alloc/share/cow/free sequencing, same monitor records) at
    page granularity, small enough to explore exhaustively:

    * ``admit``    -- reserve 3, alloc 2 prompt pages, prefill-write;
      the FIRST admit also donates its page 0 to the prefix cache
      (``share`` with holder ``"cache"``);
    * ``hit``      -- prefix-hit admit: share the cached page as block
      0, reserve 2, alloc 1 tail page, prefill-write the tail only;
    * ``cow``      -- copy-on-write split of a shared block 0 from the
      lane's reservation, then the divergent write;
    * ``decode``   -- append to the lane's LAST page (always exclusive
      or owned);
    * ``evict``    -- capture the lane's pages into a checkpoint, then
      free + unreserve (the engine's ``evict`` -> ``_release_lane``);
    * ``restore``  -- re-admit a checkpoint through reserve/alloc and
      a restore-write (the engine's ``restore``);
    * ``retire``   -- free + unreserve without a checkpoint;
    * ``flush``    -- the prefix cache drops its reference (multi-model
      weight-unload path).
    """

    def __init__(self, n_lanes: int = 2, n_pages: int = 6,
                 page_size: int = 4,
                 pool_cls: Callable[..., PagePool] = PagePool):
        self.pool = pool_cls(n_pages, page_size)
        self.san = PageSanitizer(strict=False)
        self.pool.monitor = self.san
        self.san.record("init", n_pages=n_pages, page_size=page_size,
                        scratch=n_pages)
        self.lanes = [_Lane() for _ in range(n_lanes)]
        self.cache_page: Optional[int] = None
        self.ckpts: List[int] = []       # page counts of evicted lanes

    # ------------------------------------------------------------------
    # op enumeration (sorted: exploration order is deterministic)
    # ------------------------------------------------------------------
    def available_ops(self) -> List[Op]:
        ops: List[Op] = []
        for i, lane in enumerate(self.lanes):
            if not lane.live:
                if self.pool.available() >= 3:
                    ops.append(("admit", i))
                if self.cache_page is not None \
                        and self.pool.available() >= 2:
                    ops.append(("hit", i))
                if self.ckpts and \
                        self.pool.available() >= self.ckpts[0] + 1:
                    ops.append(("restore", i))
            else:
                ops.append(("decode", i))
                ops.append(("evict", i))
                ops.append(("retire", i))
                if lane.reserved >= 1 and \
                        self.pool.is_shared(lane.pages[0]):
                    ops.append(("cow", i))
        if self.cache_page is not None:
            ops.append(("flush", 0))
        return sorted(ops)

    def apply(self, op: Op) -> None:
        name, lane = op
        getattr(self, f"_do_{name}")(lane)

    # ------------------------------------------------------------------
    # ops (each mirrors the engine's sequencing)
    # ------------------------------------------------------------------
    def _do_admit(self, i: int) -> None:
        lane = self.lanes[i]
        invariant(self.pool.reserve(3), "admit reserve failed", lane=i)
        lane.reserved = 3
        pages = self.pool.alloc(2, holder=i)
        lane.reserved -= 2
        lane.pages = list(pages)
        self.san.record("map", lane=i, pages=list(pages))
        self.san.record("write", lane=i, pages=list(pages),
                        kind="prefill")
        if self.cache_page is None:
            # the radix cache takes its own reference on the prompt page
            self.pool.share([pages[0]], holder="cache")
            self.cache_page = pages[0]

    def _do_hit(self, i: int) -> None:
        lane = self.lanes[i]
        invariant(self.pool.reserve(2), "hit reserve failed", lane=i)
        lane.reserved = 2
        self.pool.share([self.cache_page], holder=i)
        lane.pages = [self.cache_page]
        self.san.record("map", lane=i, pages=[self.cache_page])
        tail = self.pool.alloc(1, holder=i)
        lane.reserved -= 1
        lane.pages.extend(tail)
        self.san.record("map", lane=i, pages=list(tail))
        self.san.record("write", lane=i, pages=list(tail),
                        kind="prefill")

    def _do_cow(self, i: int) -> None:
        lane = self.lanes[i]
        old = lane.pages[0]
        new = self.pool.cow(old, holder=i)
        lane.reserved -= 1
        lane.pages[0] = new
        self.san.record("write", lane=i, pages=[new], kind="cow_copy")

    def _do_decode(self, i: int) -> None:
        lane = self.lanes[i]
        self.san.record("write", lane=i, pages=[lane.pages[-1]],
                        kind="decode")

    def _do_evict(self, i: int) -> None:
        lane = self.lanes[i]
        self.san.record("capture", lane=i, pages=list(lane.pages))
        self.ckpts.append(len(lane.pages))
        self._release(i)

    def _do_retire(self, i: int) -> None:
        self._release(i)

    def _release(self, i: int) -> None:
        lane = self.lanes[i]
        self.pool.free(lane.pages, holder=i)
        self.pool.unreserve(lane.reserved)
        lane.pages = []
        lane.reserved = 0

    def _do_restore(self, i: int) -> None:
        lane = self.lanes[i]
        n = self.ckpts.pop(0)
        invariant(self.pool.reserve(n + 1), "restore reserve failed",
                  lane=i)
        lane.reserved = n + 1
        pages = self.pool.alloc(n, holder=i)
        lane.reserved -= n
        lane.pages = list(pages)
        self.san.record("map", lane=i, pages=list(pages))
        self.san.record("write", lane=i, pages=list(pages),
                        kind="restore")

    def _do_flush(self, _: int) -> None:
        self.pool.free([self.cache_page], holder="cache")
        self.cache_page = None

    # ------------------------------------------------------------------
    # verification (after every op)
    # ------------------------------------------------------------------
    def verify(self) -> None:
        self.pool.check()
        self.san.crosscheck(self.pool)
        if self.san.violations:
            raise InvariantError(
                "sanitizer violations",
                codes=[v.code for v in self.san.violations],
                detail=[v.message for v in self.san.violations])

    def apply_indices(self, indices) -> int:
        """Drive the harness by choice indices (the Hypothesis entry
        point): each index picks from the current legal-op list.
        Verifies after every op; returns the number of ops applied."""
        applied = 0
        for idx in indices:
            ops = self.available_ops()
            if not ops:
                break
            self.apply(ops[idx % len(ops)])
            self.verify()
            applied += 1
        return applied


def explore(factory: Callable[[], LifecycleHarness],
            depth: int = 4) -> int:
    """Exhaustively explore every legal op interleaving to ``depth``.

    Every reached state is verified (pool invariants + shadow model +
    crosscheck).  Raises :class:`InterleavingBug` with the exact op
    trace on the first violation; returns the number of states visited
    on a clean sweep.
    """
    visited = 0
    stack: List[Tuple[LifecycleHarness, Tuple[Op, ...]]] = \
        [(factory(), ())]
    while stack:
        h, trace = stack.pop()
        visited += 1
        if len(trace) >= depth:
            continue
        for op in h.available_ops():
            h2 = copy.deepcopy(h)
            try:
                h2.apply(op)
                h2.verify()
            except InterleavingBug:
                raise
            except BaseException as e:
                raise InterleavingBug(trace + (op,), e) from e
            stack.append((h2, trace + (op,)))
    return visited


class RefcountBlindPool(PagePool):
    """Seeded bug double: ``free`` physically frees the page no matter
    how many holders remain (the classic pre-refcount allocator).  In
    share-free interleavings it is indistinguishable from the real
    pool; once an interleaving shares a page (prefix-cache donation)
    and one holder releases, the page is re-issued while the other
    holder still maps it -- which is exactly what :func:`explore` must
    catch (detection pinned by the analysis tests)."""

    def free(self, pages: List[int], holder: Any = None) -> None:
        for p in pages:
            invariant(p in self._in_use, f"double free of page {p}")
            del self._refcount[p]
            self._in_use.remove(p)
            self._free.append(p)
            self.free_count += 1
        m = self.monitor
        if m is not None:
            m.record("free", pages=list(pages), holder=holder)
