"""Project lint: repo-specific AST rules for the serving stack.

Generic linters cannot know that a bare ``assert`` in the page
allocator is a latent double-free under ``python -O``, or that an
unsorted ``set`` iteration in the fleet scheduler silently breaks
byte-identical replay.  This pass encodes the project's own invariants
as five rules:

====  ==============================================================
R001  invariant-by-``assert`` in allocator/lifecycle code -- must be
      an always-on :func:`repro.analysis.invariants.invariant` raise
R002  host-sync calls (``.item()``, ``np.asarray``,
      ``block_until_ready``, ``float()``) inside jit/scan dispatch
      regions -- each one is a device round-trip per dispatch
R003  unseeded randomness or wall-clock (``random.*``,
      ``time.time``/``monotonic``/``perf_counter``,
      ``np.random.<fn>`` module-level) in the deterministic sim and
      faults layers; also a MISMATCHED obs clock -- ``time.time`` or
      ``time.monotonic`` injected as a ``clock=`` (tracer spans read
      ``time.perf_counter``; mixing bases skews merged timelines)
R004  bare ``RuntimeError``/``Exception`` raised in serving paths --
      use structured exceptions (``AdmissionRejected``,
      ``InvariantError``) the fleet can route on
R005  unsorted iteration over sets (scheduling layers) or dict views
      (``FleetSim``) that feeds sim event order or lane scheduling
====  ==============================================================

Suppression: append ``# lint: ok R003 <reason>`` to the flagged line
(or the line above).  A suppression without a reason is itself a
finding.  Run::

    python -m repro.analysis.lint src/ [--json]

Exit status is 0 iff there are no unsuppressed findings.  The JSON
report (``--json``) is machine-readable: one object per finding with
``rule``, ``path``, ``line``, ``message``, ``suppressed``, ``reason``.
"""

from __future__ import annotations

import ast
import json
import re
import sys
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Set

__all__ = ["Finding", "lint_source", "lint_paths", "main", "RULES"]

RULES = {
    "R001": "bare assert in allocator/lifecycle code (stripped by -O)",
    "R002": "host sync inside a jit/scan dispatch region",
    "R003": "unseeded randomness or wall-clock in deterministic layers",
    "R004": "bare RuntimeError/Exception raised in a serving path",
    "R005": "unsorted set/dict iteration feeding event order",
}

# Which files each rule patrols, by path suffix (POSIX, relative or
# absolute).  Synthetic test snippets opt in via lint_source(rules=...).
RULE_PATHS = {
    "R001": ("serving/engine.py", "serving/prefix_cache.py",
             "serving/modelpool.py"),
    "R002": ("serving/engine.py",),
    "R003": ("fleet/", "obs/", "serving/"),
    "R004": ("serving/", "fleet/execution.py"),
    "R005": ("fleet/", "serving/engine.py", "serving/modelpool.py",
             "serving/prefix_cache.py"),
}
# R005's dict-view half (.keys()/.values()/.items() iteration) only
# matters where dict order feeds a global event heap:
R005_DICTVIEW_PATHS = ("fleet/sim.py",)
# R003's wall-clock-CALL half stays scoped to the deterministic sim
# layer; the obs-clock-MISMATCH half patrols the whole R003 list:
R003_WALLCLOCK_PATHS = ("fleet/",)
# clock bases that skew against the tracer's time.perf_counter
_MISMATCHED_CLOCKS = ("time.time", "time.monotonic")

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*ok\s+(R\d{3})\b\s*(.*)")

_HOST_SYNC_ATTRS = {"item", "block_until_ready"}
_WALLCLOCK_TIME = {"time", "monotonic", "perf_counter", "time_ns",
                   "monotonic_ns", "perf_counter_ns"}
_SORT_WRAPPERS = {"sorted", "list", "tuple", "min", "max", "len", "sum",
                  "any", "all", "set", "frozenset", "enumerate"}


@dataclass
class Finding:
    rule: str
    path: str
    line: int
    message: str
    suppressed: bool = False
    reason: str = ""

    def as_dict(self) -> Dict[str, Any]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "suppressed": self.suppressed,
                "reason": self.reason}


def _rule_applies(rule: str, path: str) -> bool:
    posix = Path(path).as_posix()
    return any(pat in posix for pat in RULE_PATHS[rule])


def _call_name(node: ast.Call) -> str:
    """Dotted name of a call target, best effort ('' if dynamic)."""
    return _dotted(node.func)


def _dotted(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str, rules: Sequence[str]):
        self.path = path
        self.lines = source.splitlines()
        self.rules = set(rules)
        self.findings: List[Finding] = []
        # R002: names of functions fed to jax.jit / jax.lax.scan
        self._dispatch_fns: Set[str] = set()
        self._dispatch_lambdas: List[ast.Lambda] = []
        # R005: names statically known to be sets
        self._set_names: Set[str] = set()
        self._fn_stack: List[str] = []

    # ------------------------------------------------------------------
    def _flag(self, rule: str, line: int, message: str) -> None:
        if rule not in self.rules:
            return
        sup, reason = self._suppression(rule, line)
        self.findings.append(Finding(rule=rule, path=self.path, line=line,
                                     message=message, suppressed=sup,
                                     reason=reason))

    def _suppression(self, rule: str, line: int):
        for ln in (line, line - 1):
            if 1 <= ln <= len(self.lines):
                m = _SUPPRESS_RE.search(self.lines[ln - 1])
                if m and m.group(1) == rule:
                    reason = m.group(2).strip()
                    if not reason:
                        # reasonless suppression: keep it a finding
                        return False, ""
                    return True, reason
        return False, ""

    # ------------------------------------------------------------------
    # two-pass drive: collect dispatch targets + set names, then visit
    # ------------------------------------------------------------------
    def run(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                self._collect_dispatch(node)
            self._collect_set_name(node)
        self.visit(tree)
        for lam in self._dispatch_lambdas:
            self._check_host_sync(lam)

    def _collect_dispatch(self, node: ast.Call) -> None:
        name = _call_name(node)
        if name.endswith("jax.jit") or name == "jit" \
                or name.endswith("lax.scan"):
            for arg in node.args[:1]:
                self._note_dispatch_target(arg)
            for kw in node.keywords:
                if kw.arg in ("fun", "f"):
                    self._note_dispatch_target(kw.value)

    def _note_dispatch_target(self, arg: ast.AST) -> None:
        if isinstance(arg, ast.Lambda):
            self._dispatch_lambdas.append(arg)
        elif isinstance(arg, (ast.Name, ast.Attribute)):
            self._dispatch_fns.add(_dotted(arg).split(".")[-1])
        elif isinstance(arg, ast.Call) and \
                _call_name(arg).endswith("partial") and arg.args:
            self._note_dispatch_target(arg.args[0])

    def _collect_set_name(self, node: ast.AST) -> None:
        target: Optional[ast.AST] = None
        value: Optional[ast.AST] = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target, value = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign):
            target = node.target
            ann = _dotted(node.annotation)
            if ann in ("set", "Set", "typing.Set", "FrozenSet",
                       "frozenset"):
                self._set_names.add(_dotted(target))
                return
            if isinstance(node.annotation, ast.Subscript) and \
                    _dotted(node.annotation.value) in (
                        "set", "Set", "typing.Set", "FrozenSet",
                        "frozenset"):
                self._set_names.add(_dotted(target))
                return
            value = node.value
        if target is None or value is None:
            return
        is_set = isinstance(value, (ast.Set, ast.SetComp)) or (
            isinstance(value, ast.Call)
            and _call_name(value) in ("set", "frozenset"))
        if is_set:
            self._set_names.add(_dotted(target))

    # ------------------------------------------------------------------
    # R001: bare assert
    # ------------------------------------------------------------------
    def visit_Assert(self, node: ast.Assert) -> None:
        self._flag("R001", node.lineno,
                   "bare assert (stripped under -O); use "
                   "repro.analysis.invariants.invariant(...)")
        self.generic_visit(node)

    # ------------------------------------------------------------------
    # R002: host sync inside dispatch regions
    # ------------------------------------------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._fn_stack.append(node.name)
        if node.name in self._dispatch_fns:
            self._check_host_sync(node)
        self._check_clock_defaults(node)
        self.generic_visit(node)
        self._fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def _check_host_sync(self, fn: ast.AST) -> None:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            leaf = name.split(".")[-1]
            if leaf in _HOST_SYNC_ATTRS and "." in name:
                self._flag("R002", node.lineno,
                           f"host sync `{name}()` inside a dispatch "
                           "region")
            elif name in ("np.asarray", "numpy.asarray", "float"):
                self._flag("R002", node.lineno,
                           f"host transfer `{name}()` inside a "
                           "dispatch region")

    # ------------------------------------------------------------------
    # R003 / R004: call + raise checks
    # ------------------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        name = _call_name(node)
        if name.startswith("random.") or name in ("random",):
            self._flag("R003", node.lineno,
                       f"unseeded stdlib randomness `{name}()` in a "
                       "deterministic layer")
        elif name.startswith("np.random.") or \
                name.startswith("numpy.random."):
            leaf = name.split(".")[-1]
            if leaf not in ("default_rng", "Generator", "SeedSequence",
                            "PCG64"):
                self._flag("R003", node.lineno,
                           f"module-level numpy randomness `{name}()`; "
                           "thread a seeded default_rng instead")
        elif name.startswith("time.") and \
                name.split(".")[-1] in _WALLCLOCK_TIME:
            posix = Path(self.path).as_posix()
            if any(pat in posix for pat in R003_WALLCLOCK_PATHS) or \
                    self.path == "<snippet>":
                self._flag("R003", node.lineno,
                           f"wall-clock `{name}()` in a deterministic "
                           "layer")
        for kw in node.keywords:
            if kw.arg == "clock" and \
                    _dotted(kw.value) in _MISMATCHED_CLOCKS:
                self._flag("R003", node.lineno,
                           f"obs clock mismatch: `{_dotted(kw.value)}` "
                           "injected as clock= (tracer spans read "
                           "time.perf_counter; share one clock base)")
        self.generic_visit(node)

    def _check_clock_defaults(self, node: ast.FunctionDef) -> None:
        args = node.args
        params = args.posonlyargs + args.args
        defaults = args.defaults
        bound = params[len(params) - len(defaults):]
        for param, default in list(zip(bound, defaults)) + [
                (p, d) for p, d in zip(args.kwonlyargs, args.kw_defaults)
                if d is not None]:
            if param.arg == "clock" and \
                    _dotted(default) in _MISMATCHED_CLOCKS:
                self._flag("R003", default.lineno,
                           f"obs clock mismatch: parameter default "
                           f"`clock={_dotted(default)}` (tracer spans "
                           "read time.perf_counter; share one clock "
                           "base)")

    def visit_Raise(self, node: ast.Raise) -> None:
        exc = node.exc
        name = ""
        if isinstance(exc, ast.Call):
            name = _call_name(exc)
        elif exc is not None:
            name = _dotted(exc)
        if name in ("RuntimeError", "Exception"):
            self._flag("R004", node.lineno,
                       f"bare `{name}` raised in a serving path; use a "
                       "structured exception (AdmissionRejected, "
                       "InvariantError, ...)")
        self.generic_visit(node)

    # ------------------------------------------------------------------
    # R005: unsorted set/dict-view iteration
    # ------------------------------------------------------------------
    def visit_For(self, node: ast.For) -> None:
        self._check_iteration(node.iter)
        self.generic_visit(node)

    def visit_comprehension(self, node: ast.comprehension) -> None:
        self._check_iteration(node.iter)
        self.generic_visit(node)

    def _check_iteration(self, it: ast.AST) -> None:
        # sorted(...)/list(...)/... wrappers neutralize the hazard
        if isinstance(it, ast.Call) and _call_name(it) in _SORT_WRAPPERS:
            return
        name = _dotted(it)
        if name and name in self._set_names:
            self._flag("R005", it.lineno,
                       f"iteration over set `{name}` feeds event/lane "
                       "order; wrap in sorted(...)")
            return
        if isinstance(it, ast.Call) and \
                _call_name(it).split(".")[-1] in ("keys", "values",
                                                  "items"):
            posix = Path(self.path).as_posix()
            if any(pat in posix for pat in R005_DICTVIEW_PATHS) or \
                    self.path == "<snippet>":
                self._flag("R005", it.lineno,
                           f"iteration over dict view "
                           f"`{_call_name(it)}()` feeds event order; "
                           "wrap in sorted(...)")


def lint_source(source: str, path: str = "<snippet>",
                rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one source string.  ``rules=None`` applies each rule iff
    ``path`` matches its patrol list (``<snippet>`` matches all)."""
    if rules is None:
        if path == "<snippet>":
            rules = list(RULES)
        else:
            rules = [r for r in RULES if _rule_applies(r, path)]
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(rule="PARSE", path=path, line=e.lineno or 0,
                        message=f"syntax error: {e.msg}")]
    linter = _Linter(path=path, source=source, rules=rules)
    linter.run(tree)
    linter.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return linter.findings


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    """Lint every ``*.py`` under the given files/directories."""
    files: List[Path] = []
    for p in paths:
        pp = Path(p)
        if pp.is_dir():
            files.extend(sorted(pp.rglob("*.py")))
        elif pp.suffix == ".py":
            files.append(pp)
    findings: List[Finding] = []
    for f in files:
        findings.extend(lint_source(f.read_text(), path=str(f)))
    return findings


def report(findings: List[Finding], as_json: bool = False) -> str:
    if as_json:
        return json.dumps({
            "rules": RULES,
            "n_findings": len(findings),
            "n_unsuppressed": sum(not f.suppressed for f in findings),
            "findings": [f.as_dict() for f in findings],
        }, indent=2)
    lines = []
    for f in findings:
        mark = f" [suppressed: {f.reason}]" if f.suppressed else ""
        lines.append(f"{f.path}:{f.line}: {f.rule} {f.message}{mark}")
    open_n = sum(not f.suppressed for f in findings)
    lines.append(f"{len(findings)} finding(s), {open_n} unsuppressed")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    as_json = "--json" in argv
    paths = [a for a in argv if not a.startswith("--")] or ["src/"]
    findings = lint_paths(paths)
    print(report(findings, as_json=as_json))
    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
