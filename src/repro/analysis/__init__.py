"""repro.analysis: project lint, page-lifecycle sanitizer, invariants.

Three parts (see ``docs/lint_rules.md`` and the README's "Static
analysis & sanitizers" section):

* :mod:`repro.analysis.invariants` -- :class:`InvariantError` /
  :func:`invariant`: always-on structured replacements for the bare
  ``assert`` invariants in allocator/lifecycle code;
* :mod:`repro.analysis.lint` -- repo-specific AST rules R001-R005
  (``python -m repro.analysis.lint src/``);
* :mod:`repro.analysis.sanitizer` -- :class:`PageSanitizer`, the
  shadow-state model behind ``ServeEngine(sanitize=True)`` and the
  offline ``pages.jsonl`` replay;
* :mod:`repro.analysis.interleave` -- the bounded lifecycle
  interleaving explorer.  NOT imported here (it imports the engine,
  which imports ``invariants``); import it explicitly.
"""

from repro.analysis.invariants import InvariantError, invariant
from repro.analysis.sanitizer import (PageSanitizer, SanitizerError,
                                      Violation, load_jsonl)

__all__ = ["InvariantError", "invariant", "PageSanitizer",
           "SanitizerError", "Violation", "load_jsonl"]
