"""Page-lifecycle sanitizer: a shadow-state model of the paged KV pool.

The exactness tests catch *symptoms* of allocator misuse (a corrupted
token stream); this module catches *causes*, at the op where they
happen.  A :class:`PageSanitizer` mirrors every allocator and lane
lifecycle operation against its own shadow copy of the
:class:`~repro.serving.engine.PagePool` state and flags:

* ``DOUBLE_FREE`` -- a page freed more often than it was held;
* ``SCRATCH_PAGE`` -- the dead-lane scratch page allocated, shared,
  freed, written, or captured into a checkpoint (it is plumbing, not
  request state -- see ROADMAP PR 4);
* ``ALIAS_EXCLUSIVE`` -- a lane maps a page into its block table
  without a recorded ``share``: two block tables would alias bytes the
  refcount believes are exclusively owned;
* ``WRITE_SHARED_NO_COW`` -- a holder that is not the page's original
  owner appends to a shared page without a preceding copy-on-write
  split (the donor itself MAY keep appending to its partial page: its
  writes land at slots beyond every consumer's matched length);
* ``ALLOC_UNRESERVED`` / ``RESERVE_UNDERFLOW`` -- reserve/alloc
  accounting imbalance (an alloc or cow not backed by an admission-time
  reservation, or an unreserve exceeding what was promised);
* ``SHARE_FREE`` / ``COW_EXCLUSIVE`` / ``UNKNOWN_PAGE`` -- refcount
  misuse (sharing a free page, cow of a sole-owner page, ops naming
  pages outside the pool);
* ``CONSERVATION`` -- the shadow state and the REAL pool disagree
  (:meth:`crosscheck`, run at every dispatch boundary when inline).

Two modes:

* **inline** -- ``ServeEngine(sanitize=True)`` attaches a sanitizer as
  ``pool.monitor``; every ``PagePool`` mutator forwards its op through
  one attribute check (``if self.monitor is not None``), which is the
  entire cost of the OFF mode.  Inline violations raise
  :class:`SanitizerError` at the faulting op.
* **offline** -- the same op stream is recorded as ``page.*`` events
  (:class:`repro.obs.EventLog`); dump it with ``EventLog.dump`` and
  replay the ``pages.jsonl`` later with :meth:`PageSanitizer.replay`,
  which collects violations instead of raising.

Op schema (the ``pages.jsonl`` contract): every record carries ``op``
plus the fields listed in :data:`OP_FIELDS`.  Holder tags are opaque
(the engine uses lane indices, the prefix cache uses ``"cache"``).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Optional, Set

from repro.analysis.invariants import InvariantError

__all__ = ["PageSanitizer", "SanitizerError", "Violation", "VIOLATIONS",
           "load_jsonl"]


#: violation code -> meaning (the catalog the mutation tests pin)
VIOLATIONS = {
    "DOUBLE_FREE": "page freed more often than it was held",
    "SCRATCH_PAGE": "scratch page allocated/shared/freed/written/captured",
    "ALIAS_EXCLUSIVE": "lane maps a page it never allocated or shared",
    "WRITE_SHARED_NO_COW": "non-owner write to a shared page without CoW",
    "ALLOC_UNRESERVED": "alloc/cow not backed by a reservation",
    "RESERVE_UNDERFLOW": "unreserve exceeds the outstanding reservation",
    "SHARE_FREE": "share of a page that is not allocated",
    "COW_EXCLUSIVE": "copy-on-write split of a sole-owner page",
    "UNKNOWN_PAGE": "op names a page id outside the pool",
    "CONSERVATION": "shadow state disagrees with the real pool",
}

#: op name -> fields it carries (documentation + replay validation)
OP_FIELDS = {
    "init": ("n_pages", "page_size", "scratch"),
    "reserve": ("n", "ok"),
    "unreserve": ("n",),
    "alloc": ("pages", "holder"),
    "free": ("pages", "holder"),
    "share": ("pages", "holder"),
    "cow": ("old", "new", "holder"),
    "shrink": ("pages",),
    "grow": ("pages",),
    "map": ("lane", "pages"),
    "write": ("lane", "pages", "kind"),
    "capture": ("lane", "pages"),
}


class SanitizerError(InvariantError):
    """An inline (strict-mode) sanitizer violation."""

    def __init__(self, violation: "Violation"):
        super().__init__(f"[{violation.code}] {violation.message}",
                         **violation.op)
        self.violation = violation


@dataclasses.dataclass(frozen=True)
class Violation:
    """One detected lifecycle violation: code, detail, faulting op."""

    code: str
    message: str
    op: Dict[str, Any]

    def as_dict(self) -> Dict[str, Any]:
        return {"code": self.code, "message": self.message, "op": self.op}


class PageSanitizer:
    """Shadow-state mirror of one PagePool + its lanes' block tables.

    Feed ops via :meth:`record` (the ``PagePool.monitor`` hook calls it
    for allocator ops; the engine calls it for map/write/capture).  In
    ``strict`` mode the first violation raises; otherwise violations
    accumulate in :attr:`violations` (the replay mode).
    """

    def __init__(self, strict: bool = True, log=None):
        self.strict = strict
        #: optional :class:`repro.obs.EventLog`; every op is emitted as
        #: a ``page.<op>`` event for offline replay
        self.log = log
        self.violations: List[Violation] = []
        self.ops_seen = 0
        # shadow pool state
        self.n_pages = 0
        self.page_size = 0
        self.scratch: Optional[int] = None
        self._free: Set[int] = set()
        self._disabled: Set[int] = set()
        self._ref: Dict[int, int] = {}
        self._reserved = 0
        # lifecycle state: who allocated a page (its writer of record)
        # and who currently holds a reference on it
        self._owner: Dict[int, Any] = {}
        self._holders: Dict[int, Set[Any]] = {}

    # ------------------------------------------------------------------
    # violation plumbing
    # ------------------------------------------------------------------
    def _flag(self, code: str, message: str, op: Dict[str, Any]) -> None:
        v = Violation(code=code, message=message, op=dict(op))
        self.violations.append(v)
        if self.strict:
            raise SanitizerError(v)

    @property
    def clean(self) -> bool:
        return not self.violations

    # ------------------------------------------------------------------
    # op entry points
    # ------------------------------------------------------------------
    def record(self, op: str, **fields: Any) -> None:
        """Apply one lifecycle op to the shadow state and check it."""
        rec = {"op": op, **fields}
        self.ops_seen += 1
        if self.log is not None:
            self.log.emit(f"page.{op}", **fields)
        handler = getattr(self, f"_op_{op}", None)
        if handler is None:
            self._flag("UNKNOWN_PAGE", f"unknown op {op!r}", rec)
            return
        handler(rec)

    # hook signature PagePool.monitor expects
    pool_op = record

    # ------------------------------------------------------------------
    # shadow transitions
    # ------------------------------------------------------------------
    def _op_init(self, rec) -> None:
        self.n_pages = int(rec["n_pages"])
        self.page_size = int(rec["page_size"])
        self.scratch = rec.get("scratch")
        self._free = set(range(self.n_pages))
        self._disabled = set()
        self._ref = {}
        self._owner = {}
        self._holders = {}
        self._reserved = 0

    def _known(self, page: int, rec) -> bool:
        if page == self.scratch:
            self._flag("SCRATCH_PAGE", VIOLATIONS["SCRATCH_PAGE"], rec)
            return False
        if not (0 <= int(page) < self.n_pages):
            self._flag("UNKNOWN_PAGE",
                       f"page {page} outside pool of {self.n_pages}", rec)
            return False
        return True

    def _op_reserve(self, rec) -> None:
        if rec.get("ok", True):
            self._reserved += int(rec["n"])
            if self._reserved > len(self._free):
                self._flag("ALLOC_UNRESERVED",
                           "reservation exceeds the free list", rec)

    def _op_unreserve(self, rec) -> None:
        n = int(rec["n"])
        if not 0 <= n <= self._reserved:
            self._flag("RESERVE_UNDERFLOW", VIOLATIONS["RESERVE_UNDERFLOW"],
                       rec)
            self._reserved = max(self._reserved - n, 0)
            return
        self._reserved -= n

    def _op_alloc(self, rec) -> None:
        pages = list(rec["pages"])
        holder = rec.get("holder")
        if len(pages) > self._reserved:
            self._flag("ALLOC_UNRESERVED", VIOLATIONS["ALLOC_UNRESERVED"],
                       rec)
        self._reserved = max(self._reserved - len(pages), 0)
        for p in pages:
            if not self._known(p, rec):
                continue
            if p not in self._free:
                self._flag("UNKNOWN_PAGE",
                           f"alloc of page {p} that is not free", rec)
                continue
            self._free.discard(p)
            self._ref[p] = 1
            self._owner[p] = holder
            self._holders[p] = {holder} if holder is not None else set()

    def _op_free(self, rec) -> None:
        holder = rec.get("holder")
        for p in list(rec["pages"]):
            if p == self.scratch:
                self._flag("SCRATCH_PAGE", VIOLATIONS["SCRATCH_PAGE"], rec)
                continue
            if p not in self._ref:
                self._flag("DOUBLE_FREE", f"free of page {p} with no "
                           "outstanding reference", rec)
                continue
            self._ref[p] -= 1
            if holder is not None:
                self._holders.get(p, set()).discard(holder)
            if self._ref[p] == 0:
                del self._ref[p]
                self._owner.pop(p, None)
                self._holders.pop(p, None)
                self._free.add(p)

    def _op_share(self, rec) -> None:
        holder = rec.get("holder")
        for p in list(rec["pages"]):
            if not self._known(p, rec):
                continue
            if p not in self._ref:
                self._flag("SHARE_FREE", f"share of free page {p}", rec)
                continue
            self._ref[p] += 1
            if holder is not None:
                self._holders.setdefault(p, set()).add(holder)

    def _op_cow(self, rec) -> None:
        old, new = rec["old"], rec["new"]
        holder = rec.get("holder")
        if self._known(old, rec):
            if self._ref.get(old, 0) < 2:
                self._flag("COW_EXCLUSIVE", VIOLATIONS["COW_EXCLUSIVE"],
                           rec)
            else:
                self._ref[old] -= 1
                if holder is not None:
                    self._holders.get(old, set()).discard(holder)
        if self._reserved < 1:
            self._flag("ALLOC_UNRESERVED", "cow without a reservation",
                       rec)
        else:
            self._reserved -= 1
        if self._known(new, rec):
            if new not in self._free:
                self._flag("UNKNOWN_PAGE",
                           f"cow target {new} is not free", rec)
            else:
                self._free.discard(new)
                self._ref[new] = 1
                self._owner[new] = holder
                self._holders[new] = ({holder} if holder is not None
                                      else set())

    def _op_shrink(self, rec) -> None:
        for p in list(rec["pages"]):
            if not self._known(p, rec):
                continue
            if p not in self._free:
                self._flag("UNKNOWN_PAGE",
                           f"shrink retired non-free page {p}", rec)
                continue
            self._free.discard(p)
            self._disabled.add(p)

    def _op_grow(self, rec) -> None:
        for p in list(rec["pages"]):
            if not self._known(p, rec):
                continue
            if p not in self._disabled:
                self._flag("UNKNOWN_PAGE",
                           f"grow returned non-disabled page {p}", rec)
                continue
            self._disabled.discard(p)
            self._free.add(p)

    def _op_map(self, rec) -> None:
        """A lane wrote page ids into its block-table row; each mapped
        page must carry the lane's reference (alloc'd by it or shared
        to it) -- otherwise two block tables alias exclusive bytes."""
        lane = rec["lane"]
        for p in list(rec["pages"]):
            if not self._known(p, rec):
                continue
            if lane not in self._holders.get(p, set()):
                self._flag("ALIAS_EXCLUSIVE",
                           f"lane {lane} maps page {p} without holding "
                           "a reference", rec)

    def _op_write(self, rec) -> None:
        """A holder appended KV into pages.  Writes to an exclusively
        owned page are always fine; writes to a SHARED page are legal
        only for its owner of record (the donor appending past every
        consumer's matched length) or as the copy half of a CoW split
        (``kind="cow_copy"`` targets the fresh exclusive page)."""
        lane = rec["lane"]
        for p in list(rec["pages"]):
            if p == self.scratch:
                self._flag("SCRATCH_PAGE",
                           f"write to the scratch page by lane {lane}",
                           rec)
                continue
            if p not in self._ref:
                self._flag("UNKNOWN_PAGE",
                           f"write to unallocated page {p}", rec)
                continue
            if lane not in self._holders.get(p, set()):
                self._flag("ALIAS_EXCLUSIVE",
                           f"lane {lane} writes page {p} without holding "
                           "a reference", rec)
                continue
            if self._ref[p] >= 2 and self._owner.get(p) != lane:
                self._flag("WRITE_SHARED_NO_COW",
                           f"lane {lane} writes shared page {p} owned by "
                           f"{self._owner.get(p)!r}", rec)

    def _op_capture(self, rec) -> None:
        """Evict gathered a lane's pages into a checkpoint; the scratch
        page must never travel (it is not request state)."""
        for p in list(rec["pages"]):
            if p == self.scratch:
                self._flag("SCRATCH_PAGE",
                           "scratch page captured into a checkpoint", rec)

    # ------------------------------------------------------------------
    # cross-checking and replay
    # ------------------------------------------------------------------
    def crosscheck(self, pool) -> None:
        """Compare the shadow against the REAL pool (dispatch-boundary
        hook): free set, refcounts, reservation, disabled count."""
        rec = {"op": "crosscheck"}
        if set(pool._free) != self._free:
            self._flag("CONSERVATION",
                       f"free set mismatch: pool={sorted(pool._free)} "
                       f"shadow={sorted(self._free)}", rec)
        if pool._refcount != self._ref:
            self._flag("CONSERVATION",
                       f"refcount mismatch: pool={pool._refcount} "
                       f"shadow={self._ref}", rec)
        if pool._reserved != self._reserved:
            self._flag("CONSERVATION",
                       f"reservation mismatch: pool={pool._reserved} "
                       f"shadow={self._reserved}", rec)
        if set(pool._disabled) != self._disabled:
            self._flag("CONSERVATION",
                       f"disabled mismatch: pool={sorted(pool._disabled)} "
                       f"shadow={sorted(self._disabled)}", rec)

    @classmethod
    def replay(cls, records: Iterable[Dict[str, Any]]) -> "PageSanitizer":
        """Offline mode: feed a recorded op stream (e.g. a loaded
        ``pages.jsonl``) through a non-strict sanitizer and return it
        with :attr:`violations` collected."""
        san = cls(strict=False)
        for rec in records:
            rec = dict(rec)
            name = rec.pop("op", None)
            if name is None:
                # EventLog records carry the op as "page.<op>"
                name = str(rec.pop("name", "")).split(".", 1)[-1]
            rec.pop("t", None)
            san.record(name, **rec)
        return san


def load_jsonl(path) -> List[Dict[str, Any]]:
    """Load a recorded ``pages.jsonl`` op stream (one op per line)."""
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
