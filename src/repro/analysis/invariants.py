"""Always-on structured invariants for allocator / lifecycle code.

The page allocator and lane lifecycle used to enforce their invariants
with bare ``assert`` statements -- stripped to nothing under
``python -O``, which is exactly the mode a throughput deployment might
run in.  A silent double-free or shared-page write corrupts every
stream sharing that page; the check that would have caught it must not
be optional.

:func:`invariant` is the replacement: an ordinary ``if``/``raise``
(nothing the interpreter can strip) raising :class:`InvariantError`
with the failed condition's context attached as structured fields.

:class:`InvariantError` deliberately subclasses ``AssertionError`` --
the same compatibility move :class:`~repro.serving.resilience.
AdmissionRejected` made for ``RuntimeError``: every pre-existing
``except AssertionError`` / ``pytest.raises(AssertionError)`` call
site written against the bare asserts keeps working, while new callers
read ``.context`` instead of parsing the message.  Unlike a bare
assert, it is raised unconditionally.

Lint rule R001 (``repro.analysis.lint``) flags any bare ``assert``
remaining in the allocator/lifecycle modules.
"""

from __future__ import annotations

from typing import Any, Dict

__all__ = ["InvariantError", "invariant"]


class InvariantError(AssertionError):
    """A runtime invariant does not hold.

    Structured fields:

    * ``message`` -- the human-readable statement of the invariant;
    * ``context`` -- the values that witnessed the violation (page ids,
      refcounts, reservation counters ...), attached as a dict so a
      fleet supervisor can log them without parsing the string.
    """

    def __init__(self, message: str, **context: Any):
        self.message = message
        self.context: Dict[str, Any] = dict(context)
        if context:
            detail = ", ".join(f"{k}={v!r}" for k, v in context.items())
            message = f"{message} ({detail})"
        super().__init__(message)


def invariant(cond: Any, message: str, **context: Any) -> None:
    """Raise :class:`InvariantError` unless ``cond`` is truthy.

    A plain ``if``/``raise`` -- survives ``python -O`` (pinned by the
    assertions-disabled subprocess test in ``tests/test_analysis.py``).
    """
    if not cond:
        raise InvariantError(message, **context)
