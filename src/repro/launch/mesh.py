"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A function, not a module-level constant: importing this module never
touches jax device state (required so smoke tests see 1 device).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} are "
            "available -- run under dryrun.py (which forces 512 host "
            "devices) or set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={n}")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for multi-device unit tests (subprocess-forced devices)."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
