"""Training launcher: ``python -m repro.launch.train --arch olmo-1b ...``.

Single-host it runs a real training loop (smoke/reduced or full config);
on a TPU slice the same script runs under the production mesh with the
FSDP+TP shardings of ``repro.parallel`` (``--mesh data,model``).  Wires
in the data pipeline, async checkpointing, straggler monitor, and
resume-from-latest.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, restore_latest
from repro.configs import get_config
from repro.data import DataConfig, DataLoader
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.parallel.sharding import (batch_shardings, param_shardings,
                                     use_mesh)
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.train.fault_tolerance import StragglerMonitor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '4,2' -> (data=4, model=2) over local devices")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps),
        remat=not args.smoke, microbatches=args.microbatches)
    step_fn = make_train_step(cfg, tcfg)

    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        names = ("data", "model")[:len(shape)]
        mesh = jax.make_mesh(shape, names,
                             devices=jax.devices()[:int(np.prod(shape))])

    state = init_train_state(model, jax.random.PRNGKey(0))
    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        got, restored = restore_latest(args.ckpt_dir, state)
        if got is not None:
            start, state = got, restored
            print(f"resumed from step {start}")

    if mesh is not None:
        sh = param_shardings(mesh, state)
        step_fn = jax.jit(step_fn, in_shardings=(sh, None),
                          out_shardings=(sh, None), donate_argnums=(0,))
        state = jax.device_put(state, sh)
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    data = DataLoader(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                 global_batch=args.batch), start_step=start)
    monitor = StragglerMonitor(n_hosts=jax.process_count())
    t_tokens = args.batch * args.seq

    ctx = use_mesh(mesh) if mesh is not None else _nullctx()
    with ctx:
        t_last = time.time()
        for i, batch in zip(range(start, args.steps), data):
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, jb)
            if (i + 1) % args.log_every == 0 or i == start:
                loss = float(metrics["loss"])
                dt = time.time() - t_last
                t_last = time.time()
                monitor.record(jax.process_index(), dt)
                print(f"step {i+1:5d} loss {loss:.4f} "
                      f"({t_tokens * args.log_every / max(dt, 1e-9):,.0f} "
                      f"tok/s) stragglers={monitor.stragglers()}")
            if ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save(i + 1, state)
    data.close()
    if ckpt:
        ckpt.save(args.steps, state)
        ckpt.close()
    print("done")


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
