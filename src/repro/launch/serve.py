"""Serving launcher: quantized continuous-batching inference.

``python -m repro.launch.serve --arch qwen2.5-1.5b --smoke --quant q8_0``
spins up the lane engine on synthetic prompts and reports prefill/decode
throughput plus the capability-model prediction for the target device
profile (the paper's llama-bench workflow, framework-side).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.device_profile import get_profile
from repro.core.perf_model import InferencePerfModel, LLMSpec
from repro.models import build_model
from repro.serving import Request, ServeEngine, dequantize_params, \
    quantize_params


def setup_compilation_cache() -> str | None:
    """Point XLA at the persistent compilation cache when the canonical
    environment (``scripts/serve_env.sh``) exported one.

    With the cache warm, a relaunch reuses compiled prefill/decode
    executables for every shape bucket it has seen before; the compile
    counters printed at the end make a cold cache visible.  Zero
    ``min_compile_time`` so even the tiny smoke-config executables are
    persisted (the default threshold skips sub-second compiles).
    """
    cache_dir = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    if not cache_dir:
        return None
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    return cache_dir


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default=None,
                    choices=[None, "q8_0", "q6_k", "q4_k", "q2_k"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--paged", action="store_true",
                    help="serve over the page-pool KV cache")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="radix prompt cache + copy-on-write page "
                         "sharing (implies --paged)")
    ap.add_argument("--shared-prefix-len", type=int, default=None,
                    help="tokens of common prompt head across the "
                         "synthetic requests (default: half the "
                         "prompt when --prefix-sharing is on)")
    ap.add_argument("--profile", default="tpu-v5e",
                    help="device profile for the analytic prediction")
    args = ap.parse_args(argv)

    cache_dir = setup_compilation_cache()
    if cache_dir:
        print(f"compilation cache: {cache_dir}")

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.quant:
        qp, stats = quantize_params(params, args.quant)
        print(f"quantized {stats['quantized']} weight matrices "
              f"({stats['quantized_bytes']/1e6:.1f} MB vs dense "
              f"{stats['dense_bytes']/1e6:.1f} MB kept dense)")
        params = dequantize_params(qp)   # dense exec path on CPU

    rng = np.random.default_rng(0)
    head_len = 0
    if args.prefix_sharing:
        head_len = args.shared_prefix_len \
            if args.shared_prefix_len is not None else args.prompt_len // 2
        head_len = max(min(head_len, args.prompt_len - 1), 0)
    head = rng.integers(0, cfg.vocab_size, head_len).astype(np.int32)
    reqs = [Request(uid=i,
                    prompt=np.concatenate(
                        [head,
                         rng.integers(0, cfg.vocab_size,
                                      args.prompt_len - head_len
                                      ).astype(np.int32)]),
                    max_new_tokens=args.gen)
            for i in range(args.requests)]

    paged = args.paged or args.prefix_sharing
    max_len = args.prompt_len + args.gen + 8
    if paged:                      # cache capacity is page granular
        max_len = -(-max_len // args.page_size) * args.page_size
    engine = ServeEngine(cfg, params, n_lanes=args.lanes,
                         max_len=max_len,
                         paged=paged, page_size=args.page_size,
                         prefix_sharing=args.prefix_sharing)
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    n_gen = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests, {n_gen} tokens in {dt:.2f}s "
          f"({n_gen/dt:.1f} tok/s measured on CPU)")
    print(f"compiles: prefill {engine.stats['prefill_compiles']}, "
          f"decode {engine.stats['decode_compiles']} "
          f"(steady state re-serves from the jit cache)")
    if args.prefix_sharing:
        s = engine.stats
        print(f"prefix sharing: {s['prefix_hits']} hits, "
              f"{s['prefix_tokens_matched']} prompt tokens served from "
              f"cached pages, {s['prefix_pages_saved']} prefill pages "
              f"saved, {s['prefix_cow_copies']} copy-on-write splits")
        engine.prefix_cache.flush()

    prof = get_profile(args.profile)
    spec = LLMSpec(name=cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
                   n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                   d_ff=cfg.d_ff, vocab_size=cfg.vocab_size,
                   tied_embeddings=cfg.tie_embeddings)
    m = InferencePerfModel(prof, spec)
    fmt = args.quant or "f16"
    print(f"capability-model prediction on {prof.name}: "
          f"prefill {m.prefill(fmt).tokens_per_s:,.0f} tok/s, "
          f"decode {m.decode(fmt).tokens_per_s:,.0f} tok/s ({fmt})")


if __name__ == "__main__":
    main()
