"""Serving launcher: quantized continuous-batching inference.

``python -m repro.launch.serve --arch qwen2.5-1.5b --smoke --quant q8_0``
spins up the lane engine on synthetic prompts and reports prefill/decode
throughput plus the capability-model prediction for the target device
profile (the paper's llama-bench workflow, framework-side).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.device_profile import get_profile
from repro.core.perf_model import InferencePerfModel, LLMSpec
from repro.models import build_model
from repro.serving import Request, ServeEngine, dequantize_params, \
    quantize_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default=None,
                    choices=[None, "q8_0", "q6_k", "q4_k", "q2_k"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--profile", default="tpu-v5e",
                    help="device profile for the analytic prediction")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.quant:
        qp, stats = quantize_params(params, args.quant)
        print(f"quantized {stats['quantized']} weight matrices "
              f"({stats['quantized_bytes']/1e6:.1f} MB vs dense "
              f"{stats['dense_bytes']/1e6:.1f} MB kept dense)")
        params = dequantize_params(qp)   # dense exec path on CPU

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.gen)
            for i in range(args.requests)]

    engine = ServeEngine(cfg, params, n_lanes=args.lanes,
                         max_len=args.prompt_len + args.gen + 8)
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    n_gen = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests, {n_gen} tokens in {dt:.2f}s "
          f"({n_gen/dt:.1f} tok/s measured on CPU)")

    prof = get_profile(args.profile)
    spec = LLMSpec(name=cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
                   n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                   d_ff=cfg.d_ff, vocab_size=cfg.vocab_size,
                   tied_embeddings=cfg.tie_embeddings)
    m = InferencePerfModel(prof, spec)
    fmt = args.quant or "f16"
    print(f"capability-model prediction on {prof.name}: "
          f"prefill {m.prefill(fmt).tokens_per_s:,.0f} tok/s, "
          f"decode {m.decode(fmt).tokens_per_s:,.0f} tok/s ({fmt})")


if __name__ == "__main__":
    main()
