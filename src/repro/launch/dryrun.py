import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
# REPRO_SCAN_UNROLL is toggled per-compile inside run_cell: the scanned
# build gives the production memory analysis (remat-aware liveness), the
# unrolled build gives per-layer-accurate FLOPs / collective counts.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script

1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
2. constructs ShapeDtypeStruct stand-ins for params / optimizer state /
   batch / cache (zero allocation, ``jax.eval_shape``),
3. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``,
4. records ``memory_analysis()`` (fits-per-device proof),
   ``cost_analysis()`` (FLOPs / bytes) and HLO-parsed collective bytes,
5. appends a JSON line consumed by ``repro.core.roofline`` and
   EXPERIMENTS.md.

The XLA_FLAGS line above MUST precede any jax import: device count locks
at first backend initialization.
"""

import argparse    # noqa: E402
import functools   # noqa: E402
import json        # noqa: E402
import time        # noqa: E402
import traceback   # noqa: E402

import jax                       # noqa: E402
import jax.numpy as jnp          # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, all_cells, get_config,  # noqa: E402
                           shape_applicable)
from repro.core.hlo_analysis import collective_bytes   # noqa: E402
from repro.core.roofline import analyze                # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.models import build_model                   # noqa: E402
from repro.models.common import ModelConfig            # noqa: E402
from repro.models.transformer import (init_cache, lm_decode_step,  # noqa: E402
                                      lm_prefill_batched)
from repro.models.whisper import (decode_forward, encode,  # noqa: E402
                                  init_whisper_cache, whisper_decode_step)
from repro.parallel.sharding import (batch_shardings, cache_shardings,  # noqa: E402
                                     param_shardings, replicated, use_mesh)
from repro.train import TrainConfig, init_train_state, make_train_step  # noqa: E402


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    if kind in ("train", "prefill"):
        specs = {"tokens": _sds((b, s), jnp.int32)}
        if kind == "train":
            specs["labels"] = _sds((b, s), jnp.int32)
        if cfg.is_encdec:
            specs["frames"] = _sds((b, s, cfg.d_model), jnp.float32)
        if cfg.n_vision_tokens:
            specs["vision_embeds"] = _sds(
                (b, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"tokens": _sds((b,), jnp.int32)}


def _abstract_params(model, serve_dtype=None):
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if serve_dtype is not None:
        # serving holds bf16 weights (no optimizer master copies)
        sds = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, serve_dtype
                if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype), sds)
    return sds


def _attn_scan_correction(cfg: ModelConfig, shape_name: str) -> float:
    """Blockwise attention runs a lax.scan over KV blocks whose body XLA
    cost-analysis counts once; add the (nblk-1)/nblk remainder
    analytically.  4*B*H*hd*Sq*Sk flops per layer (QK^T + PV), x3 for
    train (fwd + bwd)."""
    if cfg.family == "ssm":
        return 0.0
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    if sh["kind"] == "decode":
        return 0.0                      # decode attention has no scan
    block = 512
    sk_counted = min(block, s)
    per_layer = 4.0 * b * cfg.n_heads * cfg.hd * s * (s - sk_counted)
    mult = 3.0 if sh["kind"] == "train" else 1.0
    n_attn_layers = cfg.n_layers + cfg.n_encoder_layers
    if cfg.is_encdec:
        n_attn_layers += cfg.n_layers   # cross-attention
    return mult * per_layer * n_attn_layers


def _model_flops(cfg: ModelConfig, shape_name: str) -> float:
    sh = SHAPES[shape_name]
    n_active = cfg.active_params()
    tokens = sh["global_batch"] * (sh["seq_len"]
                                   if sh["kind"] != "decode" else 1)
    if sh["kind"] == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


# ----------------------------------------------------------------------
# per-kind lowering
# ----------------------------------------------------------------------

def lower_train(cfg: ModelConfig, mesh, shape_name: str,
                microbatches: int = None):
    model = build_model(cfg)
    if microbatches is None:
        microbatches = int(os.environ.get("REPRO_MICROBATCHES", "8"))
    # >=100B-param archs cannot hold f32 Adam moments at 16 GiB/chip
    # (arctic: 5.7 TB state vs 4 TB/pod); they train with 8-bit moments.
    moment_dtype = os.environ.get(
        "REPRO_OPT_QUANT",
        "int8" if cfg.total_params() > 1e11 else "f32")
    from repro.optim import AdamWConfig
    tcfg = TrainConfig(optimizer=AdamWConfig(moment_dtype=moment_dtype),
                       remat=True, microbatches=microbatches)
    step = make_train_step(cfg, tcfg)
    state_sds = jax.eval_shape(
        functools.partial(init_train_state, model,
                          moment_dtype=moment_dtype),
        jax.random.PRNGKey(0))
    batch_sds = input_specs(cfg, shape_name)
    state_sh = param_shardings(mesh, state_sds)
    batch_sh = batch_shardings(mesh, batch_sds)
    jit = jax.jit(step,
                  in_shardings=(state_sh, batch_sh),
                  out_shardings=(state_sh, None),
                  donate_argnums=(0,))
    with use_mesh(mesh):
        return jit.lower(state_sds, batch_sds)


def lower_prefill(cfg: ModelConfig, mesh, shape_name: str):
    model = build_model(cfg)
    params_sds = _abstract_params(model, serve_dtype=jnp.bfloat16)
    specs = input_specs(cfg, shape_name)
    p_sh = param_shardings(mesh, params_sds)
    b_sh = batch_shardings(mesh, specs)

    if cfg.is_encdec:
        def step(params, batch):
            enc = encode(params, batch["frames"], cfg)
            logits = decode_forward(params, batch["tokens"], enc, cfg)
            return logits[:, -1]
        jit = jax.jit(step, in_shardings=(p_sh, b_sh))
        with use_mesh(mesh):
            return jit.lower(params_sds, specs)

    def step(params, batch):
        return lm_prefill_batched(params, batch["tokens"], cfg,
                                  vision_embeds=batch.get("vision_embeds"))

    # out shardings: logits sharded (batch, vocab); kv cache like a cache
    out_sds = jax.eval_shape(step, params_sds, specs)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_ax = dp if len(dp) > 1 else (dp[0] if dp else None)
    sh_b = SHAPES[shape_name]["global_batch"]

    def kv_sharding(leaf):
        if leaf is None:
            return None
        spec = [None] * leaf.ndim
        if leaf.ndim == 5:  # (L, B, Hkv, S, D)
            if dp_ax and sh_b % _axsize(mesh, dp_ax) == 0:
                spec[1] = dp_ax
            if leaf.shape[3] % mesh.shape.get("model", 1) == 0:
                spec[3] = "model"
        return NamedSharding(mesh, P(*spec))

    logits_sh = NamedSharding(mesh, P(
        dp_ax if sh_b % _axsize(mesh, dp_ax) == 0 else None, "model"))
    kv_sh = jax.tree_util.tree_map(kv_sharding, out_sds[1])
    jit = jax.jit(step, in_shardings=(p_sh, b_sh),
                  out_shardings=(logits_sh, kv_sh))
    with use_mesh(mesh):
        return jit.lower(params_sds, specs)


def _axsize(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def lower_decode(cfg: ModelConfig, mesh, shape_name: str):
    # hillclimb knob: REPRO_KV_QUANT=int8 lowers the decode cell with the
    # quantized KV cache (SSPerf hillclimb 3)
    kvq = os.environ.get("REPRO_KV_QUANT")
    if kvq:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_quant=kvq)
    model = build_model(cfg)
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    params_sds = _abstract_params(model, serve_dtype=jnp.bfloat16)
    p_sh = param_shardings(mesh, params_sds, mode="serve")
    tok_sds = _sds((b,), jnp.int32)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_ax = dp if len(dp) > 1 else (dp[0] if dp else None)
    tok_sh = NamedSharding(mesh, P(
        dp_ax if (dp_ax and b % _axsize(mesh, dp_ax) == 0) else None))

    if cfg.is_encdec:
        enc_sds = _sds((b, s, cfg.d_model), cfg.compute_dtype)
        cache_sds = jax.eval_shape(
            lambda p, e: init_whisper_cache(p, e, cfg, b, s),
            params_sds, enc_sds)
        def step(params, cache, tokens):
            return whisper_decode_step(params, cfg, cache, tokens)
    else:
        cache_sds = jax.eval_shape(
            functools.partial(init_cache, cfg, b, s))

        def step(params, cache, tokens):
            return lm_decode_step(params, cfg, cache, tokens)

    c_sh = cache_shardings(mesh, cache_sds)
    logits_sh = NamedSharding(mesh, P(
        dp_ax if (dp_ax and b % _axsize(mesh, dp_ax) == 0) else None,
        "model"))
    jit = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh),
                  out_shardings=(logits_sh, c_sh), donate_argnums=(1,))
    with use_mesh(mesh, mode="serve"):
        return jit.lower(params_sds, cache_sds, tok_sds)


_LOWER = {"train": lower_train, "prefill": lower_prefill,
          "decode": lower_decode}


def _cost_dict(compiled) -> dict:
    """Normalize Compiled.cost_analysis() across jax versions: newer
    releases return a list with one dict per partition (all identical on
    an SPMD module); older ones return the dict directly."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost or {})


# ----------------------------------------------------------------------
# cell runner
# ----------------------------------------------------------------------

def _compile_once(cfg, mesh, shape_name, unroll: bool,
                  microbatches: int = None, moe_chunk: int = None):
    """One lower+compile. ``microbatches=1`` is used by the cost passes:
    the gradient-accumulation lax.scan body is counted once by XLA's
    cost analysis, so per-step FLOPs/bytes must be measured on the
    single-batch schedule (numerically the same totals)."""
    kind = SHAPES[shape_name]["kind"]
    prev = os.environ.get("REPRO_SCAN_UNROLL")
    prev_mb = os.environ.get("REPRO_MICROBATCHES")
    prev_mc = os.environ.get("REPRO_MOE_CHUNK")
    os.environ["REPRO_SCAN_UNROLL"] = "1" if unroll else "0"
    if microbatches is not None:
        os.environ["REPRO_MICROBATCHES"] = str(microbatches)
    if moe_chunk is not None:
        os.environ["REPRO_MOE_CHUNK"] = str(moe_chunk)
    try:
        t0 = time.time()
        lowered = _LOWER[kind](cfg, mesh, shape_name)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    finally:
        for k, v in (("REPRO_SCAN_UNROLL", prev),
                     ("REPRO_MICROBATCHES", prev_mb),
                     ("REPRO_MOE_CHUNK", prev_mc)):
            if k == "REPRO_SCAN_UNROLL" and v is None:
                os.environ.pop(k, None)
            elif v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    return compiled, t_lower, t_compile


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, cost_pass: bool = True) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    kind = SHAPES[shape_name]["kind"]

    # pass 1 -- production (scanned) build: memory analysis + fallback cost
    compiled, t_lower, t_compile = _compile_once(cfg, mesh, shape_name,
                                                 unroll=False)
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())

    # pass 2 -- unrolled build (single-pod only): per-layer-accurate
    # FLOPs / bytes / collective census for the roofline table.  Only the
    # dense-family train/prefill graphs unroll tractably on XLA-CPU (the
    # SSD chunk machinery, MoE dispatch, and the decode cache DUS chains
    # explode compiler time/RAM when multiplied by n_layers); all other
    # cells use the scanned build with an analytic xL correction,
    # validated against unrolled numbers on the dense archs (SSRoofline
    # notes in EXPERIMENTS.md).
    can_unroll = (cfg.family in ("dense", "vlm", "audio")
                  and kind in ("train", "prefill"))
    cost_method = "scanned"
    if cost_pass and can_unroll:
        compiled_u, _, t_u = _compile_once(cfg, mesh, shape_name,
                                           unroll=True, microbatches=1)
        cost = _cost_dict(compiled_u) or cost
        coll = collective_bytes(compiled_u.as_text())
        t_compile += t_u
        del compiled_u
        cost_method = "unrolled"
    elif cost_pass:
        # MoE/SSD cells: re-measure on the single-microbatch, un-chunked
        # scanned schedule (compile-only: memory does not matter here)
        # before the xL scaling below.
        compiled_1, _, t_1 = _compile_once(cfg, mesh, shape_name,
                                           unroll=False, microbatches=1,
                                           moe_chunk=0)
        cost = _cost_dict(compiled_1) or cost
        coll = collective_bytes(compiled_1.as_text())
        t_compile += t_1
        del compiled_1

    # cost_analysis is per-partition on the SPMD module -> whole-step
    flops_raw = float(cost.get("flops", 0.0)) * chips
    bytes_raw = float(cost.get("bytes accessed", 0.0)) * chips
    coll_total = float(coll.total_bytes) * chips
    if cost_pass and not can_unroll:
        # scanned build counts the while body once: scale by n_layers,
        # holding out the (one-shot) embedding/logits head terms.
        sh = SHAPES[shape_name]
        L = cfg.n_layers + cfg.n_encoder_layers
        if kind == "train":
            tokens = sh["global_batch"] * sh["seq_len"]
            head_f = 6.0 * cfg.d_model * cfg.padded_vocab * tokens
            head_b = 3.0 * cfg.d_model * cfg.padded_vocab * 2.0
        else:
            tokens = sh["global_batch"]
            head_f = 2.0 * cfg.d_model * cfg.padded_vocab * tokens
            head_b = 1.0 * cfg.d_model * cfg.padded_vocab * 2.0
        flops_raw = max(flops_raw - head_f, 0.0) * L + head_f
        bytes_raw = max(bytes_raw - head_b, 0.0) * L + head_b
        coll_total = coll_total * L
        cost_method = "scanned_xL"
    flops = flops_raw + _attn_scan_correction(cfg, shape_name)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind, "chips": chips,
        "hlo_flops": flops,
        "hlo_flops_raw": flops_raw,
        "hlo_bytes": bytes_raw,
        "collective_bytes": coll_total,
        "cost_method": cost_method,
        "collectives": coll.bytes_by_kind,
        "collective_counts": coll.count_by_kind,
        "model_flops": _model_flops(cfg, shape_name),
        "microbatches": int(os.environ.get("REPRO_MICROBATCHES", "8"))
        if kind == "train" else 1,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
        # live set = args + temps + outputs - donated aliases
        total = (rec.get("argument_size_in_bytes", 0)
                 + rec.get("temp_size_in_bytes", 0)
                 + rec.get("output_size_in_bytes", 0)
                 - rec.get("alias_size_in_bytes", 0))
        rec["bytes_per_device"] = total
        rec["fits_16g"] = total < 16 * 1024**3
    if verbose:
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "hlo_flops",
                           "collective_bytes", "compile_s")}, default=str))
        print("  memory:", {k: rec.get(k) for k in
                            ("argument_size_in_bytes", "temp_size_in_bytes",
                             "bytes_per_device", "fits_16g")})
        print("  collectives:", coll.summary())
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape (default all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already recorded (resume a run)")
    args = ap.parse_args()
    done = set()
    if args.skip_done and os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if "error" not in r:
                done.add((r["arch"], r["shape"], r["mesh"]))
        args.append = True

    cells = all_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    mode = "a" if args.append else "w"
    failures = []
    with open(args.out, mode) as f:
        for multi_pod in meshes:
            for arch, shape in cells:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                tag = f"{arch}/{shape}/{mesh_name}"
                if (arch, shape, mesh_name) in done:
                    continue
                print(f"=== {tag}")
                try:
                    rec = run_cell(arch, shape, multi_pod,
                                   cost_pass=not multi_pod)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    traceback.print_exc()
                    f.write(json.dumps({"arch": arch, "shape": shape,
                                        "mesh": tag.split("/")[-1],
                                        "error": repr(e)}) + "\n")
                    f.flush()
    print(f"\n{len(cells) * len(meshes) - len(failures)} cells OK, "
          f"{len(failures)} failed")
    for tag, err in failures:
        print("FAILED:", tag, err)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
