"""Quantize / dequantize reference implementations (pure jnp).

These are the oracles for the Pallas ``qmatmul`` kernels and the
functional weight store for quantized serving.  Layouts are the TPU
structure-of-arrays planes described in :mod:`repro.quant.formats`.

All functions operate on the *last* axis being the quantized (reduction)
axis of a weight matrix ``w[k, n]`` -> we quantize along ``k`` so the
matmul kernel can dequantize a (bk, bn) tile with per-k-block scales.
Weights whose k is not a multiple of the block size must be padded by the
caller (all model dims in this repo are multiples of 256).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.formats import QuantFormat, get_format


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """A block-quantized 2-D tensor in TPU plane layout.

    values:      int8 (q8_0/q6_k) or packed uint8 (q4_k: 2/byte,
                 q2_k: 4/byte), shape (k_packed, n).
    sub_scales:  int8, shape (k/sub, n)   -- None for q8_0.
    sub_mins:    int8, shape (k/sub, n)   -- only asymmetric formats.
    super_scales:f32, shape (k/block, n)  -- per-block scale of sub_scales.
    super_mins:  f32, shape (k/block, n)  -- per-block scale of sub_mins.
    """

    fmt: str
    shape: tuple
    values: jnp.ndarray
    super_scales: jnp.ndarray
    sub_scales: Optional[jnp.ndarray] = None
    sub_mins: Optional[jnp.ndarray] = None
    super_mins: Optional[jnp.ndarray] = None

    # pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        children = (self.values, self.super_scales, self.sub_scales,
                    self.sub_mins, self.super_mins)
        return children, (self.fmt, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        fmt, shape = aux
        values, super_scales, sub_scales, sub_mins, super_mins = children
        return cls(fmt=fmt, shape=shape, values=values,
                   super_scales=super_scales, sub_scales=sub_scales,
                   sub_mins=sub_mins, super_mins=super_mins)

    @property
    def format(self) -> QuantFormat:
        return get_format(self.fmt)

    def nbytes(self) -> int:
        n = self.values.size * self.values.dtype.itemsize
        for t in (self.super_scales, self.sub_scales, self.sub_mins,
                  self.super_mins):
            if t is not None:
                n += t.size * t.dtype.itemsize
        return n


# ----------------------------------------------------------------------
# packing helpers
# ----------------------------------------------------------------------

def pack_nibbles(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack unsigned ints (< 2**bits) along axis 0 into uint8."""
    per = 8 // bits
    k, n = v.shape
    assert k % per == 0
    v = v.astype(jnp.uint8).reshape(k // per, per, n)
    out = jnp.zeros((k // per, n), jnp.uint8)
    for i in range(per):
        out = out | (v[:, i, :] << (bits * i))
    return out


def unpack_nibbles(p: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Inverse of :func:`pack_nibbles` -> uint8 in [0, 2**bits)."""
    per = 8 // bits
    mask = (1 << bits) - 1
    parts = [(p >> (bits * i)) & mask for i in range(per)]
    kp, n = p.shape
    return jnp.stack(parts, axis=1).reshape(kp * per, n)


# ----------------------------------------------------------------------
# quantizers
# ----------------------------------------------------------------------

def _blockwise_absmax_scale(w, block, qmax):
    """Per-(block,n) scale mapping w -> integers in [-qmax, qmax]."""
    k, n = w.shape
    wb = w.reshape(k // block, block, n)
    amax = jnp.max(jnp.abs(wb), axis=1)
    scale = amax / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    return wb, scale


def quantize_q8_0(w: jnp.ndarray) -> QTensor:
    """Symmetric int8, block 32, one f32 scale per block (ggml Q8_0)."""
    fmt = get_format("q8_0")
    wb, scale = _blockwise_absmax_scale(w.astype(jnp.float32), fmt.block, 127.0)
    q = jnp.clip(jnp.round(wb / scale[:, None, :]), -127, 127).astype(jnp.int8)
    k, n = w.shape
    return QTensor(fmt="q8_0", shape=(k, n),
                   values=q.reshape(k, n),
                   super_scales=scale.astype(jnp.float32))


def _two_level_symmetric(w, fmt, qmax):
    """Shared machinery for symmetric k-quants (q6_k)."""
    k, n = w.shape
    sub = fmt.sub_block
    w32 = w.astype(jnp.float32)
    # inner: per-sub-block f32 scale
    wsb = w32.reshape(k // sub, sub, n)
    amax = jnp.max(jnp.abs(wsb), axis=1)
    d_sub = amax / qmax                                  # (k/sub, n)
    # outer: quantize d_sub itself to int8 against a per-block super scale
    per = fmt.block // sub
    d_grp = d_sub.reshape(k // fmt.block, per, n)
    d_super = jnp.max(d_grp, axis=1) / 127.0             # (k/block, n)
    d_super = jnp.where(d_super == 0, 1.0, d_super)
    q_sub = jnp.clip(jnp.round(d_grp / d_super[:, None, :]), 0, 127
                     ).astype(jnp.int8)                  # (k/block, per, n)
    # effective dequantized sub scale actually used for value coding:
    eff = q_sub.astype(jnp.float32) * d_super[:, None, :]
    eff = jnp.where(eff == 0, 1.0, eff).reshape(k // sub, n)
    q = jnp.clip(jnp.round(wsb / eff[:, None, :]), -qmax, qmax)
    return q.reshape(k, n), q_sub.reshape(k // sub, n), d_super


def quantize_q6_k(w: jnp.ndarray) -> QTensor:
    """6-bit symmetric, sub 16 / super 256 (ggml Q6_K algebra)."""
    fmt = get_format("q6_k")
    q, q_sub, d_super = _two_level_symmetric(w, fmt, qmax=31.0)
    k, n = w.shape
    return QTensor(fmt="q6_k", shape=(k, n),
                   values=q.astype(jnp.int8),
                   sub_scales=q_sub,
                   super_scales=d_super.astype(jnp.float32))


def _two_level_asymmetric(w, fmt, qmax, scale_qmax):
    """Asymmetric k-quants (q4_k, q2_k): value = d*q - m per sub-block."""
    k, n = w.shape
    sub = fmt.sub_block
    w32 = w.astype(jnp.float32)
    wsb = w32.reshape(k // sub, sub, n)
    wmin = jnp.min(wsb, axis=1)
    wmax = jnp.max(wsb, axis=1)
    m_sub = jnp.maximum(-wmin, 0.0)                      # min offset >= 0
    d_sub = (wmax + m_sub) / qmax
    d_sub = jnp.where(d_sub == 0, 1.0, d_sub)
    per = fmt.block // sub
    d_grp = d_sub.reshape(k // fmt.block, per, n)
    m_grp = m_sub.reshape(k // fmt.block, per, n)
    d_super = jnp.maximum(jnp.max(d_grp, axis=1) / scale_qmax, 1e-12)
    m_super = jnp.where(jnp.max(m_grp, axis=1) == 0, 1.0,
                        jnp.max(m_grp, axis=1) / scale_qmax)
    q_dsub = jnp.clip(jnp.round(d_grp / d_super[:, None, :]), 0, scale_qmax
                      ).astype(jnp.int8)
    q_msub = jnp.clip(jnp.round(m_grp / m_super[:, None, :]), 0, scale_qmax
                      ).astype(jnp.int8)
    eff_d = q_dsub.astype(jnp.float32) * d_super[:, None, :]
    eff_d = jnp.where(eff_d == 0, 1.0, eff_d).reshape(k // sub, n)
    eff_m = (q_msub.astype(jnp.float32) * m_super[:, None, :]
             ).reshape(k // sub, n)
    q = jnp.clip(jnp.round((wsb + eff_m[:, None, :]) / eff_d[:, None, :]),
                 0, qmax)
    return (q.reshape(k, n), q_dsub.reshape(k // sub, n),
            q_msub.reshape(k // sub, n), d_super, m_super)


def quantize_q4_k(w: jnp.ndarray) -> QTensor:
    fmt = get_format("q4_k")
    q, q_d, q_m, d_super, m_super = _two_level_asymmetric(
        w, fmt, qmax=15.0, scale_qmax=63.0)
    k, n = w.shape
    return QTensor(fmt="q4_k", shape=(k, n),
                   values=pack_nibbles(q.astype(jnp.uint8), 4),
                   sub_scales=q_d, sub_mins=q_m,
                   super_scales=d_super.astype(jnp.float32),
                   super_mins=m_super.astype(jnp.float32))


def quantize_q2_k(w: jnp.ndarray) -> QTensor:
    fmt = get_format("q2_k")
    q, q_d, q_m, d_super, m_super = _two_level_asymmetric(
        w, fmt, qmax=3.0, scale_qmax=15.0)
    k, n = w.shape
    return QTensor(fmt="q2_k", shape=(k, n),
                   values=pack_nibbles(q.astype(jnp.uint8), 2),
                   sub_scales=q_d, sub_mins=q_m,
                   super_scales=d_super.astype(jnp.float32),
                   super_mins=m_super.astype(jnp.float32))


QUANTIZERS = {
    "q8_0": quantize_q8_0,
    "q6_k": quantize_q6_k,
    "q4_k": quantize_q4_k,
    "q2_k": quantize_q2_k,
}


def quantize(w: jnp.ndarray, fmt: str) -> QTensor:
    if w.ndim != 2:
        raise ValueError(f"quantize expects 2-D [k, n] weights, got {w.shape}")
    blk = get_format(fmt).block
    if w.shape[0] % blk:
        raise ValueError(f"k={w.shape[0]} not a multiple of block {blk}")
    return QUANTIZERS[fmt](w)


# ----------------------------------------------------------------------
# dequantize (the jnp oracle for the Pallas kernels)
# ----------------------------------------------------------------------

def dequantize(qt: QTensor) -> jnp.ndarray:
    k, n = qt.shape
    fmt = qt.format
    if qt.fmt == "q8_0":
        scale = jnp.repeat(qt.super_scales, fmt.block, axis=0)
        return qt.values.astype(jnp.float32) * scale
    sub = fmt.sub_block
    if qt.fmt == "q6_k":
        d_super = jnp.repeat(qt.super_scales, fmt.block // sub, axis=0)
        eff = qt.sub_scales.astype(jnp.float32) * d_super
        eff = jnp.where(eff == 0, 1.0, eff)
        eff = jnp.repeat(eff, sub, axis=0)
        return qt.values.astype(jnp.float32) * eff
    # asymmetric 4/2-bit
    bits = fmt.bits
    q = unpack_nibbles(qt.values, bits).astype(jnp.float32)[:k]
    d_super = jnp.repeat(qt.super_scales, fmt.block // sub, axis=0)
    m_super = jnp.repeat(qt.super_mins, fmt.block // sub, axis=0)
    eff_d = qt.sub_scales.astype(jnp.float32) * d_super
    eff_d = jnp.where(eff_d == 0, 1.0, eff_d)
    eff_m = qt.sub_mins.astype(jnp.float32) * m_super
    eff_d = jnp.repeat(eff_d, sub, axis=0)
    eff_m = jnp.repeat(eff_m, sub, axis=0)
    return q * eff_d - eff_m


def quantization_rmse(w: jnp.ndarray, fmt: str) -> float:
    """Round-trip RMS error relative to weight RMS (property-test metric)."""
    qt = quantize(w, fmt)
    back = dequantize(qt)
    num = jnp.sqrt(jnp.mean((w.astype(jnp.float32) - back) ** 2))
    den = jnp.sqrt(jnp.mean(w.astype(jnp.float32) ** 2)) + 1e-12
    return float(num / den)
