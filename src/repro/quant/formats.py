"""ggml-family block-quantization formats, re-laid-out for TPU (C4).

The paper evaluates llama.cpp's F32/F16/Q8_0/Q6_K/Q4_K_M/Q2_K model
formats on the CMP 170HX.  We reproduce the *algebra* of those formats
faithfully -- block sizes, two-level scale hierarchies, symmetric vs
asymmetric (min-offset) coding -- while adapting the *memory layout* to
the TPU memory hierarchy:

* ggml interleaves scales and packed values per 32/256-element block so a
  CUDA warp can dequantize from one 128-byte read. A TPU VPU instead wants
  **structure-of-arrays planes**: one contiguous int8/packed-uint8 value
  plane plus small scale planes, so a Pallas kernel can load clean
  (8,128)-tiled blocks and unpack with vectorized shifts/masks.
* ggml's f16 super-scales become f32 here (TPU has no f16 ALU; bf16 would
  cost precision on the scale).  This costs 2 bytes / 256 values =
  0.0625 bpw, which we account for separately (``bpw_tpu`` vs ``bpw``).

Bits-per-weight (``bpw``) follows ggml exactly and drives the *bandwidth*
performance model -- decode throughput on a bandwidth-rich device is
``hbm_bw / bytes(active weights)``, which is precisely the paper's Graph
4-2 theoretical line.

Block geometry (all lane-aligned for TPU: 32 | 128, 256 = 2x128):

=========  ======  =========  ==========================================
format     block   sub-block  coding
=========  ======  =========  ==========================================
``q8_0``   32      --         int8 value x f16 scale (symmetric)
``q6_k``   256     16         6-bit value x (int8 sub-scale x f16 super)
``q4_k``   256     32         4-bit value x (6-bit sub-scale/min x 2xf16)
``q2_k``   256     16         2-bit value x (4-bit sub-scale/min x 2xf16)
=========  ======  =========  ==========================================
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    """Static description of one block-quant format.

    Attributes:
      name: ggml-compatible name.
      bits: value bits.
      block: elements sharing the outer (super) scale.
      sub_block: elements sharing the inner scale (None = no hierarchy).
      asymmetric: True if sub-blocks carry a min offset (Q4_K/Q2_K).
      bpw: effective bits/weight of the *ggml* packed layout (drives the
        bandwidth model; matches llama.cpp's tensor sizes).
      bpw_tpu: bits/weight of our structure-of-arrays TPU layout.
      values_per_byte: packing density of the value plane on TPU.
    """

    name: str
    bits: int
    block: int
    sub_block: Optional[int]
    asymmetric: bool
    bpw: float
    bpw_tpu: float
    values_per_byte: int

    @property
    def n_sub(self) -> int:
        return 1 if self.sub_block is None else self.block // self.sub_block


# ggml bpw references: q8_0 = 34B/32 = 8.5; q6_k = 210B/256 = 6.5625;
# q4_k = 144B/256 = 4.5; q2_k = 84B/256 = 2.625 (llama.cpp Q2_K block:
# 16 sub scales + 16 mins (4b each) + 64B values + 2xf16 = 84 bytes).
FORMATS: Dict[str, QuantFormat] = {
    "q8_0": QuantFormat(
        name="q8_0", bits=8, block=32, sub_block=None, asymmetric=False,
        bpw=8.5, bpw_tpu=8.0 + 32.0 / 32.0, values_per_byte=1),
    "q6_k": QuantFormat(
        name="q6_k", bits=6, block=256, sub_block=16, asymmetric=False,
        bpw=6.5625,
        # TPU plane: 6-bit values stored as int8 (+2 pad bits), int8
        # sub-scales, f32 super-scale.
        bpw_tpu=8.0 + 16 * 8.0 / 256.0 + 32.0 / 256.0, values_per_byte=1),
    "q4_k": QuantFormat(
        name="q4_k", bits=4, block=256, sub_block=32, asymmetric=True,
        bpw=4.5,
        bpw_tpu=4.0 + 8 * (8.0 + 8.0) / 256.0 + 2 * 32.0 / 256.0,
        values_per_byte=2),
    "q2_k": QuantFormat(
        name="q2_k", bits=2, block=256, sub_block=16, asymmetric=True,
        bpw=2.625,
        bpw_tpu=2.0 + 16 * (8.0 + 8.0) / 256.0 + 2 * 32.0 / 256.0,
        values_per_byte=4),
}

# The paper additionally benchmarks unquantized f32/f16 ggufs; model them
# as degenerate "formats" so the perf model can sweep one axis.
DENSE_BPW = {"f32": 32.0, "f16": 16.0, "bf16": 16.0}


def bits_per_weight(fmt: str) -> float:
    if fmt in FORMATS:
        return FORMATS[fmt].bpw
    if fmt in DENSE_BPW:
        return DENSE_BPW[fmt]
    raise KeyError(f"unknown format {fmt!r}")


def bytes_per_weight(fmt: str) -> float:
    return bits_per_weight(fmt) / 8.0


def get_format(name: str) -> QuantFormat:
    try:
        return FORMATS[name]
    except KeyError as e:
        raise KeyError(f"unknown quant format {name!r}; "
                       f"known: {sorted(FORMATS)}") from e
