from repro.quant.formats import (DENSE_BPW, FORMATS, QuantFormat,
                                 bits_per_weight, bytes_per_weight,
                                 get_format)
from repro.quant.quantize import (QTensor, dequantize, pack_nibbles,
                                  quantization_rmse, quantize,
                                  unpack_nibbles)

__all__ = [
    "DENSE_BPW", "FORMATS", "QuantFormat", "bits_per_weight",
    "bytes_per_weight", "get_format", "QTensor", "dequantize",
    "pack_nibbles", "quantization_rmse", "quantize", "unpack_nibbles",
]
