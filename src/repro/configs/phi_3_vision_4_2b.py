"""phi-3-vision-4.2b: phi3-mini backbone + CLIP stub (precomputed patch
embeddings as a 256-token prefix) [hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32064,
    norm="rmsnorm", tie_embeddings=False, max_seq_len=131072,
    n_vision_tokens=256,
)

SMOKE = ModelConfig(
    name="phi3v-smoke", family="vlm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512,
    norm="rmsnorm", n_vision_tokens=8,
)
