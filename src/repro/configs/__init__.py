"""Assigned-architecture configs (``--arch <id>``) + the paper's own model.

Each module exports ``CONFIG`` (the exact published configuration) and
``SMOKE`` (a reduced same-family config for CPU smoke tests).  The FULL
configs are only ever exercised via the dry-run (ShapeDtypeStruct level).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

_MODULES: Dict[str, str] = {
    "mamba2-780m": "repro.configs.mamba2_780m",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "olmo-1b": "repro.configs.olmo_1b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "arctic-480b": "repro.configs.arctic_480b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "phi-3-vision-4.2b": "repro.configs.phi_3_vision_4_2b",
    "whisper-base": "repro.configs.whisper_base",
    # the paper's evaluation model (section 4.1)
    "qwen2.5-1.5b": "repro.configs.qwen2_5_1_5b",
}

ARCH_IDS: List[str] = [k for k in _MODULES if k != "qwen2.5-1.5b"]

#: the four assigned input-shape cells (LM-family shapes).
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

#: sub-quadratic families that run the long_500k cell (DESIGN.md SS4).
LONG_CONTEXT_ARCHS = ("mamba2-780m", "hymba-1.5b")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def all_cells():
    """Every applicable (arch, shape) pair -- the dry-run matrix."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES
            if shape_applicable(a, s)]
