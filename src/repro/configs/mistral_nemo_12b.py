"""mistral-nemo-12b: 40L d5120 32H kv8, head_dim 128, 128k ctx
[hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=131072,
    head_dim=128, norm="rmsnorm", tie_embeddings=False,
    rope_theta=1e6, max_seq_len=131072,
)

SMOKE = ModelConfig(
    name="nemo-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=384, vocab_size=512,
    head_dim=32, norm="rmsnorm",
)
