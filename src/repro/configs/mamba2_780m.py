"""mamba2-780m: 48L d1536 attn-free, SSD state 128 [arXiv:2405.21060]."""
from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_ff=0, vocab_size=50280,
    norm="rmsnorm", tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    max_seq_len=1048576,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm", n_layers=2, d_model=128,
    n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=512,
    norm="rmsnorm", tie_embeddings=True,
    ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, conv_width=4,
                  chunk=32),
)
