"""whisper-base: 6L enc + 6L dec d512 8H, conv frontend stubbed to frame
embeddings [arXiv:2212.04356]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865,
    norm="layernorm", tie_embeddings=True, n_encoder_layers=6,
    max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512,
    norm="layernorm", tie_embeddings=True, n_encoder_layers=2,
)
