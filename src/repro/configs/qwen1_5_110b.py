"""qwen1.5-110b: 80L d8192 64H GQA kv8, QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=49152, vocab_size=152064,
    head_dim=128, qkv_bias=True, norm="rmsnorm", tie_embeddings=False,
    rope_theta=1e6, max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=384, vocab_size=512,
    qkv_bias=True, norm="rmsnorm", tie_embeddings=False,
)
