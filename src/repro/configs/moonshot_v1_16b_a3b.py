"""moonshot-v1-16b-a3b (Moonlight): 48L d2048, 64-expert top-6 MoE + 2
shared experts [hf:moonshotai/Moonlight-16B-A3B]."""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=163840,
    norm="rmsnorm", tie_embeddings=False, max_seq_len=131072,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert_ff=1408,
                  n_shared_experts=2),
)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    norm="rmsnorm",
    moe=MoEConfig(n_experts=8, top_k=3, d_expert_ff=128,
                  n_shared_experts=1),
)
