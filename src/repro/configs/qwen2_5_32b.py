"""qwen2.5-32b: 64L d5120 40H kv8, QKV bias [hf:Qwen/Qwen2.5-0.5B]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=27648, vocab_size=152064,
    head_dim=128, qkv_bias=True, norm="rmsnorm", tie_embeddings=False,
    rope_theta=1e6, max_seq_len=131072,
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense", n_layers=2, d_model=160,
    n_heads=5, n_kv_heads=1, d_ff=448, vocab_size=512,
    head_dim=32, qkv_bias=True, norm="rmsnorm",
)
