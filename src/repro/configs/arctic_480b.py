"""arctic-480b: 35L d7168 56H kv8, 128-expert top-2 MoE + dense residual
[hf:Snowflake/snowflake-arctic-base]."""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000,
    head_dim=128, norm="rmsnorm", tie_embeddings=False,
    max_seq_len=32768,
    moe=MoEConfig(n_experts=128, top_k=2, d_expert_ff=4864,
                  dense_residual=True),
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512,
    norm="rmsnorm",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=256,
                  dense_residual=True),
)
