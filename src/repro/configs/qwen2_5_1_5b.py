"""qwen2.5-1.5b: the paper's llama-bench model (section 4.1): 28L d1536
12Q/2KV GQA, QKV bias, tied embeddings [hf:Qwen/Qwen2.5-1.5B]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-1.5b", family="dense", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960, vocab_size=151936,
    qkv_bias=True, norm="rmsnorm", tie_embeddings=True,
    rope_theta=1e6, max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="qwen1.5b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=384, vocab_size=512,
    qkv_bias=True, norm="rmsnorm", tie_embeddings=True,
)
