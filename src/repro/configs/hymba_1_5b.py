"""hymba-1.5b: 32L d1600, parallel attention + mamba heads, sliding-window
attention (global state via SSM) [arXiv:2411.13676]."""
from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab_size=32001,
    head_dim=64, norm="rmsnorm", tie_embeddings=True,
    sliding_window=1024, max_seq_len=1048576,
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512,
    head_dim=32, norm="rmsnorm", tie_embeddings=True, sliding_window=32,
    ssm=SSMConfig(state_dim=8, head_dim=32, expand=2, conv_width=4,
                  chunk=32),
)
