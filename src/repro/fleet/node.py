"""A simulated serving node: one board, one role, one queue discipline.

A :class:`SimNode` wraps a :class:`~repro.core.device_profile.DeviceProfile`
through the :class:`~repro.core.perf_model.InferencePerfModel` and turns
its per-phase estimates into *service processes*:

* **prefill** -- a serial FIFO executor (compute-bound; batching prompts
  past saturation buys nothing on these boards).  A request occupies the
  node for ``prompt/tps`` of compute plus, when the KV must ship to
  another board, the interconnect handoff -- the same charge the static
  planner's ``effective_prefill_tps`` makes.
* **decode** -- lane-limited continuous batching modeled as processor
  sharing with a roofline step time: with ``B`` active lanes the node
  emits one token per lane every ``max(B*t_compute, t_weights +
  sum(t_kv_i))`` seconds -- weights stream once per step (shared),
  per-lane KV and MACs do not.  At ``B=1`` this reduces exactly to the
  planner's batch-1 decode estimate, which is what keeps the simulator
  and ``plan_fleet`` in steady-state agreement.

A ``role="both"`` node time-slices 50/50 between the phases (both rates
halved), mirroring the planner's seed split.

Paged KV occupancy (``kv_pool_pages``): mirrors the engine's page-pool
layout -- each resident decode slot occupies ``ceil(context /
page_size)`` pages, growing as it generates.  When the sum exceeds the
pool the board is over-committed and the overflow pages must stream
over the HOST link (PCIe 1.1 x4 on the CMP 170HX) instead of HBM: the
spilled share of the per-step KV traffic is slowed by the
``hbm_bw / interconnect_bw`` ratio, which on this board is ~3 orders of
magnitude -- the model's way of saying "don't over-commit".  Routers
consult :meth:`SimNode.kv_overcommit` to see capacity as bytes rather
than lanes; ``kv_pool_pages=None`` (default) disables the constraint
and reproduces the pre-paging behavior exactly.

Multi-model residency (``models=``): a node may host a CATALOG of
models, of which a subset is *resident* (weights in HBM).  Weights and
KV pages compete for the same ``hbm_gb`` budget -- ``kv_pool_pages``
becomes whatever the resident weights leave over -- and a request for a
non-resident model pays the weight transfer over the same PCIe 1.1 x4
host link the KV migrations cross (``swap_in``), LRU-evicting idle
resident models to make room.  Each distinct resident model serving the
decode batch streams its own weights once per step, so co-hosting
models on one board dilates the shared step time -- the cost the
router's affinity term weighs against the swap.

Energy: the node integrates board power over simulated time (idle floor
plus dynamic power scaled by instantaneous occupancy); each request is
additionally charged its solo-cost joules via
:func:`repro.core.energy.request_energy_joules` -- per-model, so the
per-model tokens/joule accounting the power-aware benchmarking
motivates falls out of the report.
"""

from __future__ import annotations

import dataclasses
from typing import Deque, Dict, List, Optional, Sequence

from collections import deque

from repro.core.device_profile import DeviceProfile
from repro.core.energy import request_energy_joules
from repro.core.perf_model import InferencePerfModel, LLMSpec, QWEN25_1P5B
from repro.quant.formats import bytes_per_weight
from repro.serving.phase_model import kv_handoff_seconds, link_transfer_seconds


def _bucket(n: int, step: int = 32) -> int:
    """Round a length to a cache bucket (exact for multiples of step)."""
    return max(step, int(round(n / step)) * step)


#: token-count slack for "generation finished" -- absorbs float drift in
#: the processor-sharing integration so completion events cannot
#: reschedule themselves with ~1e-16 token progress (a livelock).
_DONE_EPS = 1e-9


@dataclasses.dataclass
class DecodeSlot:
    """One request resident in the decode batch."""

    uid: int
    gen_len: int
    t_comp_s: float          # per-step MAC+epilogue time for this context
    t_kv_s: float            # per-step KV streaming time for this context
    dyn_j_per_tok: float     # dynamic (above-idle) joules per token
    prompt_len: int = 0      # live context = prompt_len + tokens_done
    tokens_done: float = 0.0
    t_first_token: Optional[float] = None
    model_id: Optional[str] = None
    #: per-step weight-stream time of THIS slot's model -- paid once per
    #: step per distinct resident model in the batch, not per lane
    t_weights_s: float = 0.0
    #: tokens covered by the last host-side lane checkpoint (None: no
    #: checkpoint interval has elapsed yet).  On a node crash the slot
    #: resumes from here -- tokens past it are lost with the board's HBM
    ckpt_tokens: Optional[int] = None
    #: prompt-prefix family (see ``FleetRequest``): on a prefix-sharing
    #: board the family's full prefix pages are physical ONCE no matter
    #: how many resident slots open with them
    prefix_id: Optional[int] = None
    prefix_len: int = 0


class SimNode:
    """One simulated board with a role and queues (see module docstring)."""

    def __init__(self, node_id: str, profile: DeviceProfile, role: str,
                 fmt: str, spec: LLMSpec = QWEN25_1P5B,
                 decode_lanes: int = 1, page_size: int = 16,
                 kv_pool_pages: Optional[int] = None,
                 models: Optional[Dict[str, LLMSpec]] = None,
                 resident_models: Optional[Sequence[str]] = None,
                 hbm_gb: Optional[float] = None,
                 weight_fmt: Optional[str] = None,
                 prefix_sharing: bool = False):
        assert role in ("prefill", "decode", "both"), role
        self.node_id = node_id
        self.profile = profile
        self.role = role
        self.fmt = fmt
        self.spec = spec
        self.decode_lanes = decode_lanes
        self.page_size = page_size
        #: model the engine's copy-on-write prefix cache: resident slots
        #: of one prefix family share the family's full prefix pages
        self.prefix_sharing = prefix_sharing
        self._kv_pool_pages_static = kv_pool_pages
        self._model = InferencePerfModel(profile, spec)
        # multi-model catalog: per-model perf models + weight bytes, a
        # resident subset, and (optionally) one HBM byte budget that
        # weights and KV pages share
        self.models = dict(models) if models else None
        self._weight_fmt = weight_fmt or fmt
        if self.models:
            self._perf = {m: InferencePerfModel(profile, s)
                          for m, s in self.models.items()}
            self._weight_bytes = {
                m: s.params_total * bytes_per_weight(self._weight_fmt)
                for m, s in self.models.items()}
            keep = (list(resident_models) if resident_models is not None
                    else list(self.models))
            self.resident_models: Dict[str, float] = {m: 0.0 for m in keep}
        else:
            self._perf = {}
            self._weight_bytes = {}
            self.resident_models = {}
        self._hbm_bytes = hbm_gb * 1e9 if hbm_gb is not None else None
        # pages are token-denominated and SHARED across models, so a
        # multi-model board prices them conservatively at the largest
        # catalog model's KV row -- capacity is never overcounted
        kv_tok = spec.kv_bytes_per_token()
        if self.models:
            kv_tok = max([kv_tok] + [s.kv_bytes_per_token()
                                     for s in self.models.values()])
        self._page_bytes = page_size * kv_tok
        self._model_pins: Dict[str, int] = {}   # weights en route: no evict
        self.model_swaps = 0
        self.swap_bytes = 0.0
        self.model_evictions = 0
        self.model_tokens: Dict[str, float] = {}   # decoded tokens by model
        self.model_energy_j: Dict[str, float] = {}  # dynamic joules by model
        self._split = 0.5 if role == "both" else 1.0
        self._idle_w = InferencePerfModel.IDLE_FRACTION * profile.tdp_watts
        # caches keyed by (model, bucketed length/context)
        self._prefill_cache: Dict[tuple, tuple] = {}
        self._decode_cache: Dict[tuple, tuple] = {}
        self._req_energy_cache: Dict[tuple, float] = {}
        self._t_weights = 0.0    # per-step weight-stream time (ctx-free)
        # prefill FIFO state
        self.prefill_queue: Deque = deque()
        self.prefill_active: Optional[object] = None
        # True through compute AND the KV-handoff occupancy window --
        # the next queued request must not start until the KV has left
        self.prefill_busy = False
        self._prefill_backlog_s = 0.0
        self._backlog_asof = 0.0
        # decode processor-sharing state
        self.decode_active: Dict[int, DecodeSlot] = {}
        self.decode_queue: Deque[DecodeSlot] = deque()
        self._decode_last_t = 0.0
        self.decode_version = 0   # invalidates stale scheduled events
        # fault state (driven by repro.fleet.faults via the sim)
        self.failed = False        # crashed: permanently unroutable
        self.derate = 1.0          # compute/thermal time dilation (>= 1)
        self.link_derate = 1.0     # host-link time dilation (>= 1)
        self.stall_until = 0.0     # transient stall window end (sim clock)
        # fleet membership (set by the sim / autoscaler)
        self.draining = False
        self.available_at = 0.0   # cold-start: unroutable before this
        self.inbound_inflight = 0  # KV transfers en route to this node
        # pages promised to migrations still crossing the link: counted
        # against free capacity so a burst of evictions cannot route
        # more contexts here than the pool can hold when they land
        self.inbound_pages = 0
        # accounting
        self.energy_active_j = 0.0   # above-idle joules
        self.prefill_busy_s = 0.0
        self.tokens_prefilled = 0
        self.tokens_decoded = 0
        self.kv_pages_hwm = 0        # peak page occupancy observed
        self.kv_spill_events = 0     # over-commit transitions
        self._spilled = False
        self.preemptions = 0         # slots evicted mid-decode here
        self.pages_migrated_out = 0  # KV pages shipped off this board
        self.pages_migrated_in = 0   # KV pages landed from elsewhere

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    def bind_registry(self, registry) -> None:
        """Publish this board's counters as live callback gauges under
        ``fleet.node.<id>.*`` (read-through: the sim hot path pays
        nothing for being observable).  Note ``kv_spill_events`` here is
        the SIM's over-commit transition counter -- a distinct event
        from the engine's ``serve.kv.admit_blocked``."""
        prefix = f"fleet.node.{self.node_id}"
        for attr, help_text in (
                ("tokens_prefilled", "prompt tokens prefilled here"),
                ("tokens_decoded", "tokens decoded here"),
                ("kv_pages_hwm", "peak page occupancy observed"),
                ("kv_spill_events", "page-pool over-commit transitions"),
                ("preemptions", "slots evicted mid-decode here"),
                ("pages_migrated_out", "KV pages shipped off this board"),
                ("pages_migrated_in", "KV pages landed from elsewhere"),
                ("model_swaps", "weight loads over the host link"),
                ("swap_bytes", "weight bytes those swaps moved"),
                ("model_evictions", "weight sets LRU-evicted"),
                ("energy_active_j", "above-idle joules integrated")):
            registry.gauge(f"{prefix}.{attr}",
                           fn=(lambda a=attr: getattr(self, a)),
                           help=help_text)

    # ------------------------------------------------------------------
    # multi-model residency: weights vs KV pages in one HBM budget
    # ------------------------------------------------------------------
    def _hbm_after_weights(self) -> float:
        """Budget bytes the resident weights leave for KV -- negative
        when the weights alone over-commit the board."""
        return self._hbm_bytes - sum(self._weight_bytes[m]
                                     for m in self.resident_models)

    @property
    def kv_pool_pages(self) -> Optional[int]:
        """Pages the KV pool holds.  With an ``hbm_gb`` budget this is
        whatever the RESIDENT weights leave over (the multi-model
        trade-off); otherwise the statically configured count."""
        if self._hbm_bytes is None:
            return self._kv_pool_pages_static
        return max(int(self._hbm_after_weights() // self._page_bytes), 0)

    def _spec_for(self, mid: Optional[str]) -> LLMSpec:
        if mid is not None and self.models and mid in self.models:
            return self.models[mid]
        return self.spec

    def _perf_for(self, mid: Optional[str]) -> InferencePerfModel:
        if mid is not None and mid in self._perf:
            return self._perf[mid]
        return self._model

    def serves_model(self, mid: Optional[str]) -> bool:
        """Whether this node can host requests for ``mid`` at all."""
        return mid is None or self.models is None or mid in self.models

    def model_resident(self, mid: Optional[str]) -> bool:
        return (mid is None or self.models is None
                or mid in self.resident_models)

    def model_weight_bytes(self, mid: str) -> float:
        return self._weight_bytes[mid]

    def swap_pages(self, mid: str) -> int:
        """KV pages the model's weights displace from the shared pool."""
        return int(-(-self._weight_bytes[mid] // self._page_bytes))

    def swap_in_s(self, mid: Optional[str]) -> float:
        """Seconds a swap for ``mid`` would spend on the host link
        (0 when already resident) -- the router's estimate, no mutation."""
        if self.model_resident(mid):
            return 0.0
        return (link_transfer_seconds(self.profile, self._weight_bytes[mid])
                * self.link_derate)

    def pin_model(self, mid: str) -> None:
        """Weights (or a request) are en route for ``mid``: not evictable."""
        self._model_pins[mid] = self._model_pins.get(mid, 0) + 1

    def unpin_model(self, mid: str) -> None:
        self._model_pins[mid] = self._model_pins.get(mid, 0) - 1

    def _model_in_use(self, mid: str) -> bool:
        if self._model_pins.get(mid, 0) > 0:
            return True
        if any(s.model_id == mid for s in self.decode_active.values()):
            return True
        if any(s.model_id == mid for s in self.decode_queue):
            return True
        rec = self.prefill_active
        if rec is not None and getattr(rec.req, "model_id", None) == mid:
            return True
        return any(getattr(r.req, "model_id", None) == mid
                   for r in self.prefill_queue)

    def swap_in(self, mid: Optional[str], now: float) -> float:
        """Make ``mid`` resident; returns the modeled weight-transfer
        seconds (0 when already hot).  Idle resident models are LRU-
        evicted while the pool is over-committed -- a model with live
        slots (or pinned by an in-flight swap) is never evicted, so a
        board can end up page-starved instead, which the spill factor
        and the preemption policy then punish."""
        if self.model_resident(mid):
            if mid in self.resident_models:
                self.resident_models[mid] = now
            return 0.0
        t = (link_transfer_seconds(self.profile, self._weight_bytes[mid])
             * self.link_derate)
        self.resident_models[mid] = now
        self.model_swaps += 1
        self.swap_bytes += self._weight_bytes[mid]
        while self._hbm_bytes is not None and (
                self._hbm_after_weights() < 0 or self.kv_pages_free() < 0):
            cand = [m for m in self.resident_models
                    if m != mid and not self._model_in_use(m)]
            if not cand:
                break
            victim = min(cand, key=lambda m: (self.resident_models[m], m))
            del self.resident_models[victim]
            self.model_evictions += 1
        return t

    # ------------------------------------------------------------------
    # phase-estimate caches
    # ------------------------------------------------------------------
    def _prefill_est(self, prompt_len: int, mid: Optional[str] = None):
        key = (mid, _bucket(prompt_len))
        if key not in self._prefill_cache:
            est = self._perf_for(mid).prefill(self.fmt, key[1])
            self._prefill_cache[key] = (est.tokens_per_s, est.watts)
        return self._prefill_cache[key]

    def _decode_parts(self, context: int, mid: Optional[str] = None):
        """(t_compute, t_weights, t_kv, dyn_j_per_tok) per decode step."""
        key = (mid, _bucket(context))
        if key not in self._decode_cache:
            perf = self._perf_for(mid)
            est0 = perf.decode(self.fmt, context=0)
            est = perf.decode(self.fmt, context=key[1])
            t_comp = est.t_mac_s + est.t_epilogue_s
            t_w = est0.t_memory_s
            t_kv = est.t_memory_s - t_w
            step1 = max(t_comp, t_w + t_kv)
            dyn_j = max(est.watts - self._idle_w, 0.0) * step1
            if mid is None:
                self._t_weights = t_w
            self._decode_cache[key] = (t_comp, t_w, t_kv, dyn_j)
        return self._decode_cache[key]

    def request_energy_j(self, prompt_len: int, gen_len: int,
                         phase: str, mid: Optional[str] = None) -> float:
        """Solo-cost joules of running ``phase`` of a request here."""
        key = (prompt_len, gen_len, phase, mid)
        if key not in self._req_energy_cache:
            self._req_energy_cache[key] = request_energy_joules(
                self.profile, prompt_len, gen_len, self.fmt,
                self._spec_for(mid), phase=phase)
        return self._req_energy_cache[key]

    # ------------------------------------------------------------------
    # prefill: serial FIFO
    # ------------------------------------------------------------------
    def prefill_service_s(self, prompt_len: int,
                          mid: Optional[str] = None) -> float:
        tps, _ = self._prefill_est(prompt_len, mid)
        return prompt_len / (tps * self._split) * self.derate

    def prefill_handoff_s(self, prompt_len: int,
                          peer: Optional[DeviceProfile] = None,
                          mid: Optional[str] = None) -> float:
        return kv_handoff_seconds(self.profile, prompt_len,
                                  self._spec_for(mid),
                                  peer=peer) * self.link_derate

    def est_prefill_wait_s(self, now: float) -> float:
        """Backlog ahead of a newly routed request (router's estimate)."""
        wait = max(self._prefill_backlog_s - (now - self._backlog_asof), 0.0)
        return wait

    def note_prefill_routed(self, record, now: float) -> None:
        """Track virtual backlog so routers see in-flight commitments."""
        mid = getattr(record.req, "model_id", None)
        svc = self.prefill_service_s(record.req.prompt_len, mid)
        hand = self.prefill_handoff_s(record.req.prompt_len, mid=mid)
        self._prefill_backlog_s = (self.est_prefill_wait_s(now)
                                   + svc + hand + self.swap_in_s(mid))
        self._backlog_asof = now

    def start_prefill(self, record, now: float) -> float:
        """Begin compute for ``record``; returns the compute-done time.

        A non-resident model's weights cross the host link FIRST (the
        swap extends this request's occupancy window -- prefill cannot
        start without the weights)."""
        mid = getattr(record.req, "model_id", None)
        swap_s = self.swap_in(mid, now) if self.models else 0.0
        svc = self.prefill_service_s(record.req.prompt_len, mid) + swap_s
        _, watts = self._prefill_est(record.req.prompt_len, mid)
        self.prefill_active = record
        self.prefill_busy = True
        self.prefill_busy_s += svc
        self.energy_active_j += max(watts - self._idle_w, 0.0) * svc
        self.tokens_prefilled += record.req.prompt_len
        return now + svc

    # ------------------------------------------------------------------
    # decode: lane-limited processor sharing + page-pool occupancy
    # ------------------------------------------------------------------
    def _prefix_pages(self, prefix_len: int) -> int:
        """Full pages a prefix family can share (the engine never
        shares a partial tail page for good: CoW copies it on the
        consumer's first append)."""
        return max(int(prefix_len), 0) // self.page_size

    def _slot_shared(self, slot: DecodeSlot) -> int:
        """Pages of ``slot`` served from its family's shared prefix --
        capped so every slot keeps at least one private page (the
        engine's admission reserve: the live tail is always written)."""
        if not self.prefix_sharing or slot.prefix_id is None:
            return 0
        ctx = slot.prompt_len + int(slot.tokens_done)
        total = max(-(-ctx // self.page_size), 1)
        return min(self._prefix_pages(slot.prefix_len), total - 1)

    def _slot_pages(self, slot: DecodeSlot) -> int:
        """PRIVATE pages a resident slot occupies at its CURRENT live
        context.  Shared prefix pages are charged once per resident
        family (:meth:`_resident_prefix_pages`), not per slot -- the
        copy-on-write cache's whole capacity win."""
        ctx = slot.prompt_len + int(slot.tokens_done)
        return max(-(-ctx // self.page_size), 1) - self._slot_shared(slot)

    def _resident_prefix_pages(self) -> int:
        """One physical page charge per DISTINCT resident prefix
        family, however many slots opened with it."""
        fams: Dict[int, int] = {}
        for s in self.decode_active.values():
            shared = self._slot_shared(s)
            if shared:
                fams[s.prefix_id] = max(fams.get(s.prefix_id, 0), shared)
        return sum(fams.values())

    def kv_pages_in_use(self) -> int:
        return (sum(self._slot_pages(s)
                    for s in self.decode_active.values())
                + self._resident_prefix_pages())

    def kv_pages_free(self) -> int:
        """Free pages net of in-flight migration reservations (negative
        when over-committed); unbounded when no pool is configured."""
        if self.kv_pool_pages is None:
            return 1 << 30
        return (self.kv_pool_pages - self.kv_pages_in_use()
                - self.inbound_pages)

    def kv_bytes_free(self) -> float:
        """Router-facing capacity in BYTES, the paged-cache currency."""
        return (self.kv_pages_free() * self.page_size
                * self.spec.kv_bytes_per_token())

    def kv_pages_projected(self) -> int:
        """Pages the CURRENT residents will occupy at their FINAL
        contexts (plus in-flight reservations) -- what an anticipatory
        router scores instead of today's occupancy: a board that fits
        now but cannot fit its residents' futures is a migration (pages
        x transfer time over the host link) waiting to happen."""
        final = 0
        fams: Dict[int, int] = {}
        for s in self.decode_active.values():
            pages = max(-(-(s.prompt_len + s.gen_len)
                          // self.page_size), 1)
            shared = 0
            if self.prefix_sharing and s.prefix_id is not None:
                shared = min(self._prefix_pages(s.prefix_len), pages - 1)
                fams[s.prefix_id] = max(fams.get(s.prefix_id, 0), shared)
            final += pages - shared
        return final + sum(fams.values()) + self.inbound_pages

    def kv_overcommit(self, prompt_len: int = 0, gen_len: int = 0,
                      prefix_id: Optional[int] = None,
                      prefix_len: int = 0) -> int:
        """Pages by which admitting such a request (at its steady-state
        mid-generation context) would exceed the pool; 0 if it fits or
        no pool is configured.  A prefix-sharing board discounts the
        request's full prefix pages when its family is already resident
        -- routers therefore see the cache's EFFECTIVE capacity, and
        steer prefix siblings onto the boards that already hold their
        template."""
        if self.kv_pool_pages is None:
            return 0
        ctx = prompt_len + gen_len // 2
        need = -(-ctx // self.page_size) if ctx > 0 else 0
        if (need > 1 and self.prefix_sharing and prefix_id is not None
                and any(s.prefix_id == prefix_id
                        for s in self.decode_active.values())):
            need -= min(self._prefix_pages(prefix_len), need - 1)
        return max(need - self.kv_pages_free(), 0)

    # ------------------------------------------------------------------
    # preemption / migration: page-granular KV transfer over the host link
    # ------------------------------------------------------------------
    def migration_pages(self, context: int) -> int:
        """Pages a migration must ship for a live ``context`` -- KV
        moves in page units (``ceil(ctx / page_size)``), the same
        transfer unit the engine's :class:`LaneCheckpoint` captures."""
        return max(-(-int(context) // self.page_size), 1)

    def kv_page_transfer_s(self, n_pages: int,
                           peer: Optional[DeviceProfile] = None) -> float:
        """Seconds to move ``n_pages`` of KV over the host link,
        bottlenecked by the slower endpoint when ``peer`` is given --
        on the CMP 170HX both directions are strangled by the PCIe 1.1
        x4 link (~1 GB/s), which is the whole migration trade-off."""
        return kv_handoff_seconds(self.profile, n_pages * self.page_size,
                                  self.spec, peer=peer) * self.link_derate

    def preempt_slot(self, uid: int, now: float) -> DecodeSlot:
        """Evict a resident slot mid-stream: advance everyone to ``now``
        first so the slot leaves with its exact token progress, then
        remove it (promoting queued work into the freed lane)."""
        self.decode_advance(now)
        # queued slots occupy no pages and are never migration victims
        assert uid in self.decode_active, f"preempt of non-resident {uid}"
        slot = self.decode_active.pop(uid)
        while (self.decode_queue
               and len(self.decode_active) < self.decode_lanes):
            nxt = self.decode_queue.popleft()
            self.decode_active[nxt.uid] = nxt
        self.decode_version += 1
        self.preemptions += 1
        self._note_occupancy()
        return slot

    def resume_slot(self, slot: DecodeSlot) -> DecodeSlot:
        """Clone a preempted slot for residence HERE: identity and token
        progress carry over; the per-step compute/KV costs are
        re-estimated for this board at the resumed mid-generation
        context (the remaining tokens' steady-state view)."""
        done = int(slot.tokens_done)
        ctx = slot.prompt_len + done + max(slot.gen_len - done, 0) // 2
        t_comp, t_w, t_kv, dyn_j = self._decode_parts(max(ctx, 1),
                                                      slot.model_id)
        # a resumed slot holds EXCLUSIVE pages: the engine's evict
        # deep-copies shared prefix pages into the checkpoint and
        # restore re-anchors onto fresh ones, so the prefix discount
        # does not survive a migration
        return DecodeSlot(uid=slot.uid, gen_len=slot.gen_len,
                          t_comp_s=t_comp, t_kv_s=t_kv,
                          dyn_j_per_tok=dyn_j,
                          prompt_len=slot.prompt_len,
                          tokens_done=slot.tokens_done,
                          t_first_token=slot.t_first_token,
                          model_id=slot.model_id, t_weights_s=t_w,
                          ckpt_tokens=slot.ckpt_tokens)

    def _spill_factor(self) -> float:
        """Multiplier on the KV-stream term when over-committed: the
        overflow share of pages streams over the host link instead of
        HBM."""
        if self.kv_pool_pages is None:
            return 1.0
        in_use = self.kv_pages_in_use()
        if in_use <= self.kv_pool_pages:
            return 1.0
        spilled = (in_use - self.kv_pool_pages) / in_use
        link_ratio = (self.profile.hbm_bw_gbps
                      / max(self.profile.total_interconnect_gbps(), 1e-9))
        return 1.0 + spilled * (link_ratio - 1.0)

    def _note_occupancy(self) -> None:
        """Track page high-water mark and over-commit transitions."""
        in_use = self.kv_pages_in_use()
        self.kv_pages_hwm = max(self.kv_pages_hwm, in_use)
        over = (self.kv_pool_pages is not None
                and in_use > self.kv_pool_pages)
        if over and not self._spilled:
            self.kv_spill_events += 1
        self._spilled = over

    def _weights_stream_s(self, extra: Dict[Optional[str], float]) -> float:
        """Per-step weight-stream time: each DISTINCT model in the
        decode batch streams its weights once per step (co-hosting two
        models on one board pays both streams).  ``extra`` maps model
        ids a caller hypothetically adds to their weight times."""
        per_model: Dict[Optional[str], float] = {
            s.model_id: s.t_weights_s if s.model_id is not None
            else (s.t_weights_s or self._t_weights)
            for s in self.decode_active.values()}
        per_model.update(extra)
        if not per_model:
            return self._t_weights
        return sum(per_model.values())

    def _step_time_s(self) -> float:
        """Current per-token step time shared by all active lanes.

        Per-lane MACs and KV reads accumulate across the batch; each
        distinct model's weight stream is paid once per step (the
        continuous-batching bandwidth saving -- diluted when several
        models co-reside).  An over-committed page pool slows the KV
        term by the spilled share's host-link penalty.
        """
        if not self.decode_active:
            return 0.0
        comp_sum = sum(s.t_comp_s for s in self.decode_active.values())
        kv_sum = sum(s.t_kv_s for s in self.decode_active.values())
        kv_sum *= self._spill_factor()
        return (max(comp_sum, self._weights_stream_s({}) + kv_sum)
                / self._split * self.derate)

    def decode_load(self) -> int:
        return len(self.decode_active) + len(self.decode_queue)

    def est_decode_step_s(self, context: int, extra: int = 1,
                          mid: Optional[str] = None) -> float:
        """Predicted step time if ``extra`` more such lanes were active."""
        t_comp, t_w, t_kv, _ = self._decode_parts(context, mid)
        comp_sum = sum(s.t_comp_s for s in self.decode_active.values())
        kv_sum = sum(s.t_kv_s for s in self.decode_active.values())
        comp_sum += extra * t_comp
        kv_sum += extra * t_kv
        kv_sum *= self._spill_factor()
        t_weights = self._weights_stream_s({mid: t_w})
        return (max(comp_sum, t_weights + kv_sum)
                / self._split * self.derate)

    def make_slot(self, uid: int, prompt_len: int, gen_len: int,
                  model_id: Optional[str] = None,
                  prefix_id: Optional[int] = None,
                  prefix_len: int = 0) -> DecodeSlot:
        context = prompt_len + gen_len // 2
        t_comp, t_w, t_kv, dyn_j = self._decode_parts(context, model_id)
        return DecodeSlot(uid=uid, gen_len=gen_len, t_comp_s=t_comp,
                          t_kv_s=t_kv, dyn_j_per_tok=dyn_j,
                          prompt_len=prompt_len, model_id=model_id,
                          t_weights_s=t_w, prefix_id=prefix_id,
                          prefix_len=prefix_len)

    def decode_admit(self, slot: DecodeSlot, now: float) -> bool:
        """Returns True if the slot went active (else queued)."""
        if slot.model_id is not None and slot.model_id in self.resident_models:
            self.resident_models[slot.model_id] = now   # LRU touch
        self.decode_advance(now)
        if len(self.decode_active) < self.decode_lanes:
            self.decode_active[slot.uid] = slot
            self.decode_version += 1
            self._note_occupancy()
            return True
        self.decode_queue.append(slot)
        return False

    def decode_advance(self, now: float) -> List[DecodeSlot]:
        """Progress active lanes to ``now``; returns newly finished slots.

        A transient-fault stall window (``stall_until``) produces no
        tokens: the overlap with [last_t, now] is excised from the
        integration interval."""
        run_start = self._decode_last_t
        if self.stall_until > run_start:
            run_start = min(self.stall_until, now)
        dt = now - run_start
        if dt <= 0 or not self.decode_active:
            self._decode_last_t = max(self._decode_last_t, now)
            return []
        step = self._step_time_s()
        rate = 1.0 / step
        finished: List[DecodeSlot] = []
        for slot in self.decode_active.values():
            before = slot.tokens_done
            slot.tokens_done = min(before + rate * dt, float(slot.gen_len))
            advanced = slot.tokens_done - before
            if slot.t_first_token is None and slot.tokens_done >= 1.0:
                slot.t_first_token = run_start + (1.0 - before) * step
            self.energy_active_j += slot.dyn_j_per_tok * advanced
            self.tokens_decoded += advanced
            if slot.model_id is not None:
                self.model_tokens[slot.model_id] = (
                    self.model_tokens.get(slot.model_id, 0.0) + advanced)
                self.model_energy_j[slot.model_id] = (
                    self.model_energy_j.get(slot.model_id, 0.0)
                    + slot.dyn_j_per_tok * advanced)
            if slot.tokens_done >= slot.gen_len - _DONE_EPS:
                slot.tokens_done = float(slot.gen_len)
                finished.append(slot)
        for slot in finished:
            del self.decode_active[slot.uid]
        while (self.decode_queue
               and len(self.decode_active) < self.decode_lanes):
            nxt = self.decode_queue.popleft()
            self.decode_active[nxt.uid] = nxt
        if finished:
            self.decode_version += 1
        self._note_occupancy()
        self._decode_last_t = now
        return finished

    def decode_next_event_s(self, now: float) -> Optional[float]:
        """Absolute time of the next lane completion (None if idle)."""
        if not self.decode_active:
            return None
        step = self._step_time_s()
        remaining = min(slot.gen_len - slot.tokens_done
                        for slot in self.decode_active.values())
        return max(now, self.stall_until) + max(remaining, 0.0) * step

    # ------------------------------------------------------------------
    def idle_energy_j(self, duration_s: float) -> float:
        return self._idle_w * duration_s

    def __repr__(self) -> str:  # pragma: no cover
        return (f"SimNode({self.node_id}, {self.profile.name}, "
                f"role={self.role})")
