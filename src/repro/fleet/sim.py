"""Deterministic discrete-event simulator of a heterogeneous fleet.

The event loop advances a set of :class:`~repro.fleet.node.SimNode`\\ s
through a seeded arrival trace under a routing policy:

``arrive`` -> route to a prefill-capable node (FIFO) -> ``prefill_done``
-> route to a decode-capable node, shipping the KV over the bottleneck
interconnect -> ``decode_enter`` -> lane-limited continuous batching ->
completion.  The KV handoff is charged twice, deliberately asymmetric:
the *source* board's occupancy pays its own-link egress time (exactly
the static planner's ``effective_prefill_tps`` derating, which keeps
the two models in steady-state agreement), while the *request's* TTFT
pays the bottleneck-endpoint transfer time.

Determinism: all randomness lives in the trace generator's seed; events
are totally ordered by (time, insertion sequence) and all metric math
is straight float arithmetic -- the same seed yields bit-identical
reports.

Outputs (:class:`FleetReport`): TTFT/TPOT p50/p99, completed and
goodput requests/s, generated tokens/s, average watts (idle floor +
integrated dynamic power), $/hour (amortized capex + energy) and
$/Mtok.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.device_profile import get_profile
from repro.core.perf_model import LLMSpec, QWEN25_1P5B
from repro.fleet.node import SimNode
from repro.fleet.router import LeastLoadedRouter, Router
from repro.fleet.workload import FleetRequest
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import SpanTracer
from repro.serving.disaggregation import FleetPlan
from repro.serving.phase_model import capex_usd_per_hour, energy_usd_per_hour


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """A pool of identical boards with one role."""

    profile: str
    count: int
    role: str                 # "prefill" | "decode" | "both"
    decode_lanes: int = 1
    #: paged-KV model (see SimNode): pages per board; None = unconstrained
    kv_pool_pages: Optional[int] = None
    page_size: int = 16
    #: multi-model serving: catalog of model ids this board can host
    #: (resolved against FleetSim's ``model_specs``), the subset resident
    #: at t=0 (None = all), and the HBM budget weights and KV pages share
    model_ids: Optional[Tuple[str, ...]] = None
    resident: Optional[Tuple[str, ...]] = None
    hbm_gb: Optional[float] = None


def fleet_from_plan(plan: FleetPlan, decode_lanes: int = 1) -> List[NodeSpec]:
    """Node specs realizing a static planner's role assignment."""
    return [NodeSpec(profile=a.profile, count=a.count, role=a.role,
                     decode_lanes=decode_lanes)
            for a in plan.assignments]


@dataclasses.dataclass(frozen=True)
class PreemptionPolicy:
    """When the fleet evicts a live decode and replays it elsewhere.

    * ``on_page_exhaustion`` -- whenever a board's page pool goes
      over-committed (its KV would spill over the PCIe 1.1 x4 host
      link at ~1000x HBM cost), shed resident decodes -- largest
      remaining work first, the "long decode" of the power-capping
      motivation -- until the pool fits or no destination will take
      them;
    * ``straggler_factor`` -- at every decode event, migrate a slot
      whose predicted completion HERE exceeds ``factor`` x its
      predicted completion on the best peer INCLUDING the page
      transfer time (None disables);
    * ``max_migrations_per_request`` -- thrash bound: a request that
      has already moved this many times is pinned where it is.
    """

    on_page_exhaustion: bool = True
    straggler_factor: Optional[float] = None
    max_migrations_per_request: int = 1


@dataclasses.dataclass
class RequestRecord:
    """Per-request timeline collected by the simulator."""

    req: FleetRequest
    prefill_node: Optional[str] = None
    decode_node: Optional[str] = None
    t_prefill_start: Optional[float] = None
    t_prefill_done: Optional[float] = None
    t_decode_enter: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    energy_j: float = 0.0
    preemptions: int = 0      # times this request was evicted mid-decode

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def ttft_s(self) -> float:
        return self.t_first_token - self.req.arrival_s

    @property
    def tpot_s(self) -> float:
        if self.req.gen_len <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (self.req.gen_len - 1)


@dataclasses.dataclass(frozen=True)
class FleetReport:
    """Aggregate metrics of one simulated run."""

    offered: int
    completed: int
    makespan_s: float
    ttft_p50_s: float
    ttft_p99_s: float
    tpot_p50_s: float
    tpot_p99_s: float
    requests_per_s: float
    goodput_rps: float
    gen_tokens_per_s: float
    avg_watts: float
    energy_j: float
    joules_per_request: float   # mean solo-cost attribution (completed)
    usd_per_hour: float
    usd_per_mtok: float
    preemptions: int = 0        # mid-decode evictions across the fleet
    pages_migrated: int = 0     # KV pages shipped between boards
    model_swaps: int = 0        # weight loads over host links
    swap_bytes: float = 0.0     # weight bytes those swaps moved
    #: per-model decode quality/efficiency: (model_id, tpot_p50_s,
    #: gen_tokens, tokens_per_joule) -- the power-aware per-model
    #: accounting; empty for single-model traces
    per_model: Tuple[Tuple[str, float, int, float], ...] = ()
    scale_events: Tuple[str, ...] = ()
    preempt_events: Tuple[str, ...] = ()
    swap_events: Tuple[str, ...] = ()

    def metrics(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d.pop("scale_events")
        d.pop("preempt_events")
        d.pop("swap_events")
        d.pop("per_model")
        return d


class FleetSim:
    """Trace-driven simulation of a routed heterogeneous fleet."""

    def __init__(self, specs: Sequence[NodeSpec],
                 trace: Sequence[FleetRequest], fmt: str = "q8_0",
                 spec: LLMSpec = QWEN25_1P5B,
                 router: Optional[Router] = None,
                 ttft_slo_s: Optional[float] = None,
                 tpot_slo_s: Optional[float] = None,
                 power_usd_per_kwh: float = 0.10,
                 amortization_years: float = 3.0,
                 autoscaler=None,
                 preemption: Optional[PreemptionPolicy] = None,
                 model_specs: Optional[Dict[str, LLMSpec]] = None,
                 tracer: Optional[SpanTracer] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.fmt = fmt
        self.spec = spec
        # deterministic SIM-CLOCK telemetry: spans carry simulated
        # seconds (add_span, never the host clock), so the same seed
        # yields a bit-identical trace file
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else SpanTracer(
            enabled=False, registry=self.registry)
        self.model_specs = model_specs
        self.router = router or LeastLoadedRouter()
        self.ttft_slo_s = ttft_slo_s
        self.tpot_slo_s = tpot_slo_s
        self.power_usd_per_kwh = power_usd_per_kwh
        self.amortization_years = amortization_years
        self.autoscaler = autoscaler
        self.nodes: List[SimNode] = []
        self.retired: List[SimNode] = []
        self._node_seq = 0
        self._added_at: Dict[str, float] = {}
        self._retired_at: Dict[str, float] = {}
        for ns in specs:
            for _ in range(ns.count):
                self.add_node(ns, now=0.0)
        self.records = [RequestRecord(req=r) for r in trace]
        self._slot_rec: Dict[Tuple[str, int], RequestRecord] = {}
        self.scale_events: List[str] = []
        self.swap_events: List[str] = []
        self.preemption = preemption
        self.preempt_events: List[str] = []
        self._migrations: Dict[int, int] = {}   # uid -> moves so far
        self._heap: List[tuple] = []
        self._seq = 0

    # -- fleet mutation (autoscaler hooks) -----------------------------
    def add_node(self, ns: NodeSpec, now: float) -> SimNode:
        models = None
        if ns.model_ids is not None:
            assert self.model_specs is not None, (
                "NodeSpec names model_ids but FleetSim has no model_specs")
            models = {m: self.model_specs[m] for m in ns.model_ids}
        node = SimNode(node_id=f"{ns.profile}/{ns.role}#{self._node_seq}",
                       profile=get_profile(ns.profile), role=ns.role,
                       fmt=self.fmt, spec=self.spec,
                       decode_lanes=ns.decode_lanes,
                       page_size=ns.page_size,
                       kv_pool_pages=ns.kv_pool_pages,
                       models=models, resident_models=ns.resident,
                       hbm_gb=ns.hbm_gb)
        self._node_seq += 1
        node.available_at = now
        self.nodes.append(node)
        self._added_at[node.node_id] = now
        node.bind_registry(self.registry)
        return node

    def retire_node(self, node: SimNode, now: float) -> None:
        """Stop routing to ``node``; it leaves once its work drains."""
        node.draining = True
        self._maybe_reap(node, now)

    def _maybe_reap(self, node: SimNode, now: float) -> None:
        busy = (node.prefill_busy or node.prefill_queue
                or node.decode_active or node.decode_queue
                or node.inbound_inflight)
        if node.draining and not busy and node in self.nodes:
            self.nodes.remove(node)
            self.retired.append(node)
            self._retired_at[node.node_id] = now

    def _routable(self, now: float) -> List[SimNode]:
        return [n for n in self.nodes
                if not n.draining and n.available_at <= now]

    # -- event plumbing -------------------------------------------------
    def _push(self, t: float, kind: str, payload) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, payload))

    def _schedule_decode(self, node: SimNode, now: float) -> None:
        t = node.decode_next_event_s(now)
        if t is not None:
            self._push(t, "decode", (node, node.decode_version))

    # -- event handlers -------------------------------------------------
    def _on_arrive(self, rec: RequestRecord, now: float) -> None:
        node = self.router.route_prefill(rec, self._routable(now), now)
        rec.prefill_node = node.node_id
        if not node.prefill_busy and not node.prefill_queue:
            self._start_prefill(node, rec, now)
        else:
            node.prefill_queue.append(rec)

    def _start_prefill(self, node: SimNode, rec: RequestRecord,
                       now: float) -> None:
        rec.t_prefill_start = now
        done_t = node.start_prefill(rec, now)
        self.tracer.add_span("sim.prefill", now, done_t,
                             track=node.node_id, uid=rec.req.uid,
                             prompt_len=rec.req.prompt_len)
        self._push(done_t, "prefill_done", (node, rec))

    def _on_prefill_done(self, node: SimNode, rec: RequestRecord,
                         now: float) -> None:
        rec.t_prefill_done = now
        node.prefill_active = None
        mid = getattr(rec.req, "model_id", None)
        dst = self.router.route_decode(rec, node, self._routable(now), now)
        rec.decode_node = dst.node_id
        plen = rec.req.prompt_len
        if dst is node:
            occupancy_s = transfer_s = 0.0    # KV stays in HBM
        else:
            occupancy_s = node.prefill_handoff_s(plen, mid=mid)
            transfer_s = node.prefill_handoff_s(plen, peer=dst.profile,
                                                mid=mid)
        rec.energy_j += node.request_energy_j(plen, rec.req.gen_len,
                                              phase="prefill", mid=mid)
        dst.inbound_inflight += 1      # blocks reaping until KV lands
        self._push(now + transfer_s, "decode_enter", (dst, rec))
        if occupancy_s > 0:
            self._push(now + occupancy_s, "prefill_free", node)
        else:
            self._on_prefill_free(node, now)

    def _on_prefill_free(self, node: SimNode, now: float) -> None:
        node.prefill_busy = False
        if node.prefill_queue:
            self._start_prefill(node, node.prefill_queue.popleft(), now)
        self._maybe_reap(node, now)

    def _on_decode_enter(self, node: SimNode, rec: RequestRecord,
                         now: float, pinned: bool = False) -> None:
        node.inbound_inflight -= 1
        mid = getattr(rec.req, "model_id", None)
        if pinned:
            node.unpin_model(mid)
        if node.models is not None and mid is not None:
            # weights must be resident before the first decode step: a
            # cold model swaps in over the host link, and the request
            # re-enters once the shards land.  The pin keeps the model
            # from being LRU-evicted while its weights are in flight
            # (a second request for the same model piggybacks on the
            # swap already underway: swap_in sees it resident).
            swap_s = node.swap_in(mid, now)
            if swap_s > 0:
                node.pin_model(mid)
                node.inbound_inflight += 1   # still en route: no reaping
                self.swap_events.append(
                    f"t={now:.2f}s {node.node_id} <- weights[{mid}] "
                    f"({swap_s * 1e3:.0f}ms)")
                self.tracer.add_span("sim.swap", now, now + swap_s,
                                     track=f"{node.node_id}/link",
                                     model_id=mid, uid=rec.req.uid)
                self._push(now + swap_s, "decode_enter", (node, rec, True))
                return
        rec.t_decode_enter = now
        if rec.req.gen_len <= 0:      # nothing to decode: done on arrival
            rec.t_first_token = now
            rec.t_done = now
            self._maybe_reap(node, now)
            return
        rec.energy_j += node.request_energy_j(rec.req.prompt_len,
                                              rec.req.gen_len,
                                              phase="decode", mid=mid)
        self._finish(node, node.decode_advance(now), now)
        slot = node.make_slot(rec.req.uid, rec.req.prompt_len,
                              rec.req.gen_len, model_id=mid)
        self._slot_rec[(node.node_id, rec.req.uid)] = rec
        node.decode_admit(slot, now)
        self._maybe_preempt(node, now)
        self._schedule_decode(node, now)

    def _on_decode(self, node: SimNode, version: int, now: float) -> None:
        if version != node.decode_version or node not in self.nodes:
            return                          # stale membership snapshot
        self._finish(node, node.decode_advance(now), now)
        self._maybe_preempt(node, now)
        self._schedule_decode(node, now)
        self._maybe_reap(node, now)

    # -- preemption & KV-page migration --------------------------------
    def _movable(self, node: SimNode) -> List:
        """Resident slots eligible for eviction, most remaining work
        first (deterministic: ties break on uid)."""
        cap = (self.preemption.max_migrations_per_request
               if self.preemption else 0)
        slots = [s for s in node.decode_active.values()
                 if self._migrations.get(s.uid, 0) < cap]
        return sorted(slots, key=lambda s: (-(s.gen_len - s.tokens_done),
                                            s.uid))

    def _maybe_preempt(self, node: SimNode, now: float) -> None:
        """Apply the preemption policy to ``node`` after its decode
        state changed: shed slots while the page pool is over-committed,
        and (optionally) rescue stragglers a peer would finish sooner
        despite paying the page transfer."""
        pol = self.preemption
        if pol is None or node not in self.nodes:
            return
        if pol.on_page_exhaustion:
            while node.kv_pages_free() < 0:
                moved = False
                for slot in self._movable(node):
                    dst = self.router.route_migration(
                        slot, node, self._routable(now), now)
                    if dst is not None:
                        self._migrate(node, slot, dst, now)
                        moved = True
                        break
                if not moved:       # nowhere to shed to: spill and bear it
                    break
        if pol.straggler_factor is not None:
            for slot in self._movable(node):
                remaining = slot.gen_len - slot.tokens_done
                if remaining <= 0:
                    continue
                t_here = remaining * node.est_decode_step_s(
                    slot.prompt_len + int(slot.tokens_done), extra=0,
                    mid=getattr(slot, "model_id", None))
                dst = self.router.route_migration(
                    slot, node, self._routable(now), now)
                if dst is None:
                    continue
                ctx = slot.prompt_len + int(slot.tokens_done)
                t_there = (node.kv_page_transfer_s(
                    node.migration_pages(ctx), peer=dst.profile)
                    + remaining * dst.est_decode_step_s(
                        ctx, extra=1, mid=getattr(slot, "model_id", None)))
                if t_here > pol.straggler_factor * t_there:
                    self._migrate(node, slot, dst, now)

    def _migrate(self, src: SimNode, slot, dst: SimNode,
                 now: float) -> None:
        """Evict ``slot`` from ``src`` and replay it on ``dst`` after
        its KV pages cross the host link (the request is in flight --
        nobody decodes it -- for the whole transfer)."""
        src.preempt_slot(slot.uid, now)
        ctx = slot.prompt_len + int(slot.tokens_done)
        n_pg = src.migration_pages(ctx)
        transfer_s = src.kv_page_transfer_s(n_pg, peer=dst.profile)
        mid = getattr(slot, "model_id", None)
        if dst.models is not None and mid is not None:
            # a destination without the slot's model hot swaps its
            # weights in alongside the KV pages (same host link); the
            # pin keeps them from being evicted before the slot lands
            transfer_s += dst.swap_in(mid, now)
            dst.pin_model(mid)
        src.pages_migrated_out += n_pg
        rec = self._slot_rec.pop((src.node_id, slot.uid))
        rec.preemptions += 1
        self._migrations[slot.uid] = self._migrations.get(slot.uid, 0) + 1
        dst.inbound_inflight += 1      # blocks reaping until KV lands
        dst.inbound_pages += n_pg      # reserves capacity while in flight
        self.tracer.add_span("sim.migrate", now, now + transfer_s,
                             track=f"{src.node_id}/link", uid=slot.uid,
                             pages=n_pg, dst=dst.node_id)
        self._push(now + transfer_s, "migrate_enter",
                   (dst, slot, rec, n_pg))
        self.preempt_events.append(
            f"t={now:.2f}s uid={slot.uid} {src.node_id} -> {dst.node_id} "
            f"pages={n_pg} transfer={transfer_s * 1e3:.1f}ms")
        self._schedule_decode(src, now)
        self._maybe_reap(src, now)

    def _on_migrate_enter(self, dst: SimNode, slot, rec: RequestRecord,
                          n_pg: int, now: float) -> None:
        dst.inbound_inflight -= 1
        dst.inbound_pages -= n_pg      # reservation becomes occupancy
        dst.pages_migrated_in += n_pg
        mid = getattr(slot, "model_id", None)
        if dst.models is not None and mid is not None:
            dst.unpin_model(mid)
        rec.decode_node = dst.node_id
        self._finish(dst, dst.decode_advance(now), now)
        resumed = dst.resume_slot(slot)
        self._slot_rec[(dst.node_id, resumed.uid)] = rec
        dst.decode_admit(resumed, now)
        self._maybe_preempt(dst, now)
        self._schedule_decode(dst, now)

    def _finish(self, node: SimNode, slots, now: float) -> None:
        for slot in slots:
            rec = self._slot_rec.pop((node.node_id, slot.uid))
            rec.t_first_token = slot.t_first_token
            rec.t_done = now
            if rec.t_decode_enter is not None:
                # per-request track: concurrent slots on one board
                # would partially overlap on a shared track
                self.tracer.add_span("sim.decode", rec.t_decode_enter,
                                     now,
                                     track=f"{node.node_id}/u{slot.uid}",
                                     uid=slot.uid,
                                     gen_len=rec.req.gen_len)

    def _on_autoscale(self, now: float) -> None:
        if self.autoscaler is None:
            return
        self.scale_events.extend(self.autoscaler.tick(self, now))
        if any(not rec.done for rec in self.records):
            self._push(now + self.autoscaler.interval_s, "autoscale", None)

    # -- main loop ------------------------------------------------------
    def run(self) -> FleetReport:
        for rec in self.records:
            self._push(rec.req.arrival_s, "arrive", rec)
        if self.autoscaler is not None:
            self._push(self.autoscaler.interval_s, "autoscale", None)
        now = 0.0
        while self._heap:
            now, _, kind, payload = heapq.heappop(self._heap)
            if kind == "arrive":
                self._on_arrive(payload, now)
            elif kind == "prefill_done":
                self._on_prefill_done(payload[0], payload[1], now)
            elif kind == "prefill_free":
                self._on_prefill_free(payload, now)
            elif kind == "decode_enter":
                self._on_decode_enter(payload[0], payload[1], now,
                                      *payload[2:])
            elif kind == "decode":
                self._on_decode(payload[0], payload[1], now)
            elif kind == "migrate_enter":
                self._on_migrate_enter(payload[0], payload[1], payload[2],
                                       payload[3], now)
            elif kind == "autoscale":
                self._on_autoscale(now)
        return self._report(makespan=now)

    # -- metrics --------------------------------------------------------
    def _node_uptime_s(self, node: SimNode, makespan: float) -> float:
        t0 = self._added_at.get(node.node_id, 0.0)
        t1 = self._retired_at.get(node.node_id, makespan)
        return max(t1 - t0, 0.0)

    def _report(self, makespan: float) -> FleetReport:
        done = [r for r in self.records if r.done]
        makespan = max(makespan, 1e-9)
        ttft = np.array(sorted(r.ttft_s for r in done), np.float64)
        tpot = np.array(sorted(r.tpot_s for r in done), np.float64)

        def pct(arr, q):
            return float(np.percentile(arr, q)) if arr.size else float("nan")

        def meets_slo(r: RequestRecord) -> bool:
            if self.ttft_slo_s is not None and r.ttft_s > self.ttft_slo_s:
                return False
            if self.tpot_slo_s is not None and r.tpot_s > self.tpot_slo_s:
                return False
            return True

        energy = 0.0
        usd_hour = 0.0
        for node in self.nodes + self.retired:
            up = self._node_uptime_s(node, makespan)
            energy += node.energy_active_j + node.idle_energy_j(up)
            usd_hour += (capex_usd_per_hour(node.profile,
                                            self.amortization_years)
                         * up / makespan)
        avg_watts = energy / makespan
        usd_hour += energy_usd_per_hour(avg_watts, self.power_usd_per_kwh)
        gen_tok = sum(r.req.gen_len for r in done)
        gen_tok_s = gen_tok / makespan
        usd_per_mtok = usd_hour / max(gen_tok_s * 3600.0 / 1e6, 1e-9)
        good = sum(1 for r in done if meets_slo(r))
        # per-model decode accounting (tpot + tokens/joule), multi-model
        # traces only -- the nodes integrate per-model dynamic energy
        by_model: Dict[str, List[float]] = {}
        for r in done:
            mid = getattr(r.req, "model_id", None)
            if mid is not None:
                by_model.setdefault(mid, []).append(r.tpot_s)
        per_model = []
        for mid in sorted(by_model):
            toks = sum(n.model_tokens.get(mid, 0.0)
                       for n in self.nodes + self.retired)
            joules = sum(n.model_energy_j.get(mid, 0.0)
                         for n in self.nodes + self.retired)
            per_model.append((mid, pct(np.asarray(sorted(by_model[mid])), 50),
                              int(round(toks)),
                              toks / joules if joules > 0 else float("nan")))
        report = FleetReport(
            offered=len(self.records), completed=len(done),
            makespan_s=makespan,
            ttft_p50_s=pct(ttft, 50), ttft_p99_s=pct(ttft, 99),
            tpot_p50_s=pct(tpot, 50), tpot_p99_s=pct(tpot, 99),
            requests_per_s=len(done) / makespan,
            goodput_rps=good / makespan,
            gen_tokens_per_s=gen_tok_s,
            avg_watts=avg_watts, energy_j=energy,
            joules_per_request=(sum(r.energy_j for r in done) / len(done)
                                if done else float("nan")),
            usd_per_hour=usd_hour, usd_per_mtok=usd_per_mtok,
            preemptions=sum(n.preemptions
                            for n in self.nodes + self.retired),
            pages_migrated=sum(n.pages_migrated_out
                               for n in self.nodes + self.retired),
            model_swaps=sum(n.model_swaps
                            for n in self.nodes + self.retired),
            swap_bytes=sum(n.swap_bytes
                           for n in self.nodes + self.retired),
            per_model=tuple(per_model),
            scale_events=tuple(self.scale_events),
            preempt_events=tuple(self.preempt_events),
            swap_events=tuple(self.swap_events))
        # publish the aggregate report under the fleet.* namespace so
        # the sim's numbers sit next to the engines' in one exposition
        for key, val in report.metrics().items():
            self.registry.gauge(f"fleet.{key}").set(float(val))
        return report
