"""Deterministic discrete-event simulator of a heterogeneous fleet.

The event loop advances a set of :class:`~repro.fleet.node.SimNode`\\ s
through a seeded arrival trace under a routing policy:

``arrive`` -> route to a prefill-capable node (FIFO) -> ``prefill_done``
-> route to a decode-capable node, shipping the KV over the bottleneck
interconnect -> ``decode_enter`` -> lane-limited continuous batching ->
completion.  The KV handoff is charged twice, deliberately asymmetric:
the *source* board's occupancy pays its own-link egress time (exactly
the static planner's ``effective_prefill_tps`` derating, which keeps
the two models in steady-state agreement), while the *request's* TTFT
pays the bottleneck-endpoint transfer time.

Determinism: all randomness lives in the trace generator's seed; events
are totally ordered by (time, insertion sequence) and all metric math
is straight float arithmetic -- the same seed yields bit-identical
reports.

Outputs (:class:`FleetReport`): TTFT/TPOT p50/p99, completed and
goodput requests/s, generated tokens/s, average watts (idle floor +
integrated dynamic power), $/hour (amortized capex + energy) and
$/Mtok.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.device_profile import get_profile
from repro.core.perf_model import LLMSpec, QWEN25_1P5B
from repro.fleet.faults import FaultEvent, FaultInjector, FaultPlan, \
    RecoveryPolicy
from repro.fleet.node import SimNode
from repro.fleet.router import LeastLoadedRouter, Router
from repro.fleet.workload import FleetRequest
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import SpanTracer
from repro.serving.disaggregation import FleetPlan
from repro.serving.phase_model import capex_usd_per_hour, energy_usd_per_hour
from repro.train.fault_tolerance import StragglerMonitor


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """A pool of identical boards with one role."""

    profile: str
    count: int
    role: str                 # "prefill" | "decode" | "both"
    decode_lanes: int = 1
    #: paged-KV model (see SimNode): pages per board; None = unconstrained
    kv_pool_pages: Optional[int] = None
    page_size: int = 16
    #: multi-model serving: catalog of model ids this board can host
    #: (resolved against FleetSim's ``model_specs``), the subset resident
    #: at t=0 (None = all), and the HBM budget weights and KV pages share
    model_ids: Optional[Tuple[str, ...]] = None
    resident: Optional[Tuple[str, ...]] = None
    hbm_gb: Optional[float] = None
    #: boards run the engine's copy-on-write prefix cache: slots of one
    #: prefix family share its full prefix pages (see SimNode)
    prefix_sharing: bool = False


def fleet_from_plan(plan: FleetPlan, decode_lanes: int = 1) -> List[NodeSpec]:
    """Node specs realizing a static planner's role assignment."""
    return [NodeSpec(profile=a.profile, count=a.count, role=a.role,
                     decode_lanes=decode_lanes)
            for a in plan.assignments]


@dataclasses.dataclass(frozen=True)
class PreemptionPolicy:
    """When the fleet evicts a live decode and replays it elsewhere.

    * ``on_page_exhaustion`` -- whenever a board's page pool goes
      over-committed (its KV would spill over the PCIe 1.1 x4 host
      link at ~1000x HBM cost), shed resident decodes -- largest
      remaining work first, the "long decode" of the power-capping
      motivation -- until the pool fits or no destination will take
      them;
    * ``straggler_factor`` -- at every decode event, migrate a slot
      whose predicted completion HERE exceeds ``factor`` x its
      predicted completion on the best peer INCLUDING the page
      transfer time (None disables);
    * ``max_migrations_per_request`` -- thrash bound: a request that
      has already moved this many times is pinned where it is.
    """

    on_page_exhaustion: bool = True
    straggler_factor: Optional[float] = None
    max_migrations_per_request: int = 1


@dataclasses.dataclass
class RequestRecord:
    """Per-request timeline collected by the simulator."""

    req: FleetRequest
    prefill_node: Optional[str] = None
    decode_node: Optional[str] = None
    t_prefill_start: Optional[float] = None
    t_prefill_done: Optional[float] = None
    t_decode_enter: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    energy_j: float = 0.0
    preemptions: int = 0      # times this request was evicted mid-decode

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def ttft_s(self) -> float:
        return self.t_first_token - self.req.arrival_s

    @property
    def tpot_s(self) -> float:
        if self.req.gen_len <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (self.req.gen_len - 1)


@dataclasses.dataclass(frozen=True)
class FleetReport:
    """Aggregate metrics of one simulated run."""

    offered: int
    completed: int
    makespan_s: float
    ttft_p50_s: float
    ttft_p99_s: float
    tpot_p50_s: float
    tpot_p99_s: float
    requests_per_s: float
    goodput_rps: float
    gen_tokens_per_s: float
    avg_watts: float
    energy_j: float
    joules_per_request: float   # mean solo-cost attribution (completed)
    usd_per_hour: float
    usd_per_mtok: float
    preemptions: int = 0        # mid-decode evictions across the fleet
    pages_migrated: int = 0     # KV pages shipped between boards
    model_swaps: int = 0        # weight loads over host links
    swap_bytes: float = 0.0     # weight bytes those swaps moved
    #: per-model decode quality/efficiency: (model_id, tpot_p50_s,
    #: gen_tokens, tokens_per_joule) -- the power-aware per-model
    #: accounting; empty for single-model traces
    per_model: Tuple[Tuple[str, float, int, float], ...] = ()
    scale_events: Tuple[str, ...] = ()
    preempt_events: Tuple[str, ...] = ()
    swap_events: Tuple[str, ...] = ()
    # fault-tolerance accounting (FaultPlan/RecoveryPolicy runs)
    crashes: int = 0            # boards lost mid-run
    derates: int = 0            # compute/thermal derate events
    link_faults: int = 0        # host-link degradation windows
    transients: int = 0         # transient dispatch stalls
    retries: int = 0            # request retry attempts fired
    hedges: int = 0             # tail-latency hedges launched
    requests_lost: int = 0      # retries exhausted / no destination
    recovered_lanes: int = 0    # crashed lanes resumed from checkpoint
    replayed_from_prompt: int = 0  # crashed lanes with no usable ckpt
    checkpoints: int = 0        # checkpoint ticks taken
    fault_events: Tuple[str, ...] = ()
    derate_detected: Tuple[str, ...] = ()   # straggler-monitor verdicts

    def metrics(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d.pop("scale_events")
        d.pop("preempt_events")
        d.pop("swap_events")
        d.pop("per_model")
        d.pop("fault_events")
        d.pop("derate_detected")
        return d


class FleetSim:
    """Trace-driven simulation of a routed heterogeneous fleet."""

    def __init__(self, specs: Sequence[NodeSpec],
                 trace: Sequence[FleetRequest], fmt: str = "q8_0",
                 spec: LLMSpec = QWEN25_1P5B,
                 router: Optional[Router] = None,
                 ttft_slo_s: Optional[float] = None,
                 tpot_slo_s: Optional[float] = None,
                 power_usd_per_kwh: float = 0.10,
                 amortization_years: float = 3.0,
                 autoscaler=None,
                 preemption: Optional[PreemptionPolicy] = None,
                 model_specs: Optional[Dict[str, LLMSpec]] = None,
                 tracer: Optional[SpanTracer] = None,
                 registry: Optional[MetricsRegistry] = None,
                 faults: Optional[FaultPlan] = None,
                 recovery: Optional[RecoveryPolicy] = None,
                 detect_stragglers: bool = False,
                 slo=None, flight=None):
        self.fmt = fmt
        self.spec = spec
        # deterministic SIM-CLOCK telemetry: spans carry simulated
        # seconds (add_span, never the host clock), so the same seed
        # yields a bit-identical trace file
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else SpanTracer(
            enabled=False, registry=self.registry)
        # SLO burn-rate control loop (an SLOController) fed with SIM
        # seconds at every request completion, and a flight recorder
        # tapped into the tracer (dumped on simulated crashes)
        self.slo = slo
        self.flight = flight
        if flight is not None:
            flight.attach(tracer=self.tracer)
        self.model_specs = model_specs
        self.router = router or LeastLoadedRouter()
        self.ttft_slo_s = ttft_slo_s
        self.tpot_slo_s = tpot_slo_s
        self.power_usd_per_kwh = power_usd_per_kwh
        self.amortization_years = amortization_years
        self.autoscaler = autoscaler
        self.nodes: List[SimNode] = []
        self.retired: List[SimNode] = []
        self._node_seq = 0
        self._added_at: Dict[str, float] = {}
        self._retired_at: Dict[str, float] = {}
        for ns in specs:
            for _ in range(ns.count):
                self.add_node(ns, now=0.0)
        self.records = [RequestRecord(req=r) for r in trace]
        self._slot_rec: Dict[Tuple[str, int], RequestRecord] = {}
        self.scale_events: List[str] = []
        self.swap_events: List[str] = []
        self.preemption = preemption
        self.preempt_events: List[str] = []
        self._migrations: Dict[int, int] = {}   # uid -> moves so far
        self._heap: List[tuple] = []
        self._seq = 0
        # -- fault tolerance (repro.fleet.faults) ----------------------
        self.faults = faults
        self.recovery = recovery
        self.injector = (FaultInjector(faults, self.registry)
                         if faults is not None else None)
        self.fault_events: List[str] = []
        self.crashes = 0
        self.derates = 0
        self.link_faults = 0
        self.transients = 0
        self.retries = 0
        self.hedges = 0
        self.requests_lost = 0
        self.recovered_lanes = 0
        self.replayed_from_prompt = 0
        self.checkpoints = 0
        self._attempts: Dict[int, int] = {}      # uid -> retries so far
        self._lost_uids: set = set()
        self._hedged: set = set()                # uids hedged once
        self._hedge_nodes: Dict[int, str] = {}   # uid -> hedge node_id
        # derate detection: the training-loop straggler monitor reused
        # on the SIM clock (injectable, so detection is deterministic)
        self._now = 0.0
        self.straggler_monitor: Optional[StragglerMonitor] = None
        if faults is not None or detect_stragglers:
            self.straggler_monitor = StragglerMonitor(
                n_hosts=0, warmup=4, clock=lambda: self._now)
        self._host_idx: Dict[str, int] = {}      # node_id -> monitor host
        self._host_ids: List[str] = []           # monitor host -> node_id
        self._obs_last: Dict[str, Tuple[float, float]] = {}
        self._flagged: set = set()
        self.derate_detected: List[str] = []

    # -- fleet mutation (autoscaler hooks) -----------------------------
    def add_node(self, ns: NodeSpec, now: float) -> SimNode:
        models = None
        if ns.model_ids is not None:
            assert self.model_specs is not None, (
                "NodeSpec names model_ids but FleetSim has no model_specs")
            models = {m: self.model_specs[m] for m in ns.model_ids}
        node = SimNode(node_id=f"{ns.profile}/{ns.role}#{self._node_seq}",
                       profile=get_profile(ns.profile), role=ns.role,
                       fmt=self.fmt, spec=self.spec,
                       decode_lanes=ns.decode_lanes,
                       page_size=ns.page_size,
                       kv_pool_pages=ns.kv_pool_pages,
                       models=models, resident_models=ns.resident,
                       hbm_gb=ns.hbm_gb,
                       prefix_sharing=ns.prefix_sharing)
        self._node_seq += 1
        node.available_at = now
        self.nodes.append(node)
        self._added_at[node.node_id] = now
        node.bind_registry(self.registry)
        return node

    def retire_node(self, node: SimNode, now: float) -> None:
        """Stop routing to ``node``; it leaves once its work drains."""
        node.draining = True
        self._maybe_reap(node, now)

    def _maybe_reap(self, node: SimNode, now: float) -> None:
        busy = (node.prefill_busy or node.prefill_queue
                or node.decode_active or node.decode_queue
                or node.inbound_inflight)
        if node.draining and not busy and node in self.nodes:
            self.nodes.remove(node)
            self.retired.append(node)
            self._retired_at[node.node_id] = now

    def _routable(self, now: float) -> List[SimNode]:
        return [n for n in self.nodes
                if not n.draining and not n.failed
                and n.available_at <= now]

    @property
    def _retry_policy(self):
        return self.recovery.retry if self.recovery is not None else None

    def _work_remains(self) -> bool:
        """Undone requests that are still recoverable (lost requests
        never finish -- they must not keep periodic ticks alive)."""
        return any(not rec.done and rec.req.uid not in self._lost_uids
                   for rec in self.records)

    # -- event plumbing -------------------------------------------------
    def _push(self, t: float, kind: str, payload) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, payload))

    def _schedule_decode(self, node: SimNode, now: float) -> None:
        t = node.decode_next_event_s(now)
        if t is not None:
            self._push(t, "decode", (node, node.decode_version))

    # -- event handlers -------------------------------------------------
    def _on_arrive(self, rec: RequestRecord, now: float) -> None:
        if rec.t_prefill_start is not None or rec.done:
            return    # a hedge copy (or earlier attempt) already took it
        try:
            node = self.router.route_prefill(rec, self._routable(now), now)
        except AssertionError:
            # no prefill-capable node survives right now (crashes):
            # back off and retry instead of dying
            self._retry(rec, now, "no_prefill_node")
            return
        rec.prefill_node = node.node_id
        pol = self._retry_policy
        if (pol is not None and pol.hedge_after_s is not None
                and rec.req.uid not in self._hedged):
            self._hedged.add(rec.req.uid)
            self._push(now + pol.hedge_after_s, "hedge", rec)
        if not node.prefill_busy and not node.prefill_queue:
            self._start_prefill(node, rec, now)
        else:
            node.prefill_queue.append(rec)

    def _start_prefill(self, node: SimNode, rec: RequestRecord,
                       now: float) -> None:
        rec.prefill_node = node.node_id   # a hedge may win on a peer
        rec.t_prefill_start = now
        done_t = node.start_prefill(rec, now)
        self.tracer.add_span("sim.prefill", now, done_t,
                             track=node.node_id, uid=rec.req.uid,
                             prompt_len=rec.req.prompt_len)
        self._push(done_t, "prefill_done", (node, rec))

    def _prefill_claimable(self, node: SimNode, rec: RequestRecord) -> bool:
        """May ``node`` start this queued record?  Stale entries -- an
        attempt that was retried elsewhere, a hedge whose twin already
        started, a finished request -- are skipped at pop time."""
        if rec.t_prefill_start is not None or rec.done:
            return False
        return (rec.prefill_node == node.node_id
                or self._hedge_nodes.get(rec.req.uid) == node.node_id)

    def _on_hedge(self, rec: RequestRecord, now: float) -> None:
        """Tail-latency hedge: the request is still QUEUED after
        ``hedge_after_s`` -- launch a duplicate on another board.  First
        to start prefill wins; the loser is skipped at pop time."""
        if rec.t_prefill_start is not None or rec.done \
                or rec.req.uid in self._lost_uids:
            return
        cands = [n for n in self._routable(now)
                 if n.node_id != rec.prefill_node]
        try:
            node = self.router.route_prefill(rec, cands, now)
        except AssertionError:
            return            # no second prefill-capable board exists
        self._hedge_nodes[rec.req.uid] = node.node_id
        self.hedges += 1
        if self.injector is not None:
            self.injector.count_hedge()
        self.fault_events.append(
            f"t={now:.2f}s uid={rec.req.uid} HEDGE -> {node.node_id}")
        if not node.prefill_busy and not node.prefill_queue:
            self._start_prefill(node, rec, now)
        else:
            node.prefill_queue.append(rec)

    def _retry(self, rec: RequestRecord, now: float, reason: str) -> None:
        """Request-layer retry: wipe the attempt's timeline and re-enter
        through arrival after a capped exponential backoff.  Arrival
        time is NOT reset, so TTFT/deadline pay for the fault.  With no
        RecoveryPolicy (or an exhausted one) the request is LOST."""
        uid = rec.req.uid
        if uid in self._lost_uids or rec.done:
            return
        pol = self._retry_policy
        attempt = self._attempts.get(uid, 0) + 1
        if pol is None or not pol.allows(attempt,
                                         now - rec.req.arrival_s):
            self._lost_uids.add(uid)
            self.requests_lost += 1
            if self.injector is not None:
                self.injector.count_lost()
            self.fault_events.append(
                f"t={now:.2f}s uid={uid} LOST ({reason}, "
                f"attempt={attempt})")
            self.tracer.add_instant("sim.request_lost", now,
                                    track="fleet", uid=uid, reason=reason)
            return
        self._attempts[uid] = attempt
        self.retries += 1
        if self.injector is not None:
            self.injector.count_retry()
        rec.prefill_node = None
        rec.decode_node = None
        rec.t_prefill_start = None
        rec.t_prefill_done = None
        rec.t_decode_enter = None
        rec.t_first_token = None
        self._hedge_nodes.pop(uid, None)
        delay = pol.backoff_s(attempt)
        self.fault_events.append(
            f"t={now:.2f}s uid={uid} RETRY#{attempt} ({reason}) "
            f"backoff={delay * 1e3:.0f}ms")
        self._push(now + delay, "arrive", rec)

    def _on_prefill_done(self, node: SimNode, rec: RequestRecord,
                         now: float) -> None:
        if node.failed:
            return   # board died mid-prefill; the crash already retried
        rec.t_prefill_done = now
        node.prefill_active = None
        mid = getattr(rec.req, "model_id", None)
        try:
            dst = self.router.route_decode(rec, node, self._routable(now),
                                           now)
        except AssertionError:
            # every decode-capable board is dead: the prefill output has
            # nowhere to go -- back off and retry (or report LOST)
            self._retry(rec, now, "no_decode_node")
            self._on_prefill_free(node, now)
            return
        rec.decode_node = dst.node_id
        plen = rec.req.prompt_len
        if dst is node:
            occupancy_s = transfer_s = 0.0    # KV stays in HBM
        else:
            occupancy_s = node.prefill_handoff_s(plen, mid=mid)
            transfer_s = node.prefill_handoff_s(plen, peer=dst.profile,
                                                mid=mid)
        rec.energy_j += node.request_energy_j(plen, rec.req.gen_len,
                                              phase="prefill", mid=mid)
        dst.inbound_inflight += 1      # blocks reaping until KV lands
        self._push(now + transfer_s, "decode_enter", (dst, rec))
        if occupancy_s > 0:
            self._push(now + occupancy_s, "prefill_free", node)
        else:
            self._on_prefill_free(node, now)

    def _on_prefill_free(self, node: SimNode, now: float) -> None:
        if node.failed:
            return
        node.prefill_busy = False
        while node.prefill_queue:
            rec = node.prefill_queue.popleft()
            if not self._prefill_claimable(node, rec):
                continue          # stale retry copy / lost hedge twin
            self._start_prefill(node, rec, now)
            break
        self._maybe_reap(node, now)

    def _on_decode_enter(self, node: SimNode, rec: RequestRecord,
                         now: float, pinned: bool = False) -> None:
        node.inbound_inflight -= 1
        mid = getattr(rec.req, "model_id", None)
        if node.failed:
            # the KV (or the swap) was in flight TO a board that died:
            # the prefill output is gone, recompute from the prompt
            self._retry(rec, now, "crash_inflight")
            return
        if pinned:
            node.unpin_model(mid)
        if node.models is not None and mid is not None:
            # weights must be resident before the first decode step: a
            # cold model swaps in over the host link, and the request
            # re-enters once the shards land.  The pin keeps the model
            # from being LRU-evicted while its weights are in flight
            # (a second request for the same model piggybacks on the
            # swap already underway: swap_in sees it resident).
            swap_s = node.swap_in(mid, now)
            if swap_s > 0:
                node.pin_model(mid)
                node.inbound_inflight += 1   # still en route: no reaping
                self.swap_events.append(
                    f"t={now:.2f}s {node.node_id} <- weights[{mid}] "
                    f"({swap_s * 1e3:.0f}ms)")
                self.tracer.add_span("sim.swap", now, now + swap_s,
                                     track=f"{node.node_id}/link",
                                     model_id=mid, uid=rec.req.uid)
                self._push(now + swap_s, "decode_enter", (node, rec, True))
                return
        rec.t_decode_enter = now
        if rec.req.gen_len <= 0:      # nothing to decode: done on arrival
            rec.t_first_token = now
            rec.t_done = now
            self._maybe_reap(node, now)
            return
        rec.energy_j += node.request_energy_j(rec.req.prompt_len,
                                              rec.req.gen_len,
                                              phase="decode", mid=mid)
        self._finish(node, node.decode_advance(now), now)
        slot = node.make_slot(rec.req.uid, rec.req.prompt_len,
                              rec.req.gen_len, model_id=mid,
                              prefix_id=getattr(rec.req, "prefix_id",
                                                None),
                              prefix_len=getattr(rec.req, "prefix_len",
                                                 0))
        self._slot_rec[(node.node_id, rec.req.uid)] = rec
        node.decode_admit(slot, now)
        self._maybe_preempt(node, now)
        self._schedule_decode(node, now)

    def _on_decode(self, node: SimNode, version: int, now: float) -> None:
        if version != node.decode_version or node not in self.nodes:
            return                          # stale membership snapshot
        self._finish(node, node.decode_advance(now), now)
        if self.straggler_monitor is not None:
            self._observe_decode(node)
        self._maybe_preempt(node, now)
        self._schedule_decode(node, now)
        self._maybe_reap(node, now)

    def _observe_decode(self, node: SimNode) -> None:
        """Feed the straggler monitor one per-token decode-time sample
        for ``node``, on the SIM clock (the monitor's injected clock
        reads ``self._now``) -- a derated board's seconds-per-token EWMA
        drifts above the fleet median and gets flagged, deterministically."""
        mon = self.straggler_monitor
        t = mon.clock()
        host = self._host_idx.get(node.node_id)
        if host is None:
            host = mon.add_host()
            self._host_idx[node.node_id] = host
            self._host_ids.append(node.node_id)
        if not node.decode_active:
            # going idle: drop the baseline, or the next busy window's
            # sample would charge the idle gap as decode time
            self._obs_last.pop(node.node_id, None)
            return
        last = self._obs_last.get(node.node_id)
        self._obs_last[node.node_id] = (t, node.tokens_decoded)
        if last is None:
            return
        t0, tok0 = last
        dtok = node.tokens_decoded - tok0
        if t <= t0 or dtok <= 0:
            return
        mon.record(host, (t - t0) / dtok)
        for idx in mon.stragglers():
            nid = self._host_ids[idx]
            if nid not in self._flagged:
                self._flagged.add(nid)
                self.derate_detected.append(
                    f"t={t:.2f}s STRAGGLER {nid} "
                    f"ewma={mon.ewma[idx]:.4g}s/tok")
                self.tracer.add_instant("sim.straggler_detected", t,
                                        track=nid)

    # -- preemption & KV-page migration --------------------------------
    def _movable(self, node: SimNode) -> List:
        """Resident slots eligible for eviction, most remaining work
        first (deterministic: ties break on uid)."""
        cap = (self.preemption.max_migrations_per_request
               if self.preemption else 0)
        eligible = sorted(node.decode_active.values(),
                          key=lambda s: (-(s.gen_len - s.tokens_done),
                                         s.uid))
        return [s for s in eligible
                if self._migrations.get(s.uid, 0) < cap]

    def _maybe_preempt(self, node: SimNode, now: float) -> None:
        """Apply the preemption policy to ``node`` after its decode
        state changed: shed slots while the page pool is over-committed,
        and (optionally) rescue stragglers a peer would finish sooner
        despite paying the page transfer."""
        pol = self.preemption
        if pol is None or node not in self.nodes:
            return
        if pol.on_page_exhaustion:
            while node.kv_pages_free() < 0:
                moved = False
                for slot in self._movable(node):
                    dst = self.router.route_migration(
                        slot, node, self._routable(now), now)
                    if dst is not None:
                        self._migrate(node, slot, dst, now)
                        moved = True
                        break
                if not moved:       # nowhere to shed to: spill and bear it
                    break
        if pol.straggler_factor is not None:
            for slot in self._movable(node):
                remaining = slot.gen_len - slot.tokens_done
                if remaining <= 0:
                    continue
                t_here = remaining * node.est_decode_step_s(
                    slot.prompt_len + int(slot.tokens_done), extra=0,
                    mid=getattr(slot, "model_id", None))
                dst = self.router.route_migration(
                    slot, node, self._routable(now), now)
                if dst is None:
                    continue
                ctx = slot.prompt_len + int(slot.tokens_done)
                t_there = (node.kv_page_transfer_s(
                    node.migration_pages(ctx), peer=dst.profile)
                    + remaining * dst.est_decode_step_s(
                        ctx, extra=1, mid=getattr(slot, "model_id", None)))
                if t_here > pol.straggler_factor * t_there:
                    self._migrate(node, slot, dst, now)

    def _migrate(self, src: SimNode, slot, dst: SimNode,
                 now: float) -> None:
        """Evict ``slot`` from ``src`` and replay it on ``dst`` after
        its KV pages cross the host link (the request is in flight --
        nobody decodes it -- for the whole transfer)."""
        src.preempt_slot(slot.uid, now)
        ctx = slot.prompt_len + int(slot.tokens_done)
        n_pg = src.migration_pages(ctx)
        transfer_s = src.kv_page_transfer_s(n_pg, peer=dst.profile)
        mid = getattr(slot, "model_id", None)
        if dst.models is not None and mid is not None:
            # a destination without the slot's model hot swaps its
            # weights in alongside the KV pages (same host link); the
            # pin keeps them from being evicted before the slot lands
            transfer_s += dst.swap_in(mid, now)
            dst.pin_model(mid)
        src.pages_migrated_out += n_pg
        rec = self._slot_rec.pop((src.node_id, slot.uid))
        rec.preemptions += 1
        self._migrations[slot.uid] = self._migrations.get(slot.uid, 0) + 1
        dst.inbound_inflight += 1      # blocks reaping until KV lands
        dst.inbound_pages += n_pg      # reserves capacity while in flight
        self.tracer.add_span("sim.migrate", now, now + transfer_s,
                             track=f"{src.node_id}/link", uid=slot.uid,
                             pages=n_pg, dst=dst.node_id)
        self._push(now + transfer_s, "migrate_enter",
                   (dst, slot, rec, n_pg))
        self.preempt_events.append(
            f"t={now:.2f}s uid={slot.uid} {src.node_id} -> {dst.node_id} "
            f"pages={n_pg} transfer={transfer_s * 1e3:.1f}ms")
        self._schedule_decode(src, now)
        self._maybe_reap(src, now)

    def _on_migrate_enter(self, dst: SimNode, slot, rec: RequestRecord,
                          n_pg: int, now: float) -> None:
        dst.inbound_inflight -= 1
        dst.inbound_pages -= n_pg      # reservation becomes occupancy
        if dst.failed:
            # pages were in flight TO a board that died: the KV is gone,
            # recompute from the prompt on whatever survives
            self._retry(rec, now, "crash_inflight")
            return
        dst.pages_migrated_in += n_pg
        mid = getattr(slot, "model_id", None)
        if dst.models is not None and mid is not None:
            dst.unpin_model(mid)
        rec.decode_node = dst.node_id
        self._finish(dst, dst.decode_advance(now), now)
        resumed = dst.resume_slot(slot)
        self._slot_rec[(dst.node_id, resumed.uid)] = rec
        dst.decode_admit(resumed, now)
        self._maybe_preempt(dst, now)
        self._schedule_decode(dst, now)

    def _finish(self, node: SimNode, slots, now: float) -> None:
        for slot in slots:
            rec = self._slot_rec.pop((node.node_id, slot.uid))
            rec.t_first_token = slot.t_first_token
            rec.t_done = now
            if rec.t_decode_enter is not None:
                # per-request track: concurrent slots on one board
                # would partially overlap on a shared track
                self.tracer.add_span("sim.decode", rec.t_decode_enter,
                                     now,
                                     track=f"{node.node_id}/u{slot.uid}",
                                     uid=slot.uid,
                                     gen_len=rec.req.gen_len)
            if slot.t_first_token is not None:
                self.tracer.add_instant(
                    "sim.first_token", slot.t_first_token,
                    track=f"{node.node_id}/u{slot.uid}", uid=slot.uid)
            if self.slo is not None:
                mon = self.slo.monitor
                if rec.ttft_s is not None:
                    mon.observe_ttft(rec.ttft_s, t=now)
                if rec.tpot_s is not None:
                    mon.observe_tpot(rec.tpot_s, t=now)
                self.slo.step(now)

    # -- fault injection & recovery ------------------------------------
    def _on_fault(self, ev: FaultEvent, now: float) -> None:
        node = self.injector.resolve(ev, self.nodes)
        if node is None:
            return                     # everything already dead
        self.injector.count(ev.kind)
        if ev.kind == "crash":
            self._crash_node(node, now)
            return
        # derate / link / transient all mutate live node state: settle
        # the decode integral first so past progress is priced at the
        # old rate, then bump the version so stale events are dropped
        self._finish(node, node.decode_advance(now), now)
        node.decode_version += 1
        if ev.kind == "derate":
            node.derate = ev.factor
            self.derates += 1
            self.fault_events.append(
                f"t={now:.2f}s {node.node_id} DERATE x{ev.factor:g}"
                + (f" for {ev.duration_s:.2f}s" if ev.duration_s else ""))
            self.tracer.add_span("sim.fault.derate", now,
                                 now + (ev.duration_s or 0.0),
                                 track=node.node_id, factor=ev.factor)
        elif ev.kind == "link":
            node.link_derate = ev.factor
            self.link_faults += 1
            self.fault_events.append(
                f"t={now:.2f}s {node.node_id} LINK x{ev.factor:g}"
                + (f" for {ev.duration_s:.2f}s" if ev.duration_s else ""))
            self.tracer.add_span("sim.fault.link", now,
                                 now + (ev.duration_s or 0.0),
                                 track=f"{node.node_id}/link",
                                 factor=ev.factor)
        elif ev.kind == "transient":
            # a dispatch hiccup: the board produces nothing for the
            # stall window, then resumes exactly where it was
            node.stall_until = max(node.stall_until,
                                   now + (ev.duration_s or 0.0))
            self.transients += 1
            self.fault_events.append(
                f"t={now:.2f}s {node.node_id} STALL "
                f"{(ev.duration_s or 0.0) * 1e3:.0f}ms")
            self.tracer.add_span("sim.fault.transient", now,
                                 now + (ev.duration_s or 0.0),
                                 track=node.node_id)
        if ev.kind in ("derate", "link") and ev.duration_s is not None:
            self._push(now + ev.duration_s, "fault_clear",
                       (ev.kind, node))
        self._schedule_decode(node, now)

    def _on_fault_clear(self, kind: str, node: SimNode,
                        now: float) -> None:
        if node.failed or node not in self.nodes:
            return
        self._finish(node, node.decode_advance(now), now)
        node.decode_version += 1
        if kind == "derate":
            node.derate = 1.0
        elif kind == "link":
            node.link_derate = 1.0
        self.fault_events.append(
            f"t={now:.2f}s {node.node_id} CLEAR {kind}")
        self._schedule_decode(node, now)
        self._maybe_reap(node, now)

    def _crash_node(self, node: SimNode, now: float) -> None:
        """Fail-stop: settle decode progress, mark the board dead, and
        recover its live work -- checkpointed lanes migrate their pages
        (replaying only tokens since the last checkpoint tick), the rest
        retry from the prompt.  Uptime/energy accounting stops here."""
        self._finish(node, node.decode_advance(now), now)
        node.failed = True
        node.draining = True
        node.decode_version += 1
        self.crashes += 1
        self.fault_events.append(f"t={now:.2f}s {node.node_id} CRASH")
        self.tracer.add_instant("sim.fault.crash", now,
                                track=node.node_id)
        if self.flight is not None:
            # black box: the ring holds the telemetry leading up to the
            # crash; dump it named for the dying board
            self.flight.dump(
                f"flight_{node.node_id.replace('/', '_')}.jsonl",
                reason=f"sim crash of {node.node_id} at t={now:.3f}s",
                registry=self.registry, t=now)
        if self.straggler_monitor is not None:
            host = self._host_idx.get(node.node_id)
            if host is not None:        # dead host must not skew the median
                self.straggler_monitor.reset(host)
                self._obs_last.pop(node.node_id, None)
        if node in self.nodes:          # stop routing + billing now
            self.nodes.remove(node)
            self.retired.append(node)
            self._retired_at[node.node_id] = now
        for slot in sorted(node.decode_active.values(),
                           key=lambda s: s.uid):
            rec = self._slot_rec.pop((node.node_id, slot.uid))
            self._recover_slot(node, slot, rec, now)
        node.decode_active.clear()
        for slot in list(node.decode_queue):
            rec = self._slot_rec.pop((node.node_id, slot.uid))
            self._retry(rec, now, "crash")
        node.decode_queue.clear()
        if node.prefill_active is not None:
            self._retry(node.prefill_active, now, "crash")
            node.prefill_active = None
        node.prefill_busy = False
        for rec in list(node.prefill_queue):
            if self._prefill_claimable(node, rec):
                self._retry(rec, now, "crash")
        node.prefill_queue.clear()

    def _recover_slot(self, node: SimNode, slot, rec: RequestRecord,
                      now: float) -> None:
        """One live lane of a crashed board: roll back to the last
        checkpoint tick and re-place it like a migration (the checkpoint
        lives host-side, so only the DESTINATION link is paid)."""
        ckpt = slot.ckpt_tokens if self.recovery is not None else None
        if ckpt is None:
            self.replayed_from_prompt += 1
            self._retry(rec, now, "crash_no_checkpoint")
            return
        slot.tokens_done = float(min(ckpt, slot.gen_len))
        if slot.tokens_done < 1.0:
            slot.t_first_token = None
        dst = self.router.route_migration(slot, node,
                                          self._routable(now), now)
        if dst is None:
            self.replayed_from_prompt += 1
            self._retry(rec, now, "crash_no_destination")
            return
        ctx = slot.prompt_len + int(slot.tokens_done)
        n_pg = node.migration_pages(ctx)
        transfer_s = dst.kv_page_transfer_s(n_pg)
        mid = getattr(slot, "model_id", None)
        if dst.models is not None and mid is not None:
            transfer_s += dst.swap_in(mid, now)
            dst.pin_model(mid)
        rec.preemptions += 1
        self._migrations[slot.uid] = self._migrations.get(slot.uid, 0) + 1
        self.recovered_lanes += 1
        dst.inbound_inflight += 1
        dst.inbound_pages += n_pg
        self.tracer.add_span("sim.recover", now, now + transfer_s,
                             track=f"{dst.node_id}/link", uid=slot.uid,
                             pages=n_pg, src=node.node_id)
        self._push(now + transfer_s, "migrate_enter",
                   (dst, slot, rec, n_pg))
        self.fault_events.append(
            f"t={now:.2f}s uid={slot.uid} RECOVER {node.node_id} -> "
            f"{dst.node_id} ckpt_tokens={int(slot.tokens_done)} "
            f"pages={n_pg}")

    def _on_checkpoint(self, now: float) -> None:
        """Periodic fleet-wide checkpoint tick: every live decode slot
        snapshots its progress (``ckpt_tokens``); a later crash rolls
        the slot back here instead of to the prompt."""
        for node in list(self.nodes):
            if node.failed:
                continue
            finished = node.decode_advance(now)
            if finished:
                self._finish(node, finished, now)
                self._schedule_decode(node, now)
                self._maybe_reap(node, now)
            # lint: ok R005 per-slot snapshot write, order-free
            for slot in node.decode_active.values():
                slot.ckpt_tokens = int(slot.tokens_done)
        self.checkpoints += 1
        if self._work_remains():
            self._push(now + self.recovery.checkpoint_interval_s,
                       "checkpoint", None)

    def _on_autoscale(self, now: float) -> None:
        if self.autoscaler is None:
            return
        self.scale_events.extend(self.autoscaler.tick(self, now))
        if self._work_remains():
            self._push(now + self.autoscaler.interval_s, "autoscale", None)

    # -- main loop ------------------------------------------------------
    def run(self) -> FleetReport:
        for rec in self.records:
            self._push(rec.req.arrival_s, "arrive", rec)
        if self.autoscaler is not None:
            self._push(self.autoscaler.interval_s, "autoscale", None)
        if self.injector is not None:
            for ev in self.injector.plan.sim_events():
                self._push(ev.at_s, "fault", ev)
        if self.recovery is not None:
            self._push(self.recovery.checkpoint_interval_s,
                       "checkpoint", None)
        now = 0.0
        while self._heap:
            now, _, kind, payload = heapq.heappop(self._heap)
            self._now = now            # the straggler monitor's clock
            if kind == "arrive":
                self._on_arrive(payload, now)
            elif kind == "prefill_done":
                self._on_prefill_done(payload[0], payload[1], now)
            elif kind == "prefill_free":
                self._on_prefill_free(payload, now)
            elif kind == "decode_enter":
                self._on_decode_enter(payload[0], payload[1], now,
                                      *payload[2:])
            elif kind == "decode":
                self._on_decode(payload[0], payload[1], now)
            elif kind == "migrate_enter":
                self._on_migrate_enter(payload[0], payload[1], payload[2],
                                       payload[3], now)
            elif kind == "autoscale":
                self._on_autoscale(now)
            elif kind == "fault":
                self._on_fault(payload, now)
            elif kind == "fault_clear":
                self._on_fault_clear(payload[0], payload[1], now)
            elif kind == "checkpoint":
                self._on_checkpoint(now)
            elif kind == "hedge":
                self._on_hedge(payload, now)
        return self._report(makespan=now)

    # -- metrics --------------------------------------------------------
    def _node_uptime_s(self, node: SimNode, makespan: float) -> float:
        t0 = self._added_at.get(node.node_id, 0.0)
        t1 = self._retired_at.get(node.node_id, makespan)
        return max(t1 - t0, 0.0)

    def _report(self, makespan: float) -> FleetReport:
        done = [r for r in self.records if r.done]
        makespan = max(makespan, 1e-9)
        ttft = np.array(sorted(r.ttft_s for r in done), np.float64)
        tpot = np.array(sorted(r.tpot_s for r in done), np.float64)

        def pct(arr, q):
            return float(np.percentile(arr, q)) if arr.size else float("nan")

        def meets_slo(r: RequestRecord) -> bool:
            if self.ttft_slo_s is not None and r.ttft_s > self.ttft_slo_s:
                return False
            if self.tpot_slo_s is not None and r.tpot_s > self.tpot_slo_s:
                return False
            return True

        energy = 0.0
        usd_hour = 0.0
        for node in self.nodes + self.retired:
            up = self._node_uptime_s(node, makespan)
            energy += node.energy_active_j + node.idle_energy_j(up)
            usd_hour += (capex_usd_per_hour(node.profile,
                                            self.amortization_years)
                         * up / makespan)
        avg_watts = energy / makespan
        usd_hour += energy_usd_per_hour(avg_watts, self.power_usd_per_kwh)
        gen_tok = sum(r.req.gen_len for r in done)
        gen_tok_s = gen_tok / makespan
        usd_per_mtok = usd_hour / max(gen_tok_s * 3600.0 / 1e6, 1e-9)
        good = sum(1 for r in done if meets_slo(r))
        # per-model decode accounting (tpot + tokens/joule), multi-model
        # traces only -- the nodes integrate per-model dynamic energy
        by_model: Dict[str, List[float]] = {}
        for r in done:
            mid = getattr(r.req, "model_id", None)
            if mid is not None:
                by_model.setdefault(mid, []).append(r.tpot_s)
        per_model = []
        for mid in sorted(by_model):
            toks = sum(n.model_tokens.get(mid, 0.0)
                       for n in self.nodes + self.retired)
            joules = sum(n.model_energy_j.get(mid, 0.0)
                         for n in self.nodes + self.retired)
            per_model.append((mid, pct(np.asarray(sorted(by_model[mid])), 50),
                              int(round(toks)),
                              toks / joules if joules > 0 else float("nan")))
        report = FleetReport(
            offered=len(self.records), completed=len(done),
            makespan_s=makespan,
            ttft_p50_s=pct(ttft, 50), ttft_p99_s=pct(ttft, 99),
            tpot_p50_s=pct(tpot, 50), tpot_p99_s=pct(tpot, 99),
            requests_per_s=len(done) / makespan,
            goodput_rps=good / makespan,
            gen_tokens_per_s=gen_tok_s,
            avg_watts=avg_watts, energy_j=energy,
            joules_per_request=(sum(r.energy_j for r in done) / len(done)
                                if done else float("nan")),
            usd_per_hour=usd_hour, usd_per_mtok=usd_per_mtok,
            preemptions=sum(n.preemptions
                            for n in self.nodes + self.retired),
            pages_migrated=sum(n.pages_migrated_out
                               for n in self.nodes + self.retired),
            model_swaps=sum(n.model_swaps
                            for n in self.nodes + self.retired),
            swap_bytes=sum(n.swap_bytes
                           for n in self.nodes + self.retired),
            per_model=tuple(per_model),
            scale_events=tuple(self.scale_events),
            preempt_events=tuple(self.preempt_events),
            swap_events=tuple(self.swap_events),
            crashes=self.crashes, derates=self.derates,
            link_faults=self.link_faults, transients=self.transients,
            retries=self.retries, hedges=self.hedges,
            requests_lost=self.requests_lost,
            recovered_lanes=self.recovered_lanes,
            replayed_from_prompt=self.replayed_from_prompt,
            checkpoints=self.checkpoints,
            fault_events=tuple(self.fault_events),
            derate_detected=tuple(self.derate_detected))
        # publish the aggregate report under the fleet.* namespace so
        # the sim's numbers sit next to the engines' in one exposition
        # lint: ok R005 dataclass field order, deterministic by construction
        for key, val in report.metrics().items():
            self.registry.gauge(f"fleet.{key}").set(float(val))
        return report
