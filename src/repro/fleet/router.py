"""Request routing policies over a heterogeneous node set.

The router makes two decisions per request: which node prefills it
(chosen at arrival) and which node decodes it (chosen when the KV is
ready, so the decision sees current decode load).  The prefill->decode
KV handoff cost -- the CMP 170HX's defining constraint, a PCIe 1.1 x4
link (~1 GB/s) -- is computed from the *bottleneck* endpoint via
``phase_model.kv_handoff_seconds`` and charged both to the prefill
board's occupancy and to the request's time-to-first-token.

Policies:

* :class:`LeastLoadedRouter` -- shortest backlog / fewest resident
  requests.  The throughput-oriented default.
* :class:`CostAwareRouter`   -- least incremental $ per useful token:
  prefers cheap reclaimed boards until their queues erase the price
  advantage.
* :class:`SLOAwareRouter`    -- minimizes predicted TTFT (prefill) and
  avoids nodes whose post-admission step time would breach the TPOT
  SLO (decode); falls back to least-loaded among violators.
"""

from __future__ import annotations

from typing import List, Sequence

from repro.fleet.node import SimNode
from repro.serving.phase_model import capex_usd_per_hour, energy_usd_per_hour


def prefill_candidates(nodes: Sequence[SimNode]) -> List[SimNode]:
    return [n for n in nodes if n.role in ("prefill", "both")]


def decode_candidates(nodes: Sequence[SimNode]) -> List[SimNode]:
    return [n for n in nodes if n.role in ("decode", "both")]


def kv_capacity_penalty(record, node: SimNode) -> float:
    """Additive decode-score penalty for a node whose page pool cannot
    hold the request: capacity is BYTES, not lanes, so a board with a
    free lane but a full pool must lose to one with pages to spare
    (spilling over the PCIe 1.1 x4 host link is ~1000x slower than HBM).
    Zero for nodes without a configured pool -- legacy scores unchanged.
    """
    over = node.kv_overcommit(record.req.prompt_len, record.req.gen_len)
    return 1e9 * over if over else 0.0


def kv_migration_penalty(ctx: int, remaining: float,
                         node: SimNode) -> float:
    """Same page-capacity penalty, expressed for a mid-stream slot
    (live context + remaining budget) instead of a fresh request."""
    over = node.kv_overcommit(ctx, int(remaining))
    return 1e9 * over if over else 0.0


class Router:
    """Base policy; subclasses override the two scoring hooks."""

    name = "base"

    def route_prefill(self, record, nodes: Sequence[SimNode],
                      now: float) -> SimNode:
        cands = prefill_candidates(nodes)
        assert cands, "no prefill-capable node in the fleet"
        chosen = min(cands, key=lambda n: (self._prefill_score(record, n, now),
                                           n.node_id))
        chosen.note_prefill_routed(record, now)
        return chosen

    def route_decode(self, record, src: SimNode, nodes: Sequence[SimNode],
                     now: float) -> SimNode:
        cands = decode_candidates(nodes)
        assert cands, "no decode-capable node in the fleet"
        # score ties break toward the prefill board itself: local decode
        # keeps the KV in HBM and pays no handoff (the planner's
        # colocated model assumes exactly this)
        return min(cands, key=lambda n: (self._decode_score(record, src, n,
                                                            now),
                                         n is not src, n.node_id))

    def route_migration(self, slot, src: SimNode,
                        nodes: Sequence[SimNode], now: float):
        """Pick the board a preempted slot resumes on, or ``None``.

        Migration is only worth its page traffic when the destination
        actually has capacity: the score is the page-granular transfer
        time over the bottleneck host link (``ceil(ctx/page_size)``
        pages, the same units the engine checkpoint ships) plus the
        remaining decode time at the destination's current sharing
        level, with the page-capacity penalty on top.  A destination
        whose own pool cannot hold the context is refused outright --
        shipping KV into another over-committed board trades one spill
        for two plus a transfer.
        """
        cands = [n for n in decode_candidates(nodes) if n is not src]
        if not cands:
            return None
        ctx = slot.prompt_len + int(slot.tokens_done)
        remaining = max(slot.gen_len - slot.tokens_done, 0.0)
        n_pg = src.migration_pages(ctx)

        def score(n: SimNode) -> float:
            return (src.kv_page_transfer_s(n_pg, peer=n.profile)
                    + remaining * n.est_decode_step_s(ctx, extra=1)
                    + kv_migration_penalty(ctx, remaining, n))

        best = min(cands, key=lambda n: (score(n), n.node_id))
        if best.kv_overcommit(ctx, int(remaining)) > 0:
            return None
        return best

    # -- scoring hooks (lower wins) ------------------------------------
    def _prefill_score(self, record, node: SimNode, now: float) -> float:
        raise NotImplementedError

    def _decode_score(self, record, src: SimNode, node: SimNode,
                      now: float) -> float:
        raise NotImplementedError


class LeastLoadedRouter(Router):
    name = "least-loaded"

    def _prefill_score(self, record, node: SimNode, now: float) -> float:
        return node.est_prefill_wait_s(now)

    def _decode_score(self, record, src: SimNode, node: SimNode,
                      now: float) -> float:
        return float(node.decode_load()) + kv_capacity_penalty(record, node)


class CostAwareRouter(Router):
    """Minimize incremental $/token: (wait + service) x board $/s."""

    name = "cost-aware"

    def __init__(self, amortization_years: float = 3.0,
                 power_usd_per_kwh: float = 0.10):
        self.amortization_years = amortization_years
        self.power_usd_per_kwh = power_usd_per_kwh

    def _usd_per_s(self, node: SimNode) -> float:
        capex = capex_usd_per_hour(node.profile, self.amortization_years)
        opex = energy_usd_per_hour(node.profile.tdp_watts,
                                   self.power_usd_per_kwh)
        return (capex + opex) / 3600.0

    def _prefill_score(self, record, node: SimNode, now: float) -> float:
        busy = (node.est_prefill_wait_s(now)
                + node.prefill_service_s(record.req.prompt_len))
        return busy * self._usd_per_s(node) / max(record.req.prompt_len, 1)

    def _decode_score(self, record, src: SimNode, node: SimNode,
                      now: float) -> float:
        ctx = record.req.prompt_len + record.req.gen_len // 2
        t_req = (record.req.gen_len
                 * node.est_decode_step_s(ctx, extra=1 + node.decode_load()
                                          - len(node.decode_active)))
        return (t_req * self._usd_per_s(node) / max(record.req.gen_len, 1)
                + kv_capacity_penalty(record, node))


class SLOAwareRouter(Router):
    """Route to minimize predicted TTFT / keep TPOT under the SLO."""

    name = "slo-aware"

    def __init__(self, ttft_slo_s: float = 2.0, tpot_slo_s: float = 0.2):
        self.ttft_slo_s = ttft_slo_s
        self.tpot_slo_s = tpot_slo_s

    def _prefill_score(self, record, node: SimNode, now: float) -> float:
        ttft = (node.est_prefill_wait_s(now)
                + node.prefill_service_s(record.req.prompt_len)
                + node.prefill_handoff_s(record.req.prompt_len))
        return ttft

    def _decode_score(self, record, src: SimNode, node: SimNode,
                      now: float) -> float:
        ctx = record.req.prompt_len + record.req.gen_len // 2
        active = len(node.decode_active)
        queued = node.decode_load() - active
        # steady-state batch is capped by the lane count: queued work
        # waits, it does not run concurrently
        b = min(node.decode_lanes, active + queued + 1)
        step = node.est_decode_step_s(ctx, extra=max(b - active, 0))
        # SLO violators sort after every compliant node; among
        # compliant nodes deeper backlogs (longer queue wait) lose
        penalty = 1e6 if step > self.tpot_slo_s else 0.0
        penalty += kv_capacity_penalty(record, node)
        return penalty + step * (1.0 + queued / max(node.decode_lanes, 1))
