"""Request routing policies over a heterogeneous node set.

The router makes two decisions per request: which node prefills it
(chosen at arrival) and which node decodes it (chosen when the KV is
ready, so the decision sees current decode load).  The prefill->decode
KV handoff cost -- the CMP 170HX's defining constraint, a PCIe 1.1 x4
link (~1 GB/s) -- is computed from the *bottleneck* endpoint via
``phase_model.kv_handoff_seconds`` and charged both to the prefill
board's occupancy and to the request's time-to-first-token.

Policies:

* :class:`LeastLoadedRouter` -- shortest backlog / fewest resident
  requests.  The throughput-oriented default.
* :class:`CostAwareRouter`   -- least incremental $ per useful token:
  prefers cheap reclaimed boards until their queues erase the price
  advantage.
* :class:`SLOAwareRouter`    -- minimizes predicted TTFT (prefill) and
  avoids nodes whose post-admission step time would breach the TPOT
  SLO (decode); falls back to least-loaded among violators.
* :class:`PreemptionAwareSLORouter` -- SLO routing plus an ANTICIPATED
  eviction-cost term: near-capacity nodes are charged the pages the
  fleet would later have to migrate, priced at the host-link transfer
  time, instead of reacting only after page exhaustion.

Multi-model fleets add an affinity dimension: every policy charges a
node that does not have the request's model resident the weight-swap
transfer time plus the page-pool shrinkage the swapped-in weights cause
(``model_affinity_penalty``) -- so a request routes to a node that
already has the model HOT whenever one exists with capacity, instead of
forcing a swap over the PCIe 1.1 x4 link.  Construct any router with
``model_aware=False`` to get the affinity-blind baseline.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from repro.fleet.node import SimNode
from repro.serving.phase_model import capex_usd_per_hour, energy_usd_per_hour


def _req_model(record) -> Optional[str]:
    return getattr(record.req, "model_id", None)


def prefill_candidates(nodes: Sequence[SimNode],
                       mid: Optional[str] = None) -> List[SimNode]:
    return [n for n in nodes if n.role in ("prefill", "both")
            and n.serves_model(mid) and not n.failed]


def decode_candidates(nodes: Sequence[SimNode],
                      mid: Optional[str] = None) -> List[SimNode]:
    return [n for n in nodes if n.role in ("decode", "both")
            and n.serves_model(mid) and not n.failed]


def kv_capacity_penalty(record, node: SimNode) -> float:
    """Additive decode-score penalty for a node whose page pool cannot
    hold the request: capacity is BYTES, not lanes, so a board with a
    free lane but a full pool must lose to one with pages to spare
    (spilling over the PCIe 1.1 x4 host link is ~1000x slower than HBM).
    Zero for nodes without a configured pool -- legacy scores unchanged.
    On a prefix-sharing board the over-commit probe discounts a request
    whose prefix family is already resident, so siblings gravitate to
    the board holding their template.
    """
    over = node.kv_overcommit(record.req.prompt_len, record.req.gen_len,
                              prefix_id=getattr(record.req, "prefix_id",
                                                None),
                              prefix_len=getattr(record.req, "prefix_len",
                                                 0))
    return 1e9 * over if over else 0.0


def kv_migration_penalty(ctx: int, remaining: float,
                         node: SimNode) -> float:
    """Same page-capacity penalty, expressed for a mid-stream slot
    (live context + remaining budget) instead of a fresh request."""
    over = node.kv_overcommit(ctx, int(remaining))
    return 1e9 * over if over else 0.0


def model_affinity_penalty(record, node: SimNode) -> float:
    """Additive score term for multi-model nodes: a node with the
    request's model HOT costs nothing; a cold node pays

    * the weight transfer over its host link (the swap itself), plus
    * the page-pool shrinkage those weights cause, priced as the
      host-link transfer time of the KV pages they displace beyond the
      node's spare headroom (the anticipated eviction cost of the
      decodes the shrink would push out),

    and is refused outright (1e9) when the displaced pages would leave
    the pool unable to hold the request itself.  Zero for model-blind
    nodes/requests -- legacy scores unchanged.
    """
    mid = _req_model(record)
    if mid is None or node.models is None:
        return 0.0
    swap_s = node.swap_in_s(mid)
    if swap_s == 0.0:
        return 0.0
    pages_lost = node.swap_pages(mid)
    if node.kv_pool_pages is None:
        return swap_s
    ctx = record.req.prompt_len + record.req.gen_len // 2
    need = -(-ctx // node.page_size) if ctx > 0 else 0
    free_after = node.kv_pages_free() - pages_lost
    if free_after < need:
        return 1e9
    headroom = max(node.kv_pages_free() - need, 0)
    displaced = max(pages_lost - headroom, 0)
    return swap_s + node.kv_page_transfer_s(displaced)


def anticipated_eviction_s(record, node: SimNode) -> float:
    """Seconds of KV-page migration this node is PROJECTED to pay if it
    also takes ``record``: residents' final contexts (plus the new
    request's) minus the pool, priced per page over the host link.
    Zero when the futures fit -- only near-capacity nodes are charged.
    """
    if node.kv_pool_pages is None:
        return 0.0
    final_ctx = record.req.prompt_len + record.req.gen_len
    need = max(-(-final_ctx // node.page_size), 1)
    overflow = max(node.kv_pages_projected() + need - node.kv_pool_pages, 0)
    return node.kv_page_transfer_s(overflow) if overflow else 0.0


class Router:
    """Base policy; subclasses override the two scoring hooks.

    ``model_aware=False`` drops the multi-model affinity term from all
    scores -- the baseline that swaps weights wherever load-balancing
    happens to point.
    """

    name = "base"
    model_aware = True

    def __init__(self, model_aware: bool = True):
        self.model_aware = model_aware

    def _affinity(self, record, node: SimNode) -> float:
        return model_affinity_penalty(record, node) if self.model_aware \
            else 0.0

    def route_prefill(self, record, nodes: Sequence[SimNode],
                      now: float) -> SimNode:
        cands = prefill_candidates(nodes, _req_model(record))
        assert cands, "no prefill-capable node in the fleet"
        chosen = min(cands, key=lambda n: (self._prefill_score(record, n, now)
                                           + self._affinity(record, n),
                                           n.node_id))
        chosen.note_prefill_routed(record, now)
        return chosen

    def route_decode(self, record, src: SimNode, nodes: Sequence[SimNode],
                     now: float) -> SimNode:
        cands = decode_candidates(nodes, _req_model(record))
        assert cands, "no decode-capable node in the fleet"
        # score ties break toward the prefill board itself: local decode
        # keeps the KV in HBM and pays no handoff (the planner's
        # colocated model assumes exactly this)
        return min(cands, key=lambda n: (self._decode_score(record, src, n,
                                                            now)
                                         + self._affinity(record, n),
                                         n is not src, n.node_id))

    def route_migration(self, slot, src: SimNode,
                        nodes: Sequence[SimNode], now: float):
        """Pick the board a preempted slot resumes on, or ``None``.

        Migration is only worth its page traffic when the destination
        actually has capacity: the score is the page-granular transfer
        time over the bottleneck host link (``ceil(ctx/page_size)``
        pages, the same units the engine checkpoint ships) plus the
        remaining decode time at the destination's current sharing
        level, with the page-capacity penalty on top.  A destination
        whose own pool cannot hold the context is refused outright --
        shipping KV into another over-committed board trades one spill
        for two plus a transfer.
        """
        mid = getattr(slot, "model_id", None)
        cands = [n for n in decode_candidates(nodes, mid) if n is not src]
        if not cands:
            return None
        ctx = slot.prompt_len + int(slot.tokens_done)
        remaining = max(slot.gen_len - slot.tokens_done, 0.0)
        n_pg = src.migration_pages(ctx)

        def score(n: SimNode) -> float:
            # a destination without the slot's model hot pays the
            # weight swap on top of the KV page transfer
            swap_s = n.swap_in_s(mid) if self.model_aware else 0.0
            return (src.kv_page_transfer_s(n_pg, peer=n.profile) + swap_s
                    + remaining * n.est_decode_step_s(ctx, extra=1, mid=mid)
                    + kv_migration_penalty(ctx, remaining, n))

        best = min(cands, key=lambda n: (score(n), n.node_id))
        if best.kv_overcommit(ctx, int(remaining)) > 0:
            return None
        return best

    # -- scoring hooks (lower wins) ------------------------------------
    def _prefill_score(self, record, node: SimNode, now: float) -> float:
        raise NotImplementedError

    def _decode_score(self, record, src: SimNode, node: SimNode,
                      now: float) -> float:
        raise NotImplementedError


class LeastLoadedRouter(Router):
    name = "least-loaded"

    def _prefill_score(self, record, node: SimNode, now: float) -> float:
        return node.est_prefill_wait_s(now)

    def _decode_score(self, record, src: SimNode, node: SimNode,
                      now: float) -> float:
        return float(node.decode_load()) + kv_capacity_penalty(record, node)


class CostAwareRouter(Router):
    """Minimize incremental $/token: (wait + service) x board $/s."""

    name = "cost-aware"

    def __init__(self, amortization_years: float = 3.0,
                 power_usd_per_kwh: float = 0.10,
                 model_aware: bool = True):
        super().__init__(model_aware=model_aware)
        self.amortization_years = amortization_years
        self.power_usd_per_kwh = power_usd_per_kwh

    def _usd_per_s(self, node: SimNode) -> float:
        capex = capex_usd_per_hour(node.profile, self.amortization_years)
        opex = energy_usd_per_hour(node.profile.tdp_watts,
                                   self.power_usd_per_kwh)
        return (capex + opex) / 3600.0

    def _prefill_score(self, record, node: SimNode, now: float) -> float:
        busy = (node.est_prefill_wait_s(now)
                + node.prefill_service_s(record.req.prompt_len,
                                         _req_model(record)))
        return busy * self._usd_per_s(node) / max(record.req.prompt_len, 1)

    def _decode_score(self, record, src: SimNode, node: SimNode,
                      now: float) -> float:
        ctx = record.req.prompt_len + record.req.gen_len // 2
        t_req = (record.req.gen_len
                 * node.est_decode_step_s(ctx, extra=1 + node.decode_load()
                                          - len(node.decode_active),
                                          mid=_req_model(record)))
        return (t_req * self._usd_per_s(node) / max(record.req.gen_len, 1)
                + kv_capacity_penalty(record, node))


class SLOAwareRouter(Router):
    """Route to minimize predicted TTFT / keep TPOT under the SLO."""

    name = "slo-aware"

    def __init__(self, ttft_slo_s: float = 2.0, tpot_slo_s: float = 0.2,
                 model_aware: bool = True):
        super().__init__(model_aware=model_aware)
        self.ttft_slo_s = ttft_slo_s
        self.tpot_slo_s = tpot_slo_s

    def _prefill_score(self, record, node: SimNode, now: float) -> float:
        mid = _req_model(record)
        ttft = (node.est_prefill_wait_s(now)
                + node.prefill_service_s(record.req.prompt_len, mid)
                + node.prefill_handoff_s(record.req.prompt_len, mid=mid))
        return ttft

    def _decode_score(self, record, src: SimNode, node: SimNode,
                      now: float) -> float:
        ctx = record.req.prompt_len + record.req.gen_len // 2
        active = len(node.decode_active)
        queued = node.decode_load() - active
        # steady-state batch is capped by the lane count: queued work
        # waits, it does not run concurrently
        b = min(node.decode_lanes, active + queued + 1)
        step = node.est_decode_step_s(ctx, extra=max(b - active, 0),
                                      mid=_req_model(record))
        # SLO violators sort after every compliant node; among
        # compliant nodes deeper backlogs (longer queue wait) lose
        penalty = 1e6 if step > self.tpot_slo_s else 0.0
        penalty += kv_capacity_penalty(record, node)
        return penalty + step * (1.0 + queued / max(node.decode_lanes, 1))


class PreemptionAwareSLORouter(SLOAwareRouter):
    """SLO routing that ANTICIPATES eviction cost (the ROADMAP
    follow-on): instead of reacting only once a board's page pool is
    exhausted -- by which point the fleet is already paying a migration
    (``ceil(ctx/page_size)`` pages over the host link) -- the decode
    score charges each candidate the migration seconds its PROJECTED
    final occupancy implies.  A board whose residents' futures already
    fill the pool loses to a peer with headroom even while its present
    occupancy still looks fine, so the request that would have forced
    an eviction lands on the peer up front and the migration never
    happens.
    """

    name = "preempt-aware-slo"

    def __init__(self, ttft_slo_s: float = 2.0, tpot_slo_s: float = 0.2,
                 eviction_weight: float = 1.0, model_aware: bool = True):
        super().__init__(ttft_slo_s, tpot_slo_s, model_aware=model_aware)
        self.eviction_weight = eviction_weight

    def _decode_score(self, record, src: SimNode, node: SimNode,
                      now: float) -> float:
        base = super()._decode_score(record, src, node, now)
        return base + self.eviction_weight * anticipated_eviction_s(
            record, node)
