"""Trace-driven heterogeneous fleet simulation (paper §6.2, dynamic).

Where `repro.serving.disaggregation` plans a mixed fleet's *steady
state*, this package simulates it *over time*: seeded arrival traces,
per-node queueing and continuous batching, prefill->decode KV handoffs
over each board's host link, routing policies, autoscaling, and
per-request latency/energy/cost accounting.  It is the substrate for
scheduling / batching / autoscaling experiments on reclaimed-GPU
fleets.

Quick start::

    from repro.fleet import (FleetSim, LeastLoadedRouter, NodeSpec,
                             bursty_trace, fleet_from_plan)
    from repro.serving import Workload, plan_fleet

    wl = Workload(prompt_len=512, gen_len=128, fmt="q8_0")
    plan = plan_fleet({"a100-40g": 2, "cmp-170hx-nofma": 8}, wl)
    trace = bursty_trace(rate_on_rps=40.0, duration_s=120.0, seed=0)
    report = FleetSim(fleet_from_plan(plan), trace, fmt=wl.fmt).run()
    print(report.ttft_p99_s, report.goodput_rps, report.usd_per_mtok)

Demo: ``PYTHONPATH=src python examples/fleet_sim_demo.py``.

Modules: `workload` (trace generators + multi-model mixes), `node`
(simulated boards incl. resident-model sets), `router` (placement
policies incl. model affinity and anticipated eviction cost), `sim`
(event loop + metrics), `autoscale` (queue-depth pool scaling),
`faults` (deterministic fault plans, injection, recovery policy),
`execution` (replay on the real `ServeEngine` /
`MultiModelServeEngine` to validate token accounting and
crash-recovery exactness).
"""

from repro.fleet.autoscale import QueueDepthAutoscaler
from repro.fleet.execution import (ExecutionResult, FaultReplayResult,
                                   MultiModelExecutionResult,
                                   run_multimodel_trace_on_engine,
                                   run_trace_on_engine,
                                   run_trace_with_faults,
                                   validate_multimodel_exactness,
                                   validate_preemption_exactness,
                                   validate_recovery_exactness,
                                   validate_token_accounting)
from repro.fleet.faults import (FAULT_KINDS, FaultEvent, FaultInjector,
                                FaultPlan, RecoveryPolicy, RetryPolicy)
from repro.fleet.node import SimNode
from repro.fleet.router import (CostAwareRouter, LeastLoadedRouter,
                                PreemptionAwareSLORouter, Router,
                                SLOAwareRouter, anticipated_eviction_s,
                                model_affinity_penalty)
from repro.fleet.sim import (FleetReport, FleetSim, NodeSpec,
                             PreemptionPolicy, RequestRecord,
                             fleet_from_plan)
from repro.fleet.workload import (FleetRequest, LengthDist, bursty_trace,
                                  constant_trace, diurnal_trace,
                                  multimodel_trace, poisson_trace,
                                  shared_prefix_trace)

__all__ = [
    "QueueDepthAutoscaler", "ExecutionResult", "FaultReplayResult",
    "MultiModelExecutionResult", "run_multimodel_trace_on_engine",
    "run_trace_on_engine", "run_trace_with_faults",
    "validate_multimodel_exactness",
    "validate_preemption_exactness", "validate_recovery_exactness",
    "validate_token_accounting",
    "FAULT_KINDS", "FaultEvent", "FaultInjector", "FaultPlan",
    "RecoveryPolicy", "RetryPolicy",
    "SimNode", "CostAwareRouter",
    "LeastLoadedRouter", "PreemptionAwareSLORouter", "Router",
    "SLOAwareRouter", "anticipated_eviction_s", "model_affinity_penalty",
    "FleetReport",
    "FleetSim", "NodeSpec", "PreemptionPolicy", "RequestRecord",
    "fleet_from_plan",
    "FleetRequest", "LengthDist", "bursty_trace", "constant_trace",
    "diurnal_trace", "multimodel_trace", "poisson_trace",
    "shared_prefix_trace",
]
