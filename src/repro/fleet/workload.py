"""Seeded request-trace generators for the fleet simulator.

A trace is a time-ordered list of :class:`FleetRequest` arrivals.  All
randomness flows through one ``numpy.random.default_rng(seed)``, so a
given (generator, parameters, seed) triple is bit-reproducible -- the
property every simulator metric inherits.

Three arrival processes cover the serving regimes that matter:

* :func:`poisson_trace`   -- memoryless steady traffic (M/·/· baseline);
* :func:`bursty_trace`    -- ON/OFF modulated Poisson (flash crowds, the
  regime where disaggregated fleets earn their keep or fall over);
* :func:`diurnal_trace`   -- sinusoidal day/night rate (capacity-planning
  horizon, the autoscaler's target);
* :func:`constant_trace`  -- deterministic arrivals, used to validate the
  simulator's steady state against the analytic planner.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class FleetRequest:
    """One inference request as the router sees it."""

    uid: int
    arrival_s: float
    prompt_len: int
    gen_len: int
    #: which registered model serves this request (None = single-model
    #: fleet, the pre-multimodel behavior)
    model_id: Optional[str] = None
    #: prompt-prefix family: requests sharing a ``prefix_id`` open with
    #: the same ``prefix_len`` tokens (system prompt / few-shot
    #: template).  None = unique prompt, the pre-prefix behavior.  A
    #: prefix-sharing engine/board serves the shared span from cached
    #: KV pages; capacity models discount it accordingly.
    prefix_id: Optional[int] = None
    prefix_len: int = 0


@dataclasses.dataclass(frozen=True)
class LengthDist:
    """Token-length distribution: lognormal around ``mean`` with
    coefficient of variation ``cv`` (``cv=0`` -> constant), clamped."""

    mean: int
    cv: float = 0.0
    min_len: int = 4
    max_len: int = 8192

    def sample(self, rng: np.random.Generator) -> int:
        if self.cv <= 0.0:
            return self.mean
        sigma2 = math.log(1.0 + self.cv ** 2)
        mu = math.log(self.mean) - sigma2 / 2.0
        x = rng.lognormal(mean=mu, sigma=math.sqrt(sigma2))
        return int(min(max(round(x), self.min_len), self.max_len))


def _emit(arrivals: List[float], rng: np.random.Generator,
          prompt: LengthDist, gen: LengthDist) -> List[FleetRequest]:
    return [FleetRequest(uid=i, arrival_s=t,
                         prompt_len=prompt.sample(rng),
                         gen_len=gen.sample(rng))
            for i, t in enumerate(arrivals)]


def poisson_trace(rate_rps: float, duration_s: float, seed: int = 0,
                  prompt: LengthDist = LengthDist(512),
                  gen: LengthDist = LengthDist(128)) -> List[FleetRequest]:
    """Homogeneous Poisson arrivals at ``rate_rps`` for ``duration_s``."""
    rng = np.random.default_rng(seed)
    arrivals, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate_rps)
        if t >= duration_s:
            break
        arrivals.append(t)
    return _emit(arrivals, rng, prompt, gen)


def bursty_trace(rate_on_rps: float, duration_s: float, seed: int = 0,
                 rate_off_rps: Optional[float] = None,
                 mean_on_s: float = 10.0, mean_off_s: float = 20.0,
                 prompt: LengthDist = LengthDist(512),
                 gen: LengthDist = LengthDist(128)) -> List[FleetRequest]:
    """ON/OFF (interrupted Poisson) arrivals.

    The process alternates exponential ON periods (rate ``rate_on_rps``)
    and OFF periods (rate ``rate_off_rps``, default ``rate_on/10``) --
    the bursty regime where queueing, not steady-state throughput,
    decides the tail latency.
    """
    if rate_off_rps is None:
        rate_off_rps = rate_on_rps / 10.0
    rng = np.random.default_rng(seed)
    arrivals: List[float] = []
    t, on = 0.0, True
    phase_end = rng.exponential(mean_on_s)
    while t < duration_s:
        rate = rate_on_rps if on else rate_off_rps
        if rate > 0:
            nxt = t + rng.exponential(1.0 / rate)
            if nxt < phase_end:
                t = nxt
                if t < duration_s:
                    arrivals.append(t)
                continue
        # no arrival before the phase flips (memoryless: restart there)
        t = phase_end
        on = not on
        phase_end = t + rng.exponential(mean_on_s if on else mean_off_s)
    return _emit(arrivals, rng, prompt, gen)


def diurnal_trace(base_rps: float, peak_rps: float, duration_s: float,
                  seed: int = 0, period_s: float = 86400.0,
                  prompt: LengthDist = LengthDist(512),
                  gen: LengthDist = LengthDist(128)) -> List[FleetRequest]:
    """Inhomogeneous Poisson with a sinusoidal day/night rate.

    Sampled by thinning a homogeneous ``peak_rps`` process; the
    instantaneous rate swings between ``base_rps`` (trough) and
    ``peak_rps`` (crest) once per ``period_s``.
    """
    assert peak_rps >= base_rps > 0
    rng = np.random.default_rng(seed)
    arrivals, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / peak_rps)
        if t >= duration_s:
            break
        mid = (base_rps + peak_rps) / 2.0
        amp = (peak_rps - base_rps) / 2.0
        rate = mid + amp * math.sin(2.0 * math.pi * t / period_s)
        if rng.uniform() < rate / peak_rps:
            arrivals.append(t)
    return _emit(arrivals, rng, prompt, gen)


def multimodel_trace(trace: List[FleetRequest], mix: dict,
                     seed: int = 0) -> List[FleetRequest]:
    """Assign a ``model_id`` to every request of ``trace`` by weighted
    draw -- the multi-model request mix.

    ``mix`` maps model id -> weight (normalized internally); the draw
    is seeded separately from the arrival process so the same arrival
    trace can be replayed under different mixes.  Composes with every
    generator above::

        trace = multimodel_trace(poisson_trace(3.0, 60.0, seed=0),
                                 {"qwen2.5-1.5b": 2, "qwen2.5-0.5b": 1},
                                 seed=1)
    """
    assert mix and all(w > 0 for w in mix.values()), mix
    ids = sorted(mix)
    weights = np.asarray([mix[i] for i in ids], np.float64)
    weights = weights / weights.sum()
    rng = np.random.default_rng(seed)
    draws = rng.choice(len(ids), size=len(trace), p=weights)
    return [dataclasses.replace(r, model_id=ids[d])
            for r, d in zip(trace, draws)]


def shared_prefix_trace(trace: List[FleetRequest], prefix_len: int = 256,
                        fanout: int = 4, n_prefixes: Optional[int] = None,
                        seed: int = 0) -> List[FleetRequest]:
    """Overlay a shared-prefix structure on an arrival ``trace``: each
    request joins one of the prompt-prefix families (system prompts /
    few-shot templates) and its prompt OPENS with that family's
    ``prefix_len`` common tokens, followed by a unique tail.

    * ``fanout`` -- mean requests per prefix family (the reuse degree);
      ``n_prefixes`` overrides it with a fixed family count;
    * prompts shorter than ``prefix_len + 1`` are lengthened to hold
      the prefix plus at least one unique tail token (a real serving
      stack never sees a prompt that is ONLY the cached template);
    * the family draw is seeded separately from the arrival process so
      the same arrivals replay under different sharing structures.

    The *overlap fraction* -- the knob the prefix bench sweeps -- is
    ``prefix_len / mean_prompt_len``.  Composes with every generator in
    this module, like :func:`multimodel_trace`::

        trace = shared_prefix_trace(poisson_trace(3.0, 60.0, seed=0),
                                    prefix_len=256, fanout=8, seed=1)
    """
    assert prefix_len > 0 and fanout >= 1
    if not trace:
        return []
    k = n_prefixes if n_prefixes is not None \
        else max(int(round(len(trace) / fanout)), 1)
    rng = np.random.default_rng(seed)
    fams = rng.integers(0, k, size=len(trace))
    return [dataclasses.replace(
                r, prefix_id=int(f), prefix_len=prefix_len,
                prompt_len=max(r.prompt_len, prefix_len + 1))
            for r, f in zip(trace, fams)]


def constant_trace(rate_rps: float, duration_s: float,
                   prompt_len: int = 512,
                   gen_len: int = 128) -> List[FleetRequest]:
    """Deterministic arrivals every ``1/rate`` s with fixed lengths --
    the steady-state fixture for validating against ``plan_fleet``."""
    n = int(rate_rps * duration_s)
    return [FleetRequest(uid=i, arrival_s=(i + 1) / rate_rps,
                         prompt_len=prompt_len, gen_len=gen_len)
            for i in range(n)]
