"""Deterministic fault injection for the fleet: plans, injector, recovery.

Salvaged mining boards are not datacenter parts: a CMP 170HX drops off
the bus, its PCIe-1.1-x4 host link flaps, thermals derate the clock.
This module makes those regimes first-class and DETERMINISTIC -- a
:class:`FaultPlan` is a seeded, immutable schedule of fault events that
plugs into both the discrete-event simulator (``FleetSim(faults=...)``)
and the execution-backed replay on the real engine
(``fleet.execution.run_trace_with_faults``):

* events scheduled **by sim time** (``at_s``) drive the simulator;
* events scheduled **by dispatch index** (``at_dispatch``) drive the
  replay, where "time" is the decode dispatch counter.

Fault taxonomy (``FaultEvent.kind``):

========== ============================================================
``crash``     node fails permanently; live lanes recover via checkpoint
              migration (``Router.route_migration``) or replay-from-
              prompt when no checkpoint interval has elapsed
``derate``    compute/thermal derate: step and prefill times dilate by
              ``factor`` for ``duration_s`` (or forever)
``link``      host-link degradation/flap: PCIe transfer times dilate by
              ``factor`` for ``duration_s``
``transient`` transient dispatch error: the node stalls for
              ``duration_s`` (sim) / one dispatch is retried (replay)
========== ============================================================

:class:`RecoveryPolicy` bundles the checkpoint cadence with a
:class:`~repro.serving.resilience.RetryPolicy`; counters land in the
``fleet.faults.*`` / ``fleet.retry.*`` registry namespace via
:class:`FaultInjector`.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.serving.resilience import RetryPolicy

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultPlan",
    "FaultInjector",
    "RecoveryPolicy",
    "RetryPolicy",
]

FAULT_KINDS = ("crash", "derate", "link", "transient")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``node`` selects the target: an int indexes the ALIVE node set
    (sorted by ``node_id``, modulo its size -- stable under autoscaling
    and prior crashes), a str matches a ``node_id`` exactly.  Exactly
    one of ``at_s`` (sim clock) / ``at_dispatch`` (replay dispatch
    index) must be set.
    """

    kind: str
    node: Union[int, str] = 0
    at_s: Optional[float] = None
    at_dispatch: Optional[int] = None
    factor: float = 1.0               # derate/link dilation (>= 1)
    duration_s: Optional[float] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if (self.at_s is None) == (self.at_dispatch is None):
            raise ValueError("exactly one of at_s / at_dispatch must be set")
        if self.factor < 1.0:
            raise ValueError("factor dilates time; must be >= 1")
        if self.kind == "transient" and self.at_s is not None \
                and self.duration_s is None:
            raise ValueError("sim-time transient faults need duration_s")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Immutable, ordered schedule of :class:`FaultEvent`."""

    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    # -- construction ---------------------------------------------------
    @classmethod
    def seeded(cls, seed: int, n_nodes: int, horizon_s: float,
               n_crashes: int = 1, n_derates: int = 1, n_links: int = 1,
               n_transients: int = 1, derate_factor: float = 2.0,
               link_factor: float = 4.0,
               transient_s: float = 0.25) -> "FaultPlan":
        """Deterministic random plan over ``[0.1, 0.9] * horizon_s``.

        Crashes land in the middle half of the horizon so a "kill a node
        mid-trace" scenario is the default; windows (derate/link) last a
        random 10-30% of the horizon.
        """
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for _ in range(n_crashes):
            events.append(FaultEvent(
                "crash", node=int(rng.integers(n_nodes)),
                at_s=float(rng.uniform(0.25, 0.75) * horizon_s)))
        for kind, n, factor in (("derate", n_derates, derate_factor),
                                ("link", n_links, link_factor)):
            for _ in range(n):
                events.append(FaultEvent(
                    kind, node=int(rng.integers(n_nodes)),
                    at_s=float(rng.uniform(0.1, 0.6) * horizon_s),
                    factor=factor,
                    duration_s=float(rng.uniform(0.1, 0.3) * horizon_s)))
        for _ in range(n_transients):
            events.append(FaultEvent(
                "transient", node=int(rng.integers(n_nodes)),
                at_s=float(rng.uniform(0.1, 0.9) * horizon_s),
                duration_s=transient_s))
        events.sort(key=lambda e: (e.at_s, e.kind, str(e.node)))
        return cls(tuple(events))

    @classmethod
    def flap(cls, node: Union[int, str], t0: float, period_s: float,
             n_flaps: int, factor: float = 4.0) -> "FaultPlan":
        """A flapping host link: ``n_flaps`` degradation windows of
        ``period_s / 2`` starting at ``t0``, one per ``period_s``."""
        events = tuple(
            FaultEvent("link", node=node, at_s=t0 + i * period_s,
                       factor=factor, duration_s=period_s / 2.0)
            for i in range(n_flaps))
        return cls(events)

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        merged = sorted(
            self.events + other.events,
            key=lambda e: (e.at_s if e.at_s is not None else float(
                e.at_dispatch), e.kind, str(e.node)))
        return FaultPlan(tuple(merged))

    # -- views ----------------------------------------------------------
    def sim_events(self) -> List[FaultEvent]:
        """Events scheduled on the sim clock, in time order."""
        return sorted((e for e in self.events if e.at_s is not None),
                      key=lambda e: (e.at_s, e.kind, str(e.node)))

    def crash_dispatch(self) -> Optional[int]:
        """First dispatch-indexed crash (replay mode), or None."""
        idx = [e.at_dispatch for e in self.events
               if e.kind == "crash" and e.at_dispatch is not None]
        return min(idx) if idx else None

    def transient_dispatches(self) -> List[int]:
        """Dispatch indices with a transient dispatch error (replay)."""
        return sorted(e.at_dispatch for e in self.events
                      if e.kind == "transient" and e.at_dispatch is not None)


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """How the fleet recovers from the plan's faults.

    * ``checkpoint_interval_s`` -- cadence of the host-side lane
      checkpoints the sim takes; a crashed lane restores from its last
      checkpoint (pages generated since are lost) or, if none has been
      taken yet, replays from the prompt.
    * ``retry`` -- request-layer retry/hedging policy for work the crash
      (or an exhausted router) orphaned.
    """

    checkpoint_interval_s: float = 5.0
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)


class FaultInjector:
    """Applies a :class:`FaultPlan` to a fleet and counts what it did.

    The injector owns target resolution (stable node selection under
    autoscaling/crashes) and the ``fleet.faults.*`` registry counters;
    the actual state transitions live in ``FleetSim`` (sim clock) and
    ``fleet.execution`` (dispatch index), which call back into it.
    """

    COUNTERS = {
        "crash": "fleet.faults.crashes",
        "derate": "fleet.faults.derates",
        "link": "fleet.faults.link_events",
        "transient": "fleet.faults.transients",
    }

    def __init__(self, plan: FaultPlan,
                 registry: Optional[MetricsRegistry] = None):
        self.plan = plan
        self.registry = registry if registry is not None else MetricsRegistry()
        for metric in self.COUNTERS.values():
            self.registry.counter(metric).set(0)
        self.registry.counter("fleet.retry.attempts").set(0)
        self.registry.counter("fleet.retry.hedges").set(0)
        self.registry.counter("fleet.faults.requests_lost").set(0)

    def resolve(self, ev: FaultEvent, nodes: Sequence) -> Optional[object]:
        """Target node of ``ev`` among the currently-alive ``nodes``
        (objects with ``node_id``); None when nothing matches."""
        alive = [n for n in nodes if not getattr(n, "failed", False)]
        if not alive:
            return None
        if isinstance(ev.node, str):
            for n in alive:
                if n.node_id == ev.node:
                    return n
            return None
        ordered = sorted(alive, key=lambda n: n.node_id)
        return ordered[ev.node % len(ordered)]

    def count(self, kind: str, n: int = 1) -> None:
        self.registry.counter(self.COUNTERS[kind]).inc(n)

    def count_retry(self, n: int = 1) -> None:
        self.registry.counter("fleet.retry.attempts").inc(n)

    def count_hedge(self, n: int = 1) -> None:
        self.registry.counter("fleet.retry.hedges").inc(n)

    def count_lost(self, n: int = 1) -> None:
        self.registry.counter("fleet.faults.requests_lost").inc(n)
