"""Queue-depth-driven autoscaling over one pool of the simulated fleet.

A :class:`QueueDepthAutoscaler` watches one (profile, role) pool on a
fixed control interval and keeps its backlog-per-node between a low and
a high watermark: above the high mark it clones a node from the pool
template (cold-start delay included -- reclaimed boards still take time
to join), below the low mark it drains the least-loaded node.  Scale
decisions are pure functions of simulated state, so runs stay
deterministic.

Backlog metric: prefill-capable pools use the estimated FIFO wait in
units of one request's service time; decode-capable pools use resident
requests per lane.
"""

from __future__ import annotations

import dataclasses
from typing import List

from repro.fleet.node import SimNode
from repro.fleet.sim import FleetSim, NodeSpec


@dataclasses.dataclass
class QueueDepthAutoscaler:
    """Scale ``template``'s pool between ``min_nodes`` and ``max_nodes``."""

    template: NodeSpec
    interval_s: float = 10.0
    high_depth: float = 2.0
    low_depth: float = 0.25
    min_nodes: int = 1
    max_nodes: int = 16
    cold_start_s: float = 30.0
    #: prompt length used to express prefill backlog in units of one
    #: request's service time -- set it to the workload's prompt_len.
    ref_prompt_len: int = 512

    def _pool(self, sim: FleetSim) -> List[SimNode]:
        return [n for n in sim.nodes
                if n.profile.name == self.template.profile
                and n.role == self.template.role and not n.draining]

    def _depth(self, node: SimNode, now: float) -> float:
        if node.role in ("decode", "both"):
            lane_depth = node.decode_load() / max(node.decode_lanes, 1)
            if node.kv_pool_pages is not None:
                # paged capacity is bytes: pressure is whichever binds
                # first, lanes or page-pool occupancy
                page_depth = (node.kv_pages_in_use()
                              / max(node.kv_pool_pages, 1))
                return max(lane_depth, page_depth)
            return lane_depth
        svc = node.prefill_service_s(self.ref_prompt_len)
        return node.est_prefill_wait_s(now) / max(svc, 1e-9)

    def tick(self, sim: FleetSim, now: float) -> List[str]:
        pool = self._pool(sim)
        if not pool:
            return []
        depth = sum(self._depth(n, now) for n in pool) / len(pool)
        if depth > self.high_depth and len(pool) < self.max_nodes:
            node = sim.add_node(self.template, now=now + self.cold_start_s)
            return [f"t={now:.1f}s depth={depth:.2f} +1 -> "
                    f"{node.node_id} (joins t={now + self.cold_start_s:.1f}s)"]
        if depth < self.low_depth and len(pool) > self.min_nodes:
            victim = min(pool, key=lambda n: (self._depth(n, now),
                                              n.node_id))
            sim.retire_node(victim, now)
            return [f"t={now:.1f}s depth={depth:.2f} -1 -> "
                    f"drain {victim.node_id}"]
        return []
