"""Execution-backed mode: replay a trace on the *real* ServeEngine.

The simulator's value rests on its token accounting being honest, so
for configs small enough to run on the host this module replays the
same :class:`~repro.fleet.workload.FleetRequest` trace through
:class:`~repro.serving.engine.ServeEngine` (the actual jax continuous
batcher) and cross-checks per-request token counts against what the
simulator claims to have served.  Arrival times are ignored by the
engine -- it saturates its lanes in arrival order -- because the check
is about *accounting* (every prompt token prefilled, every generation
capped at ``gen_len``), not wall-clock latency.

``validate_token_accounting`` is the contract the tests pin down:
simulated served-token totals must equal the engine's exactly.
"""

from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.fleet.sim import FleetReport, FleetSim
from repro.fleet.workload import FleetRequest
from repro.models.common import ModelConfig
from repro.obs import events as obs_events
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import SpanTracer
from repro.serving.engine import Request, ServeEngine
from repro.serving.modelpool import ModelPool, MultiModelServeEngine


@dataclasses.dataclass(frozen=True)
class ExecutionResult:
    """Token accounting from a real engine replay of a trace.

    ``kv_pages_hwm`` / ``kv_admit_blocked`` surface the paged engine's
    page-pool pressure: peak pages promised+mapped, and requests that
    found a free lane but had to WAIT for pages (counted once per
    blocked episode, so the number is dispatch-granularity invariant).
    Zero for a fixed-lane replay.  These feed the sim-to-real
    calibration loop: the simulator's ``SimNode.kv_pages_hwm`` models
    the same peak.

    Naming note: this field was published as ``kv_spill_events`` for a
    while, ALIASING the simulator's counter of the same name -- which
    counts over-commit TRANSITIONS in ``SimNode._note_occupancy``, a
    different event (the sim over-commits where the engine defers
    admission).  The telemetry schema keeps them distinct
    (``serve.kv.admit_blocked`` vs ``fleet.node.*.kv_spill_events``);
    the old attribute survives as a deprecated alias.
    """

    prompt_tokens: int
    gen_tokens: int
    gen_by_uid: Dict[int, int]
    decode_dispatches: int = 0
    decode_steps: int = 0
    kv_pages_hwm: int = 0
    kv_admit_blocked: int = 0
    #: mid-decode evictions / checkpoint re-admissions / KV pages that
    #: crossed an evict->restore cycle during the replay (all zero when
    #: the replay runs without preemption injection)
    preemptions: int = 0
    restores: int = 0
    pages_migrated: int = 0

    @property
    def kv_spill_events(self) -> int:
        """Deprecated alias of ``kv_admit_blocked`` (the engine never
        spills; the sim's spill counter is a different event)."""
        warnings.warn(
            "ExecutionResult.kv_spill_events is a deprecated alias of "
            "kv_admit_blocked (the simulator's kv_spill_events counts "
            "over-commit transitions, a distinct event)",
            DeprecationWarning, stacklevel=2)
        return self.kv_admit_blocked


def run_trace_on_engine(trace: Sequence[FleetRequest], cfg: ModelConfig,
                        params, n_lanes: int = 2, max_len: int = 64,
                        vocab_size: Optional[int] = None,
                        seed: int = 0,
                        dispatch_n: int = 8,
                        paged: bool = False, page_size: int = 16,
                        n_pages: Optional[int] = None,
                        temperature: float = 0.0,
                        preempt_every: Optional[int] = None,
                        tracer: Optional[SpanTracer] = None,
                        registry: Optional[MetricsRegistry] = None
                        ) -> ExecutionResult:
    """Serve ``trace`` through the real continuous batcher.

    Prompt token ids are derived deterministically from the request uid,
    so the replay itself is seed-reproducible.  ``dispatch_n`` is the
    engine's multi-token decode granularity (tokens per host dispatch);
    the replayed token counts are dispatch-size invariant.  ``paged``
    replays through the page-pool cache (token counts are layout
    invariant; the page stats are what changes).

    ``preempt_every`` (paged only) injects evict-and-replay churn: at
    every k-th dispatch boundary the live lane with the LONGEST context
    is evicted into a :class:`~repro.serving.engine.LaneCheckpoint` and
    held until the pool re-admits it -- the execution-backed analogue of
    a fleet migration, minus the wire.  Token counts (and the token
    streams themselves, see ``validate_preemption_exactness``) must be
    preemption invariant.
    """
    vocab = vocab_size or cfg.vocab_size
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=r.uid,
                    prompt=rng.integers(0, vocab, r.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=r.gen_len)
            for r in sorted(trace, key=lambda r: (r.arrival_s, r.uid))]
    engine = ServeEngine(cfg, params, n_lanes=n_lanes, max_len=max_len,
                         dispatch_n=dispatch_n, paged=paged,
                         page_size=page_size, n_pages=n_pages,
                         temperature=temperature, tracer=tracer,
                         registry=registry)
    if preempt_every is None:
        engine.run(reqs)
    else:
        assert paged, "preemption replay needs the paged engine"
        _run_with_preemption(engine, reqs, preempt_every)
    if paged:
        engine.pool.check()
        assert engine.pool.n_in_use == 0, "replay leaked KV pages"
    gen_by_uid = {r.uid: len(r.generated) for r in reqs}
    return ExecutionResult(
        prompt_tokens=sum(len(r.prompt) for r in reqs),
        gen_tokens=sum(gen_by_uid.values()),
        gen_by_uid=gen_by_uid,
        decode_dispatches=engine.stats["decode_dispatches"],
        decode_steps=engine.stats["decode_steps"],
        kv_pages_hwm=engine.stats["kv_pages_hwm"],
        kv_admit_blocked=engine.stats["kv_admit_blocked"],
        preemptions=engine.stats["preemptions"],
        restores=engine.stats["restores"],
        pages_migrated=engine.stats["pages_migrated"])


def _run_with_preemption(engine: ServeEngine, reqs, every: int) -> None:
    """Continuous batching with periodic evict-and-replay.

    Held checkpoints have strict re-admission priority over fresh
    requests (an evicted request must not starve behind the queue it
    was serving ahead of).  An empty engine always fits one checkpoint
    -- restore needs at most a full context, the pool's guaranteed
    minimum -- so the loop cannot wedge.
    """
    pending = list(reqs)
    held: deque = deque()
    blocks = 0
    while pending or held or engine.live_lanes():
        while held and engine.restore(held[0]):
            held.popleft()
        if not held:
            while pending and engine.free_lanes():
                if not engine.admit(pending[0]):
                    break
                pending.pop(0)
        if not engine.live_lanes():
            raise RuntimeError("preemption replay made no progress")
        engine.decode_n()
        blocks += 1
        if blocks % every == 0:
            live = engine.live_lanes()
            if live:
                lane = max(live, key=lambda i: (engine.lane_context(i), -i))
                held.append(engine.evict(lane))


def validate_preemption_exactness(trace: Sequence[FleetRequest],
                                  cfg: ModelConfig, params,
                                  preempt_every: int = 2,
                                  **kw) -> Dict[str, object]:
    """Replay ``trace`` with and without evict-and-replay churn and diff
    the TOKEN STREAMS (not just counts): a migrated request must resume
    bit-identically.  Returns the diff plus the preemption counters."""
    kw = dict(kw, paged=True)
    vocab = kw.pop("vocab_size", None) or cfg.vocab_size

    def streams(preempt):
        rng = np.random.default_rng(kw.get("seed", 0))
        reqs = [Request(uid=r.uid,
                        prompt=rng.integers(0, vocab, r.prompt_len,
                                            dtype=np.int32),
                        max_new_tokens=r.gen_len)
                for r in sorted(trace, key=lambda r: (r.arrival_s, r.uid))]
        engine = ServeEngine(cfg, params,
                             n_lanes=kw.get("n_lanes", 2),
                             max_len=kw.get("max_len", 64),
                             dispatch_n=kw.get("dispatch_n", 8),
                             paged=True,
                             page_size=kw.get("page_size", 16),
                             n_pages=kw.get("n_pages"),
                             temperature=kw.get("temperature", 0.0))
        if preempt:
            _run_with_preemption(engine, reqs, preempt_every)
        else:
            engine.run(reqs)
        engine.pool.check()
        return {r.uid: tuple(r.generated) for r in reqs}, engine.stats

    base, _ = streams(False)
    moved, stats = streams(True)
    mismatches = {uid: (base[uid], moved[uid]) for uid in base
                  if base[uid] != moved[uid]}
    verdict = {
        "resume_exact": not mismatches,
        "mismatches": mismatches,
        "preemptions": stats["preemptions"],
        "restores": stats["restores"],
        "pages_migrated": stats["pages_migrated"],
    }
    # auditable record: the replay session keeps evidence the check ran
    obs_events.emit("validate.preemption_exactness",
                    resume_exact=verdict["resume_exact"],
                    n_requests=len(base),
                    n_mismatches=len(mismatches),
                    preemptions=verdict["preemptions"],
                    restores=verdict["restores"],
                    pages_migrated=verdict["pages_migrated"])
    return verdict


@dataclasses.dataclass(frozen=True)
class MultiModelExecutionResult:
    """Token + swap accounting from a multi-model engine replay."""

    prompt_tokens: int
    gen_tokens: int
    gen_by_uid: Dict[int, int]
    gen_by_model: Dict[str, int]
    model_swaps: int = 0
    swap_bytes: int = 0
    weight_evictions: int = 0
    kv_pages_shrunk: int = 0
    kv_pages_grown: int = 0


def dense_hbm_bytes(models: Dict[str, Tuple[ModelConfig, object]],
                    n_lanes: int, max_len: int, page_size: int) -> int:
    """Board budget holding EVERY model resident at its dense KV target
    (weights + ``n_lanes`` full contexts + scratch) -- the no-swap
    baseline; anything tighter exercises weight paging."""
    from repro.models.transformer import paged_capacity
    from repro.serving.modelpool import kv_page_bytes, params_nbytes

    total = 0
    for cfg, params in models.values():
        bt = (0 if cfg.attn_free
              else paged_capacity(max_len, cfg) // page_size)
        total += params_nbytes(params) + (
            n_lanes * bt + 1) * kv_page_bytes(cfg, page_size)
    return total


def _mm_requests(trace: Sequence[FleetRequest],
                 models: Dict[str, Tuple[ModelConfig, object]],
                 seed: int) -> list:
    """Deterministic multi-model request list from a fleet trace (ids
    derived from one rng stream, exactly like ``run_trace_on_engine``,
    clamped to each request's own model vocab)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for r in sorted(trace, key=lambda r: (r.arrival_s, r.uid)):
        assert r.model_id in models, f"trace uid={r.uid} names " \
            f"unregistered model {r.model_id!r}"
        vocab = models[r.model_id][0].vocab_size
        reqs.append(Request(uid=r.uid,
                            prompt=rng.integers(0, vocab, r.prompt_len,
                                                dtype=np.int32),
                            max_new_tokens=r.gen_len,
                            model_id=r.model_id))
    return reqs


def run_multimodel_trace_on_engine(
        trace: Sequence[FleetRequest],
        models: Dict[str, Tuple[ModelConfig, object]],
        hbm_bytes: Optional[int] = None,
        n_lanes: int = 2, max_len: int = 64, seed: int = 0,
        dispatch_n: int = 8, page_size: int = 16,
        temperature: float = 0.0) -> MultiModelExecutionResult:
    """Serve a multi-model ``trace`` through the REAL
    :class:`~repro.serving.modelpool.MultiModelServeEngine`.

    ``models`` maps model id -> (cfg, params).  ``hbm_bytes`` is the
    board budget weights and KV pages share; ``None`` sizes it to hold
    every model at its dense KV target (no swap pressure), which is the
    accounting baseline -- pass something tighter to exercise weight
    paging.  Token counts must be budget invariant (streams depend only
    on per-model admission order); the swap counters are what changes.
    """
    if hbm_bytes is None:
        hbm_bytes = dense_hbm_bytes(models, n_lanes=n_lanes,
                                    max_len=max_len, page_size=page_size)
    pool = ModelPool(hbm_bytes, page_size=page_size)
    for mid in sorted(models):
        pool.register(mid, models[mid][0], models[mid][1])
    engine = MultiModelServeEngine(pool, n_lanes=n_lanes, max_len=max_len,
                                   temperature=temperature, rng_seed=seed,
                                   dispatch_n=dispatch_n)
    reqs = _mm_requests(trace, models, seed)
    engine.run(reqs)
    for eng in engine.engines.values():
        eng.pool.check()
        assert eng.pool.n_in_use == 0, "replay leaked KV pages"
    gen_by_uid = {r.uid: len(r.generated) for r in reqs}
    gen_by_model: Dict[str, int] = {}
    for r in reqs:
        gen_by_model[r.model_id] = (gen_by_model.get(r.model_id, 0)
                                    + len(r.generated))
    return MultiModelExecutionResult(
        prompt_tokens=sum(len(r.prompt) for r in reqs),
        gen_tokens=sum(gen_by_uid.values()),
        gen_by_uid=gen_by_uid, gen_by_model=gen_by_model,
        model_swaps=engine.stats["model_swaps"],
        swap_bytes=engine.stats["swap_bytes"],
        weight_evictions=engine.stats["weight_evictions"],
        kv_pages_shrunk=engine.stats["kv_pages_shrunk"],
        kv_pages_grown=engine.stats["kv_pages_grown"])


def validate_multimodel_exactness(
        trace: Sequence[FleetRequest],
        models: Dict[str, Tuple[ModelConfig, object]],
        hbm_bytes: Optional[int] = None, **kw) -> Dict[str, object]:
    """Replay a multi-model trace and diff each model's TOKEN STREAMS
    against the same requests served ALONE by a single-model
    ``ServeEngine`` with the same config/seed/temperature -- the
    exactness contract of the multi-model engine.  Returns the diff
    plus the swap counters."""
    seed = kw.get("seed", 0)
    engine_kw = dict(n_lanes=kw.get("n_lanes", 2),
                     max_len=kw.get("max_len", 64),
                     dispatch_n=kw.get("dispatch_n", 8),
                     temperature=kw.get("temperature", 0.0))
    page_size = kw.get("page_size", 16)

    reqs = _mm_requests(trace, models, seed)
    if hbm_bytes is None:
        hbm_bytes = dense_hbm_bytes(models, n_lanes=engine_kw["n_lanes"],
                                    max_len=engine_kw["max_len"],
                                    page_size=page_size)
    pool = ModelPool(hbm_bytes, page_size=page_size)
    for mid in sorted(models):
        pool.register(mid, models[mid][0], models[mid][1])
    mm = MultiModelServeEngine(pool, rng_seed=seed, **engine_kw)
    mm.run(reqs)
    moved = {r.uid: tuple(r.generated) for r in reqs}

    mismatches = {}
    for mid in sorted(models):
        cfg, params = models[mid]
        solo = [Request(uid=r.uid, prompt=r.prompt.copy(),
                        max_new_tokens=r.max_new_tokens)
                for r in reqs if r.model_id == mid]
        ref = ServeEngine(cfg, params, paged=True, page_size=page_size,
                          rng_seed=seed, **engine_kw)
        ref.run(solo)
        for r in solo:
            if tuple(r.generated) != moved[r.uid]:
                mismatches[r.uid] = (tuple(r.generated), moved[r.uid])
    verdict = {
        "exact": not mismatches,
        "mismatches": mismatches,
        "model_swaps": mm.stats["model_swaps"],
        "swap_bytes": mm.stats["swap_bytes"],
        "weight_evictions": mm.stats["weight_evictions"],
        "gen_by_model": {mid: sum(len(r.generated) for r in reqs
                                  if r.model_id == mid)
                         for mid in sorted(models)},
    }
    # auditable record: the replay session keeps evidence the check ran
    obs_events.emit("validate.multimodel_exactness",
                    exact=verdict["exact"],
                    n_requests=len(reqs),
                    n_mismatches=len(mismatches),
                    model_swaps=verdict["model_swaps"],
                    weight_evictions=verdict["weight_evictions"])
    return verdict


def simulated_token_accounting(sim: FleetSim,
                               report: FleetReport) -> Dict[int, int]:
    """Per-uid generated-token counts the simulator claims to have served."""
    return {rec.req.uid: (rec.req.gen_len if rec.done else 0)
            for rec in sim.records}


def validate_token_accounting(sim: FleetSim, report: FleetReport,
                              cfg: ModelConfig, params,
                              n_lanes: int = 2,
                              max_len: int = 64) -> Dict[str, object]:
    """Replay the sim's trace on the engine and diff token counts."""
    sim_counts = simulated_token_accounting(sim, report)
    exe = run_trace_on_engine([rec.req for rec in sim.records], cfg,
                              params, n_lanes=n_lanes, max_len=max_len)
    mismatches = {uid: (sim_counts.get(uid, 0), got)
                  for uid, got in exe.gen_by_uid.items()
                  if sim_counts.get(uid, 0) != got}
    return {
        "sim_prompt_tokens": sum(rec.req.prompt_len
                                 for rec in sim.records if rec.done),
        "sim_gen_tokens": sum(sim_counts.values()),
        "engine_prompt_tokens": exe.prompt_tokens,
        "engine_gen_tokens": exe.gen_tokens,
        "mismatches": mismatches,
        "match": not mismatches,
    }
