"""Execution-backed mode: replay a trace on the *real* ServeEngine.

The simulator's value rests on its token accounting being honest, so
for configs small enough to run on the host this module replays the
same :class:`~repro.fleet.workload.FleetRequest` trace through
:class:`~repro.serving.engine.ServeEngine` (the actual jax continuous
batcher) and cross-checks per-request token counts against what the
simulator claims to have served.  Arrival times are ignored by the
engine -- it saturates its lanes in arrival order -- because the check
is about *accounting* (every prompt token prefilled, every generation
capped at ``gen_len``), not wall-clock latency.

``validate_token_accounting`` is the contract the tests pin down:
simulated served-token totals must equal the engine's exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.fleet.sim import FleetReport, FleetSim
from repro.fleet.workload import FleetRequest
from repro.models.common import ModelConfig
from repro.serving.engine import Request, ServeEngine


@dataclasses.dataclass(frozen=True)
class ExecutionResult:
    """Token accounting from a real engine replay of a trace.

    ``kv_pages_hwm`` / ``kv_spill_events`` surface the paged engine's
    page-pool pressure: peak pages promised+mapped, and requests that
    found a free lane but had to WAIT for pages (counted once per
    blocked episode, so the number is dispatch-granularity invariant).
    Zero for a fixed-lane replay.  These feed the sim-to-real
    calibration loop: the simulator's ``SimNode.kv_pages_hwm`` models
    the same peak; its ``kv_spill_events`` counts over-commit
    transitions, the sim-side analogue of a blocked episode (the sim
    over-commits where the engine defers).
    """

    prompt_tokens: int
    gen_tokens: int
    gen_by_uid: Dict[int, int]
    decode_dispatches: int = 0
    decode_steps: int = 0
    kv_pages_hwm: int = 0
    kv_spill_events: int = 0


def run_trace_on_engine(trace: Sequence[FleetRequest], cfg: ModelConfig,
                        params, n_lanes: int = 2, max_len: int = 64,
                        vocab_size: Optional[int] = None,
                        seed: int = 0,
                        dispatch_n: int = 8,
                        paged: bool = False, page_size: int = 16,
                        n_pages: Optional[int] = None) -> ExecutionResult:
    """Serve ``trace`` through the real continuous batcher.

    Prompt token ids are derived deterministically from the request uid,
    so the replay itself is seed-reproducible.  ``dispatch_n`` is the
    engine's multi-token decode granularity (tokens per host dispatch);
    the replayed token counts are dispatch-size invariant.  ``paged``
    replays through the page-pool cache (token counts are layout
    invariant; the page stats are what changes).
    """
    vocab = vocab_size or cfg.vocab_size
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=r.uid,
                    prompt=rng.integers(0, vocab, r.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=r.gen_len)
            for r in sorted(trace, key=lambda r: (r.arrival_s, r.uid))]
    engine = ServeEngine(cfg, params, n_lanes=n_lanes, max_len=max_len,
                         dispatch_n=dispatch_n, paged=paged,
                         page_size=page_size, n_pages=n_pages)
    engine.run(reqs)
    gen_by_uid = {r.uid: len(r.generated) for r in reqs}
    return ExecutionResult(
        prompt_tokens=sum(len(r.prompt) for r in reqs),
        gen_tokens=sum(gen_by_uid.values()),
        gen_by_uid=gen_by_uid,
        decode_dispatches=engine.stats["decode_dispatches"],
        decode_steps=engine.stats["decode_steps"],
        kv_pages_hwm=engine.stats["kv_pages_hwm"],
        kv_spill_events=engine.stats["kv_admit_blocked"])


def simulated_token_accounting(sim: FleetSim,
                               report: FleetReport) -> Dict[int, int]:
    """Per-uid generated-token counts the simulator claims to have served."""
    return {rec.req.uid: (rec.req.gen_len if rec.done else 0)
            for rec in sim.records}


def validate_token_accounting(sim: FleetSim, report: FleetReport,
                              cfg: ModelConfig, params,
                              n_lanes: int = 2,
                              max_len: int = 64) -> Dict[str, object]:
    """Replay the sim's trace on the engine and diff token counts."""
    sim_counts = simulated_token_accounting(sim, report)
    exe = run_trace_on_engine([rec.req for rec in sim.records], cfg,
                              params, n_lanes=n_lanes, max_len=max_len)
    mismatches = {uid: (sim_counts.get(uid, 0), got)
                  for uid, got in exe.gen_by_uid.items()
                  if sim_counts.get(uid, 0) != got}
    return {
        "sim_prompt_tokens": sum(rec.req.prompt_len
                                 for rec in sim.records if rec.done),
        "sim_gen_tokens": sum(sim_counts.values()),
        "engine_prompt_tokens": exe.prompt_tokens,
        "engine_gen_tokens": exe.gen_tokens,
        "mismatches": mismatches,
        "match": not mismatches,
    }
