"""Execution-backed mode: replay a trace on the *real* ServeEngine.

The simulator's value rests on its token accounting being honest, so
for configs small enough to run on the host this module replays the
same :class:`~repro.fleet.workload.FleetRequest` trace through
:class:`~repro.serving.engine.ServeEngine` (the actual jax continuous
batcher) and cross-checks per-request token counts against what the
simulator claims to have served.  Arrival times are ignored by the
engine -- it saturates its lanes in arrival order -- because the check
is about *accounting* (every prompt token prefilled, every generation
capped at ``gen_len``), not wall-clock latency.

``validate_token_accounting`` is the contract the tests pin down:
simulated served-token totals must equal the engine's exactly.
"""

from __future__ import annotations

import dataclasses
import os
from collections import deque
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.fleet.faults import FaultPlan
from repro.fleet.sim import FleetReport, FleetSim
from repro.fleet.workload import FleetRequest
from repro.models.common import ModelConfig
from repro.obs import events as obs_events
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import SpanTracer
from repro.serving.engine import LaneCheckpoint, Request, ServeEngine
from repro.serving.modelpool import ModelPool, MultiModelServeEngine


@dataclasses.dataclass(frozen=True)
class ExecutionResult:
    """Token accounting from a real engine replay of a trace.

    ``kv_pages_hwm`` / ``kv_admit_blocked`` surface the paged engine's
    page-pool pressure: peak pages promised+mapped, and requests that
    found a free lane but had to WAIT for pages (counted once per
    blocked episode, so the number is dispatch-granularity invariant).
    Zero for a fixed-lane replay.  These feed the sim-to-real
    calibration loop: the simulator's ``SimNode.kv_pages_hwm`` models
    the same peak.  (The simulator's ``kv_spill_events`` counts
    over-commit TRANSITIONS in ``SimNode._note_occupancy`` -- a
    different event; the telemetry schema keeps them distinct as
    ``serve.kv.admit_blocked`` vs ``fleet.node.*.kv_spill_events``.)
    """

    prompt_tokens: int
    gen_tokens: int
    gen_by_uid: Dict[int, int]
    decode_dispatches: int = 0
    decode_steps: int = 0
    kv_pages_hwm: int = 0
    kv_admit_blocked: int = 0
    #: mid-decode evictions / checkpoint re-admissions / KV pages that
    #: crossed an evict->restore cycle during the replay (all zero when
    #: the replay runs without preemption injection)
    preemptions: int = 0
    restores: int = 0
    pages_migrated: int = 0
    #: prefix-sharing counters (zero unless the replay ran with
    #: ``prefix_sharing=True``): prompts that opened on cached pages,
    #: and prefill pages those hits avoided allocating
    prefix_hits: int = 0
    prefix_pages_saved: int = 0


def _prompt_for(rng: np.random.Generator, r: FleetRequest,
                vocab: int) -> np.ndarray:
    """Deterministic prompt ids for one fleet request.

    A request without prefix structure draws its whole prompt from the
    caller's shared ``rng`` stream -- byte-identical to the pre-prefix
    replays, so every pinned token stream survives.  A request with a
    ``prefix_id`` OPENS with its family's shared tokens (their own rng,
    keyed by family id, so all members agree regardless of arrival
    order) and draws only the unique tail from the shared stream.
    """
    if r.prefix_id is None:
        return rng.integers(0, vocab, r.prompt_len, dtype=np.int32)
    head_len = min(r.prefix_len, r.prompt_len - 1)
    head = np.random.default_rng((7919, r.prefix_id)).integers(
        0, vocab, head_len, dtype=np.int32)
    tail = rng.integers(0, vocab, r.prompt_len - head_len, dtype=np.int32)
    return np.concatenate([head, tail])


def trace_requests(trace: Sequence[FleetRequest], vocab: int,
                   seed: int) -> list:
    """Engine :class:`Request` list for a fleet trace, in the arrival
    order every replay in this module admits them."""
    rng = np.random.default_rng(seed)
    return [Request(uid=r.uid, prompt=_prompt_for(rng, r, vocab),
                    max_new_tokens=r.gen_len)
            for r in sorted(trace, key=lambda r: (r.arrival_s, r.uid))]


def run_trace_on_engine(trace: Sequence[FleetRequest], cfg: ModelConfig,
                        params, n_lanes: int = 2, max_len: int = 64,
                        vocab_size: Optional[int] = None,
                        seed: int = 0,
                        dispatch_n: int = 8,
                        paged: bool = False, page_size: int = 16,
                        n_pages: Optional[int] = None,
                        temperature: float = 0.0,
                        preempt_every: Optional[int] = None,
                        prefix_sharing: bool = False,
                        tracer: Optional[SpanTracer] = None,
                        registry: Optional[MetricsRegistry] = None
                        ) -> ExecutionResult:
    """Serve ``trace`` through the real continuous batcher.

    Prompt token ids are derived deterministically from the request uid,
    so the replay itself is seed-reproducible.  ``dispatch_n`` is the
    engine's multi-token decode granularity (tokens per host dispatch);
    the replayed token counts are dispatch-size invariant.  ``paged``
    replays through the page-pool cache (token counts are layout
    invariant; the page stats are what changes).

    ``preempt_every`` (paged only) injects evict-and-replay churn: at
    every k-th dispatch boundary the live lane with the LONGEST context
    is evicted into a :class:`~repro.serving.engine.LaneCheckpoint` and
    held until the pool re-admits it -- the execution-backed analogue of
    a fleet migration, minus the wire.  Token counts (and the token
    streams themselves, see ``validate_preemption_exactness``) must be
    preemption invariant.
    """
    vocab = vocab_size or cfg.vocab_size
    reqs = trace_requests(trace, vocab, seed)
    engine = ServeEngine(cfg, params, n_lanes=n_lanes, max_len=max_len,
                         dispatch_n=dispatch_n, paged=paged,
                         page_size=page_size, n_pages=n_pages,
                         temperature=temperature,
                         prefix_sharing=prefix_sharing, tracer=tracer,
                         registry=registry)
    if preempt_every is None:
        engine.run(reqs)
    else:
        assert paged, "preemption replay needs the paged engine"
        _run_with_preemption(engine, reqs, preempt_every)
    if paged:
        if engine.prefix_cache is not None:
            engine.prefix_cache.flush()      # release the cache's refs
        engine.pool.check()
        assert engine.pool.n_in_use == 0, "replay leaked KV pages"
    gen_by_uid = {r.uid: len(r.generated) for r in reqs}
    return ExecutionResult(
        prompt_tokens=sum(len(r.prompt) for r in reqs),
        gen_tokens=sum(gen_by_uid.values()),
        gen_by_uid=gen_by_uid,
        decode_dispatches=engine.stats["decode_dispatches"],
        decode_steps=engine.stats["decode_steps"],
        kv_pages_hwm=engine.stats["kv_pages_hwm"],
        kv_admit_blocked=engine.stats["kv_admit_blocked"],
        preemptions=engine.stats["preemptions"],
        restores=engine.stats["restores"],
        pages_migrated=engine.stats["pages_migrated"],
        prefix_hits=engine.stats["prefix_hits"],
        prefix_pages_saved=engine.stats["prefix_pages_saved"])


def _run_with_preemption(engine: ServeEngine, reqs, every: int) -> None:
    """Continuous batching with periodic evict-and-replay.

    Held checkpoints have strict re-admission priority over fresh
    requests (an evicted request must not starve behind the queue it
    was serving ahead of).  An empty engine always fits one checkpoint
    -- restore needs at most a full context, the pool's guaranteed
    minimum -- so the loop cannot wedge.
    """
    pending = list(reqs)
    held: deque = deque()
    blocks = 0
    while pending or held or engine.live_lanes():
        while held and engine.restore(held[0]):
            held.popleft()
        if not held:
            while pending and engine.free_lanes():
                if not engine.admit(pending[0]):
                    break
                pending.pop(0)
        if not engine.live_lanes():
            # lint: ok R004 harness deadlock guard, not a serving path
            raise RuntimeError("preemption replay made no progress")
        engine.decode_n()
        blocks += 1
        if blocks % every == 0:
            live = engine.live_lanes()
            if live:
                lane = max(live, key=lambda i: (engine.lane_context(i), -i))
                held.append(engine.evict(lane))


def validate_preemption_exactness(trace: Sequence[FleetRequest],
                                  cfg: ModelConfig, params,
                                  preempt_every: int = 2,
                                  **kw) -> Dict[str, object]:
    """Replay ``trace`` with and without evict-and-replay churn and diff
    the TOKEN STREAMS (not just counts): a migrated request must resume
    bit-identically.  Returns the diff plus the preemption counters.
    With ``prefix_sharing=True`` both replays share cached prefixes, so
    the diff also pins evict/restore of prefix-hit lanes."""
    kw = dict(kw, paged=True)
    vocab = kw.pop("vocab_size", None) or cfg.vocab_size

    def streams(preempt):
        reqs = trace_requests(trace, vocab, kw.get("seed", 0))
        engine = ServeEngine(cfg, params,
                             n_lanes=kw.get("n_lanes", 2),
                             max_len=kw.get("max_len", 64),
                             dispatch_n=kw.get("dispatch_n", 8),
                             paged=True,
                             page_size=kw.get("page_size", 16),
                             n_pages=kw.get("n_pages"),
                             temperature=kw.get("temperature", 0.0),
                             prefix_sharing=kw.get("prefix_sharing",
                                                   False))
        if preempt:
            _run_with_preemption(engine, reqs, preempt_every)
        else:
            engine.run(reqs)
        if engine.prefix_cache is not None:
            engine.prefix_cache.flush()
        engine.pool.check()
        return {r.uid: tuple(r.generated) for r in reqs}, engine.stats

    base, _ = streams(False)
    moved, stats = streams(True)
    mismatches = {uid: (base[uid], moved[uid]) for uid in base
                  if base[uid] != moved[uid]}
    verdict = {
        "resume_exact": not mismatches,
        "mismatches": mismatches,
        "preemptions": stats["preemptions"],
        "restores": stats["restores"],
        "pages_migrated": stats["pages_migrated"],
    }
    # auditable record: the replay session keeps evidence the check ran
    obs_events.emit("validate.preemption_exactness",
                    resume_exact=verdict["resume_exact"],
                    n_requests=len(base),
                    n_mismatches=len(mismatches),
                    preemptions=verdict["preemptions"],
                    restores=verdict["restores"],
                    pages_migrated=verdict["pages_migrated"])
    return verdict


@dataclasses.dataclass(frozen=True)
class FaultReplayResult:
    """Accounting from a crash-and-recover replay on the REAL engine.

    ``checkpointed_uids`` resumed from a :class:`LaneCheckpoint` taken at
    the last checkpoint tick before the crash (tokens generated since the
    tick were rolled back and re-decoded); ``replayed_uids`` had no
    checkpoint yet and restarted from the prompt.  ``retry_attempts``
    counts both recovery admissions and transient dispatch retries, and
    lands in the surviving engine's ``engine.retry.attempts`` counter.
    """

    gen_by_uid: Dict[int, int]
    streams: Dict[int, Tuple[int, ...]]
    crashes: int
    checkpointed_uids: Tuple[int, ...]
    replayed_uids: Tuple[int, ...]
    retry_attempts: int
    transients: int
    checkpoints: int
    #: flight-recorder dumps written during the replay (one per crash
    #: when a ``flight_dir`` was given), in the order they were written
    flight_dumps: Tuple[str, ...] = ()


def run_trace_with_faults(trace: Sequence[FleetRequest],
                          cfg: ModelConfig, params,
                          plan: Optional[FaultPlan] = None,
                          crash_at_dispatch: Optional[int] = None,
                          checkpoint_every: Optional[int] = 4,
                          transient_dispatches: Sequence[int] = (),
                          n_lanes: int = 2, max_len: int = 64,
                          vocab_size: Optional[int] = None, seed: int = 0,
                          dispatch_n: int = 8, page_size: int = 16,
                          n_pages: Optional[int] = None,
                          temperature: float = 0.0,
                          prefix_sharing: bool = False,
                          tracer: Optional[SpanTracer] = None,
                          registry: Optional[MetricsRegistry] = None,
                          flight_dir: Optional[str] = None,
                          slo=None) -> FaultReplayResult:
    """Replay ``trace`` through the real paged engine while injecting a
    node crash (plus optional transient dispatch errors) and recovering.

    "Time" here is the decode dispatch index (a :class:`FaultPlan` with
    ``at_dispatch`` events drives it; or pass the knobs directly).  Every
    ``checkpoint_every`` dispatches each live lane is checkpointed -- an
    evict/restore round trip, so the snapshot is exactly what a fleet
    would hold host-side.  At ``crash_at_dispatch`` the engine ("node0")
    dies with its lanes; a fresh engine ("node1") takes over:
    checkpointed lanes re-enter from their snapshot (their request's
    stream rolled back to the tick), the rest replay from the prompt.
    Greedy streams must come out bit-identical to an undisturbed run
    (``validate_recovery_exactness`` pins this).

    Observability: pass ONE shared ``tracer``/``registry`` and both
    engines emit onto it, so ``repro.obs.requests`` reconstructs
    gap-free per-request timelines ACROSS the migration hop.  With a
    ``flight_dir``, each engine gets a flight recorder tapped into the
    tracer and the dying engine's ring is dumped to
    ``<flight_dir>/flight_<node>.jsonl`` at the crash (paths land in
    ``FaultReplayResult.flight_dumps``).  An ``slo`` controller is
    threaded into the engines and stepped at every dispatch drain.
    """
    if plan is not None:
        if crash_at_dispatch is None:
            crash_at_dispatch = plan.crash_dispatch()
        transient_dispatches = plan.transient_dispatches()
    vocab = vocab_size or cfg.vocab_size
    reqs = trace_requests(trace, vocab, seed)
    final_req: Dict[int, Request] = {r.uid: r for r in reqs}

    def mk_engine(node: str) -> ServeEngine:
        flight = (FlightRecorder(name=node)
                  if flight_dir is not None else None)
        return ServeEngine(cfg, params, n_lanes=n_lanes, max_len=max_len,
                           dispatch_n=dispatch_n, paged=True,
                           page_size=page_size, n_pages=n_pages,
                           temperature=temperature,
                           prefix_sharing=prefix_sharing, name=node,
                           tracer=tracer, registry=registry,
                           flight=flight, slo=slo)

    engine = mk_engine("node0")
    flight_dumps: list = []
    pending = list(reqs)
    held: deque = deque()                  # checkpoints awaiting restore
    #: uid -> (checkpoint, generated-length at the tick); the request
    #: object inside keeps accumulating, so the length pins the rollback
    snapshots: Dict[int, Tuple[LaneCheckpoint, int]] = {}
    dispatch = 0
    crashes = 0
    checkpoints = 0
    transients = 0
    retry_attempts = 0
    transient_set = set(transient_dispatches)
    checkpointed: list = []
    replayed: list = []

    while pending or held or engine.live_lanes():
        while held and engine.restore(held[0]):
            held.popleft()
        if not held:
            while pending and engine.free_lanes():
                if not engine.admit(pending[0]):
                    break
                pending.pop(0)
        if not engine.live_lanes():
            # lint: ok R004 harness deadlock guard, not a serving path
            raise RuntimeError("fault replay made no progress")
        if dispatch in transient_set:
            # transient dispatch error: the dispatch fails and is
            # re-issued -- one retry attempt, no token-stream effect
            retry_attempts += 1
            engine.stats["retry_attempts"] += 1
            transients += 1
        engine.decode_n()
        dispatch += 1
        if checkpoint_every and dispatch % checkpoint_every == 0:
            for lane in list(engine.live_lanes()):
                ckpt = engine.evict(lane)
                snapshots[ckpt.uid] = (ckpt, len(ckpt.req.generated))
                assert engine.restore(ckpt), \
                    "checkpoint round trip must fit the pages it freed"
            checkpoints += 1
        if crash_at_dispatch is not None and dispatch == crash_at_dispatch:
            # node0 dies fail-stop: its lanes (and their pages) are gone
            crashes += 1
            casualties = [engine.lane_req[i] for i in engine.live_lanes()]
            if engine.flight is not None:
                # black box first: dump the dying engine's ring at the
                # faulting op, before the survivor takes over
                flight_dumps.append(engine.flight.dump(
                    os.path.join(flight_dir,
                                 f"flight_{engine.name}.jsonl"),
                    reason=f"crash at dispatch {dispatch}",
                    registry=engine.registry, dispatch=dispatch))
            engine = mk_engine("node1")
            for req in casualties:
                snap = snapshots.get(req.uid)
                if snap is not None:
                    ckpt, glen = snap
                    resumed = Request(uid=req.uid, prompt=req.prompt,
                                      max_new_tokens=req.max_new_tokens,
                                      generated=list(req.generated[:glen]),
                                      model_id=req.model_id,
                                      priority=req.priority)
                    final_req[req.uid] = resumed
                    held.append(dataclasses.replace(ckpt, req=resumed))
                    checkpointed.append(req.uid)
                else:
                    req.generated.clear()    # no checkpoint yet: from prompt
                    pending.insert(0, req)
                    replayed.append(req.uid)
                retry_attempts += 1
            # node0's counter died with it; the surviving engine carries
            # the replay-level total under engine.retry.attempts
            engine.stats["retry_attempts"] = retry_attempts

    if engine.prefix_cache is not None:
        engine.prefix_cache.flush()
    engine.pool.check()
    assert engine.pool.n_in_use == 0, "fault replay leaked KV pages"
    streams = {uid: tuple(r.generated) for uid, r in final_req.items()}
    return FaultReplayResult(
        gen_by_uid={uid: len(s) for uid, s in streams.items()},
        streams=streams, crashes=crashes,
        checkpointed_uids=tuple(checkpointed),
        replayed_uids=tuple(replayed),
        retry_attempts=engine.stats["retry_attempts"],
        transients=transients, checkpoints=checkpoints,
        flight_dumps=tuple(flight_dumps))


def validate_recovery_exactness(trace: Sequence[FleetRequest],
                                cfg: ModelConfig, params,
                                crash_at_dispatch: int = 6,
                                checkpoint_every: int = 3,
                                transient_dispatches: Sequence[int] = (2,),
                                **kw) -> Dict[str, object]:
    """The recovery oracle: crash a node mid-trace and diff the TOKEN
    STREAMS against an undisturbed run.

    Checkpointed lanes must resume BIT-IDENTICALLY (the sampling
    identity travels in the checkpoint); replayed-from-prompt lanes must
    also complete identically under greedy decoding (the stream is a
    pure function of the prompt).  Returns the verdict plus the recovery
    counters, and leaves an auditable ``repro.obs`` event behind.
    """
    kw = dict(kw, temperature=0.0)      # the bit-exactness contract is greedy
    base = run_trace_on_engine(trace, cfg, params, paged=True,
                               **{k: v for k, v in kw.items()
                                  if k != "temperature"})
    # stream-level baseline: rebuild the same requests and run clean
    vocab = kw.get("vocab_size") or cfg.vocab_size
    clean = trace_requests(trace, vocab, kw.get("seed", 0))
    eng = ServeEngine(cfg, params, n_lanes=kw.get("n_lanes", 2),
                      max_len=kw.get("max_len", 64),
                      dispatch_n=kw.get("dispatch_n", 8), paged=True,
                      page_size=kw.get("page_size", 16),
                      n_pages=kw.get("n_pages"), temperature=0.0,
                      prefix_sharing=kw.get("prefix_sharing", False))
    eng.run(clean)
    base_streams = {r.uid: tuple(r.generated) for r in clean}

    faulted = run_trace_with_faults(
        trace, cfg, params, crash_at_dispatch=crash_at_dispatch,
        checkpoint_every=checkpoint_every,
        transient_dispatches=transient_dispatches, **kw)
    ckpt_mismatch = {uid: (base_streams[uid], faulted.streams[uid])
                     for uid in faulted.checkpointed_uids
                     if base_streams[uid] != faulted.streams[uid]}
    replay_mismatch = {uid: (base_streams[uid], faulted.streams[uid])
                       for uid in faulted.replayed_uids
                       if base_streams[uid] != faulted.streams[uid]}
    verdict = {
        "resume_exact": not ckpt_mismatch,
        "replay_exact": not replay_mismatch,
        "counts_match": faulted.gen_by_uid == base.gen_by_uid,
        "crashes": faulted.crashes,
        "recovered_lanes": len(faulted.checkpointed_uids),
        "replayed_from_prompt": len(faulted.replayed_uids),
        "retry_attempts": faulted.retry_attempts,
        "checkpoints": faulted.checkpoints,
        "mismatches": {**ckpt_mismatch, **replay_mismatch},
    }
    obs_events.emit("validate.recovery_exactness",
                    resume_exact=verdict["resume_exact"],
                    replay_exact=verdict["replay_exact"],
                    counts_match=verdict["counts_match"],
                    crashes=verdict["crashes"],
                    recovered_lanes=verdict["recovered_lanes"],
                    replayed_from_prompt=verdict["replayed_from_prompt"],
                    retry_attempts=verdict["retry_attempts"])
    return verdict


@dataclasses.dataclass(frozen=True)
class MultiModelExecutionResult:
    """Token + swap accounting from a multi-model engine replay."""

    prompt_tokens: int
    gen_tokens: int
    gen_by_uid: Dict[int, int]
    gen_by_model: Dict[str, int]
    model_swaps: int = 0
    swap_bytes: int = 0
    weight_evictions: int = 0
    kv_pages_shrunk: int = 0
    kv_pages_grown: int = 0


def dense_hbm_bytes(models: Dict[str, Tuple[ModelConfig, object]],
                    n_lanes: int, max_len: int, page_size: int) -> int:
    """Board budget holding EVERY model resident at its dense KV target
    (weights + ``n_lanes`` full contexts + scratch) -- the no-swap
    baseline; anything tighter exercises weight paging."""
    from repro.models.transformer import paged_capacity
    from repro.serving.modelpool import kv_page_bytes, params_nbytes

    total = 0
    for cfg, params in models.values():
        bt = (0 if cfg.attn_free
              else paged_capacity(max_len, cfg) // page_size)
        total += params_nbytes(params) + (
            n_lanes * bt + 1) * kv_page_bytes(cfg, page_size)
    return total


def _mm_requests(trace: Sequence[FleetRequest],
                 models: Dict[str, Tuple[ModelConfig, object]],
                 seed: int) -> list:
    """Deterministic multi-model request list from a fleet trace (ids
    derived from one rng stream, exactly like ``run_trace_on_engine``,
    clamped to each request's own model vocab)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for r in sorted(trace, key=lambda r: (r.arrival_s, r.uid)):
        assert r.model_id in models, f"trace uid={r.uid} names " \
            f"unregistered model {r.model_id!r}"
        vocab = models[r.model_id][0].vocab_size
        reqs.append(Request(uid=r.uid,
                            prompt=_prompt_for(rng, r, vocab),
                            max_new_tokens=r.gen_len,
                            model_id=r.model_id))
    return reqs


def run_multimodel_trace_on_engine(
        trace: Sequence[FleetRequest],
        models: Dict[str, Tuple[ModelConfig, object]],
        hbm_bytes: Optional[int] = None,
        n_lanes: int = 2, max_len: int = 64, seed: int = 0,
        dispatch_n: int = 8, page_size: int = 16,
        temperature: float = 0.0,
        prefix_sharing: bool = False) -> MultiModelExecutionResult:
    """Serve a multi-model ``trace`` through the REAL
    :class:`~repro.serving.modelpool.MultiModelServeEngine`.

    ``models`` maps model id -> (cfg, params).  ``hbm_bytes`` is the
    board budget weights and KV pages share; ``None`` sizes it to hold
    every model at its dense KV target (no swap pressure), which is the
    accounting baseline -- pass something tighter to exercise weight
    paging.  Token counts must be budget invariant (streams depend only
    on per-model admission order); the swap counters are what changes.
    """
    if hbm_bytes is None:
        hbm_bytes = dense_hbm_bytes(models, n_lanes=n_lanes,
                                    max_len=max_len, page_size=page_size)
    pool = ModelPool(hbm_bytes, page_size=page_size)
    for mid in sorted(models):
        pool.register(mid, models[mid][0], models[mid][1])
    engine = MultiModelServeEngine(pool, n_lanes=n_lanes, max_len=max_len,
                                   temperature=temperature, rng_seed=seed,
                                   dispatch_n=dispatch_n,
                                   prefix_sharing=prefix_sharing)
    reqs = _mm_requests(trace, models, seed)
    engine.run(reqs)
    for eng in engine.engines.values():
        if eng.prefix_cache is not None:
            eng.prefix_cache.flush()
        eng.pool.check()
        assert eng.pool.n_in_use == 0, "replay leaked KV pages"
    gen_by_uid = {r.uid: len(r.generated) for r in reqs}
    gen_by_model: Dict[str, int] = {}
    for r in reqs:
        gen_by_model[r.model_id] = (gen_by_model.get(r.model_id, 0)
                                    + len(r.generated))
    return MultiModelExecutionResult(
        prompt_tokens=sum(len(r.prompt) for r in reqs),
        gen_tokens=sum(gen_by_uid.values()),
        gen_by_uid=gen_by_uid, gen_by_model=gen_by_model,
        model_swaps=engine.stats["model_swaps"],
        swap_bytes=engine.stats["swap_bytes"],
        weight_evictions=engine.stats["weight_evictions"],
        kv_pages_shrunk=engine.stats["kv_pages_shrunk"],
        kv_pages_grown=engine.stats["kv_pages_grown"])


def validate_multimodel_exactness(
        trace: Sequence[FleetRequest],
        models: Dict[str, Tuple[ModelConfig, object]],
        hbm_bytes: Optional[int] = None, **kw) -> Dict[str, object]:
    """Replay a multi-model trace and diff each model's TOKEN STREAMS
    against the same requests served ALONE by a single-model
    ``ServeEngine`` with the same config/seed/temperature -- the
    exactness contract of the multi-model engine.  Returns the diff
    plus the swap counters."""
    seed = kw.get("seed", 0)
    engine_kw = dict(n_lanes=kw.get("n_lanes", 2),
                     max_len=kw.get("max_len", 64),
                     dispatch_n=kw.get("dispatch_n", 8),
                     temperature=kw.get("temperature", 0.0))
    page_size = kw.get("page_size", 16)

    reqs = _mm_requests(trace, models, seed)
    if hbm_bytes is None:
        hbm_bytes = dense_hbm_bytes(models, n_lanes=engine_kw["n_lanes"],
                                    max_len=engine_kw["max_len"],
                                    page_size=page_size)
    pool = ModelPool(hbm_bytes, page_size=page_size)
    for mid in sorted(models):
        pool.register(mid, models[mid][0], models[mid][1])
    mm = MultiModelServeEngine(pool, rng_seed=seed, **engine_kw)
    mm.run(reqs)
    moved = {r.uid: tuple(r.generated) for r in reqs}

    mismatches = {}
    for mid in sorted(models):
        cfg, params = models[mid]
        solo = [Request(uid=r.uid, prompt=r.prompt.copy(),
                        max_new_tokens=r.max_new_tokens)
                for r in reqs if r.model_id == mid]
        ref = ServeEngine(cfg, params, paged=True, page_size=page_size,
                          rng_seed=seed, **engine_kw)
        ref.run(solo)
        for r in solo:
            if tuple(r.generated) != moved[r.uid]:
                mismatches[r.uid] = (tuple(r.generated), moved[r.uid])
    verdict = {
        "exact": not mismatches,
        "mismatches": mismatches,
        "model_swaps": mm.stats["model_swaps"],
        "swap_bytes": mm.stats["swap_bytes"],
        "weight_evictions": mm.stats["weight_evictions"],
        "gen_by_model": {mid: sum(len(r.generated) for r in reqs
                                  if r.model_id == mid)
                         for mid in sorted(models)},
    }
    # auditable record: the replay session keeps evidence the check ran
    obs_events.emit("validate.multimodel_exactness",
                    exact=verdict["exact"],
                    n_requests=len(reqs),
                    n_mismatches=len(mismatches),
                    model_swaps=verdict["model_swaps"],
                    weight_evictions=verdict["weight_evictions"])
    return verdict


def simulated_token_accounting(sim: FleetSim,
                               report: FleetReport) -> Dict[int, int]:
    """Per-uid generated-token counts the simulator claims to have served."""
    return {rec.req.uid: (rec.req.gen_len if rec.done else 0)
            for rec in sim.records}


def validate_token_accounting(sim: FleetSim, report: FleetReport,
                              cfg: ModelConfig, params,
                              n_lanes: int = 2,
                              max_len: int = 64) -> Dict[str, object]:
    """Replay the sim's trace on the engine and diff token counts."""
    sim_counts = simulated_token_accounting(sim, report)
    exe = run_trace_on_engine([rec.req for rec in sim.records], cfg,
                              params, n_lanes=n_lanes, max_len=max_len)
    mismatches = {uid: (sim_counts.get(uid, 0), got)
                  for uid, got in exe.gen_by_uid.items()
                  if sim_counts.get(uid, 0) != got}
    return {
        "sim_prompt_tokens": sum(rec.req.prompt_len
                                 for rec in sim.records if rec.done),
        "sim_gen_tokens": sum(sim_counts.values()),
        "engine_prompt_tokens": exe.prompt_tokens,
        "engine_gen_tokens": exe.gen_tokens,
        "mismatches": mismatches,
        "match": not mismatches,
    }
