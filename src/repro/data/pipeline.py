"""Deterministic synthetic token pipeline, host-sharded, prefetching.

Production posture: each host process generates only its shard of the
global batch (host-sharded loading), determinism comes from a counter-
based PRNG (step, host) -> identical restart behavior after preemption,
and a background thread keeps ``prefetch`` batches ready so the input
pipeline never blocks the TPU step.

The synthetic stream is a Zipf-ish unigram mixture with short-range
repetition structure, so cross-entropy decreases meaningfully during the
example runs (a pure-uniform stream would pin loss at log V).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    zipf_a: float = 1.2
    repeat_p: float = 0.35     # probability of copying a recent token
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(
        key=cfg.seed, counter=[0, 0, cfg.host_id, step]))


def synth_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """One host-shard batch for ``step`` (pure function of (cfg, step))."""
    rng = _batch_rng(cfg, step)
    b, s = cfg.host_batch, cfg.seq_len
    # zipf unigrams clipped to vocab
    base = rng.zipf(cfg.zipf_a, size=(b, s + 1)).astype(np.int64)
    base = (base - 1) % cfg.vocab_size
    # short-range repetition: with prob p, copy the token 1..8 back
    rep = rng.random((b, s + 1)) < cfg.repeat_p
    lag = rng.integers(1, 9, size=(b, s + 1))
    idx = np.maximum(np.arange(s + 1)[None, :] - lag, 0)
    seq = np.where(rep, np.take_along_axis(base, idx, axis=1), base)
    return {"tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32)}


class DataLoader:
    """Prefetching iterator over synth_batch(step)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
