"""Per-path device capability profiles (paper contribution C1).

The central lesson of the CMP 170HX study is that a device is not a single
FLOP/s number: every (precision x instruction-path) pair has its own
throughput ceiling, and a SKU-level throttle may hit one path (FMA) while
leaving others (separate mul/add, int8 dot, HBM) untouched.

A :class:`DeviceProfile` is the framework's source of truth for those
ceilings.  It drives

* the compute-path policy (``core.compute_path``) -- which kernel variant
  to select on a given device,
* the analytic performance model (``core.perf_model``) -- predicted
  prefill/decode/train throughput,
* the energy / cost model (``core.energy``),
* the roofline analysis (``core.roofline``) -- peak terms per chip.

Numbers for the CMP 170HX come from the paper (Tables 2-1..2-4, Graphs
3-1..3-5, EX.1/EX.2); A100 numbers from the NVIDIA datasheet the paper
cites; TPU v5e numbers from the task's hardware constants.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Mapping, Optional, Tuple


class Path(enum.Enum):
    """An instruction/issue path on the device.

    ``FMA``     fused multiply-add pipeline (CUDA default codegen; the MXU
                systolic path on TPU).
    ``MUL_ADD`` decomposed multiply + add (``-fmad=false`` on CUDA; the VPU
                vector path on TPU).
    ``DOT_I8``  integer-8 dot-product path (dp4a on GPU; int8 MXU on TPU).
    ``TENSOR``  matrix-engine path with its own ratios (TensorCore / MXU).
    """

    FMA = "fma"
    MUL_ADD = "mul_add"
    DOT_I8 = "dot_i8"
    TENSOR = "tensor"


# (precision, path) -> TFLOP/s (or TOP/s for integer precisions).
PathTable = Mapping[Tuple[str, Path], float]


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Capability table of one accelerator SKU.

    Attributes:
      name: SKU name.
      peak: per-(precision, path) achievable throughput in T(FL)OP/s.
        *Achievable* means "what a well-written kernel on the right path
        reaches", i.e. the paper's measured values, not marketing peaks.
      theoretical: the datasheet/derived theoretical ceilings per
        precision, used to report "fraction of theoretical" like the paper.
      hbm_bw_gbps: achievable HBM bandwidth, GB/s.
      hbm_capacity_gib: HBM capacity per chip/board, GiB.
      interconnect_gbps: per-direction device interconnect bandwidth, GB/s
        (PCIe for the mining card, per-link ICI for TPU).
      interconnect_links: number of interconnect links (ICI torus links).
      tdp_watts: board TDP.
      asp_usd: estimated average selling price (paper Table 1-1), for the
        cost model. ``None`` if not applicable.
      notes: provenance of the numbers.
    """

    name: str
    peak: PathTable
    theoretical: Mapping[str, float]
    hbm_bw_gbps: float
    hbm_capacity_gib: float
    interconnect_gbps: float
    interconnect_links: int
    tdp_watts: float
    asp_usd: Optional[float] = None
    notes: str = ""
    # Which path a *standard compiled build* routes each precision through
    # (the paper's default vs -fmad=false distinction).  Hand-written
    # kernels may use any path in ``peak``; framework codegen uses these.
    build_paths: Mapping[str, "Path"] = dataclasses.field(default_factory=dict)
    # Effective throughput of vendor BLAS GEMMs (TF), which are pre-built
    # binaries NOT affected by the -fmad recompile (paper: f32/f16 ggufs
    # showed no noFMA gains because cuBLAS does the GEMM).
    blas_tflops: Mapping[str, float] = dataclasses.field(default_factory=dict)
    # Achievable fraction of hbm_bw_gbps in a GEMV-style streaming kernel
    # (decode).  The mining card's PCIe-x4 host link + kernel overheads
    # cost it more than the A100.
    gemv_efficiency: float = 0.80

    # ------------------------------------------------------------------
    def throughput(self, precision: str, path: Path) -> float:
        """Achievable T(FL)OP/s of ``precision`` via ``path`` (0 if absent)."""
        return float(self.peak.get((precision, path), 0.0))

    def best_path(self, precision: str) -> Tuple[Path, float]:
        """The fastest path for ``precision`` and its throughput."""
        best, best_tf = None, 0.0
        for (prec, path), tf in self.peak.items():
            if prec == precision and tf >= best_tf:
                best, best_tf = path, tf
        if best is None:
            raise KeyError(f"{self.name}: no path for precision {precision!r}")
        return best, best_tf

    def fraction_of_theoretical(self, precision: str, path: Path) -> float:
        theo = self.theoretical.get(precision)
        if not theo:
            return 0.0
        return self.throughput(precision, path) / theo

    def total_interconnect_gbps(self) -> float:
        return self.interconnect_gbps * self.interconnect_links


# ----------------------------------------------------------------------
# Profile registry
# ----------------------------------------------------------------------

def _cmp170hx_peaks(fma_disabled: bool) -> Dict[Tuple[str, Path], float]:
    """CMP 170HX measured capability (paper Graphs 3-1..3-4, EX.1).

    Default build: FP32 via FMA runs at ~1/32 of the 12.63 TFLOPS
    theoretical -> 0.39 TFLOPS.  ``-fmad=false`` reroutes onto the
    mul+add path -> ~6.2 TFLOPS (1/2 of theoretical: no fusion means two
    instructions per multiply-accumulate).  FP16 (non-TensorCore) is
    unthrottled either way (~48 TFLOPS, RTX-4080-class per the paper);
    frameworks that lower FP16 through the FMA path (PyTorch, GPU-Burn)
    see only ~6.3.  FP64 is ~1/64 of its 6.317 theoretical and *halves
    again* without FMA.  INT32/INT8 are essentially unthrottled.
    """
    if not fma_disabled:
        return {
            ("f32", Path.FMA): 0.39,
            ("f32", Path.MUL_ADD): 6.2,     # reachable per-kernel even in default builds
            ("f16", Path.FMA): 6.3,          # what PyTorch/GPU-Burn observe
            ("f16", Path.MUL_ADD): 48.7,     # OpenCL half2 path, ~RTX 4080 class
            ("f64", Path.FMA): 0.197,        # ~1/32 of 6.317
            ("i32", Path.FMA): 9.8,          # TIOPs, "not significantly restricted"
            ("i8", Path.DOT_I8): 25.1,       # dp4a (EX.1: 25.13 / 21.77)
        }
    return {
        ("f32", Path.MUL_ADD): 6.2,          # the paper's headline recovery
        ("f16", Path.FMA): 6.3,              # framework f16 path: unchanged
        ("f16", Path.MUL_ADD): 48.7,         # unchanged by FMA status
        ("f64", Path.MUL_ADD): 0.10,         # 1/128: halves again
        ("i32", Path.MUL_ADD): 9.8,
        ("i8", Path.DOT_I8): 21.6,           # EX.1 noFMA bar
    }


CMP_170HX = DeviceProfile(
    name="cmp-170hx",
    peak=_cmp170hx_peaks(fma_disabled=False),
    theoretical={"f32": 12.63, "f16": 50.53, "f64": 6.317, "i32": 12.63, "i8": 50.5},
    hbm_bw_gbps=1290.0,              # ~86% of 1493 GB/s theoretical, streaming
    hbm_capacity_gib=8.0,
    interconnect_gbps=1.0,           # PCIe 1.1 x4 ~= 1 GB/s/dir (EX.2)
    interconnect_links=1,
    tdp_watts=250.0,
    asp_usd=4500.0,
    notes="paper Tables 2-1..2-4, Graphs 3-1..3-5, EX.1/EX.2",
    gemv_efficiency=0.70,           # PCIe-x4 host link + GEMV overheads
    build_paths={"f32": Path.FMA, "f16": Path.FMA, "f64": Path.FMA,
                 "i32": Path.FMA, "i8": Path.DOT_I8},
    # cuBLAS pre-built binaries: SGEMM lands ~2.8 TF on the throttled die
    # (instruction mix partially escapes the FMA throttle), HGEMM ~6.3 TF
    # (no TensorCores usable).  Both are -fmad-insensitive.
    blas_tflops={"f32": 2.8, "f16": 6.3},
)

CMP_170HX_NOFMA = dataclasses.replace(
    CMP_170HX,
    name="cmp-170hx-nofma",
    peak=_cmp170hx_peaks(fma_disabled=True),
    notes="paper: -fmad=false build (niconiconi workaround)",
    gemv_efficiency=0.70,
    build_paths={"f32": Path.MUL_ADD, "f16": Path.FMA,
                 "f64": Path.MUL_ADD, "i32": Path.MUL_ADD,
                 "i8": Path.DOT_I8},
    blas_tflops={"f32": 2.8, "f16": 6.3},   # vendor BLAS unaffected
)

A100_40G = DeviceProfile(
    name="a100-40g",
    peak={
        ("f32", Path.FMA): 19.5,
        ("f32", Path.MUL_ADD): 9.75,
        ("f16", Path.FMA): 78.0,
        ("f16", Path.TENSOR): 312.0,
        ("f64", Path.FMA): 9.7,
        ("i32", Path.FMA): 19.5,
        ("i8", Path.DOT_I8): 624.0,
    },
    theoretical={"f32": 19.5, "f16": 312.0, "f64": 9.7, "i32": 19.5, "i8": 624.0},
    hbm_bw_gbps=1555.0,
    hbm_capacity_gib=40.0,
    interconnect_gbps=64.0,          # PCIe 4 x16
    interconnect_links=1,
    tdp_watts=250.0,
    asp_usd=10000.0,
    notes="NVIDIA A100 40GB PCIe datasheet (paper refs [21][22])",
    gemv_efficiency=0.82,
    build_paths={"f32": Path.FMA, "f16": Path.TENSOR, "f64": Path.FMA,
                 "i32": Path.FMA, "i8": Path.DOT_I8},
    blas_tflops={"f32": 16.5, "f16": 53.0},  # ~17% of TC peak: llama.cpp-class
)

# The reproduction target. bf16 is the native matrix precision; the VPU
# (mul_add path) runs ~8 ops/cycle/lane -> roughly peak/16 of the MXU for
# f32 elementwise chains.  int8 runs at 2x bf16 on v5e MXU (394 TOPS).
TPU_V5E = DeviceProfile(
    name="tpu-v5e",
    peak={
        ("bf16", Path.TENSOR): 197.0,
        ("bf16", Path.FMA): 197.0,
        ("f32", Path.TENSOR): 98.5,
        ("f32", Path.FMA): 98.5,
        ("f32", Path.MUL_ADD): 12.3,   # VPU vector path
        ("bf16", Path.MUL_ADD): 12.3,
        ("i8", Path.DOT_I8): 394.0,
    },
    theoretical={"bf16": 197.0, "f32": 98.5, "i8": 394.0},
    hbm_bw_gbps=819.0,
    hbm_capacity_gib=16.0,
    interconnect_gbps=50.0,          # per ICI link
    interconnect_links=4,            # 2D torus
    tdp_watts=170.0,
    asp_usd=None,
    notes="task hardware constants: 197 TFLOP/s bf16, 819 GB/s, 50 GB/s/link",
    build_paths={"bf16": Path.TENSOR, "f16": Path.TENSOR,
                 "f32": Path.TENSOR, "i8": Path.DOT_I8},
    blas_tflops={"f32": 78.0, "f16": 160.0, "bf16": 160.0},  # XLA GEMM ~0.8 MXU
)

PROFILES: Dict[str, DeviceProfile] = {
    p.name: p
    for p in (CMP_170HX, CMP_170HX_NOFMA, A100_40G, TPU_V5E)
}


def get_profile(name: str) -> DeviceProfile:
    try:
        return PROFILES[name]
    except KeyError as e:
        raise KeyError(
            f"unknown device profile {name!r}; known: {sorted(PROFILES)}") from e


def register_profile(profile: DeviceProfile) -> None:
    """Register a custom SKU (e.g. a hypothetical degraded TPU)."""
    PROFILES[profile.name] = profile
