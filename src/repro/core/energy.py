"""Energy-efficiency and fleet-economics model (paper C5, §4.4 + Table 1-x).

The paper's bottom line is an *economics* argument: hundreds of thousands
of mining boards (Table 1-2 estimates ~460k-640k units) with retained HBM
bandwidth are viable for bandwidth-bound inference if tokens/s/W and
tokens/s/$ are competitive.  This module turns
:class:`~repro.core.perf_model.InferencePerfModel` phase estimates into
those two figures and reproduces the paper's sales-volume estimation
methodology (Appendix Ex.1).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional

from repro.core.device_profile import DeviceProfile, get_profile
from repro.core.perf_model import InferencePerfModel, LLMSpec, QWEN25_1P5B


def capex_usd_per_hour(profile: DeviceProfile,
                       amortization_years: float = 3.0) -> float:
    """Board price amortized to $/hour (0 when no ASP is known)."""
    if not profile.asp_usd:
        return 0.0
    return profile.asp_usd / (amortization_years * 365 * 24)


def energy_usd_per_hour(watts: float,
                        power_usd_per_kwh: float = 0.10) -> float:
    return watts / 1000.0 * power_usd_per_kwh


@dataclasses.dataclass(frozen=True)
class EfficiencyReport:
    profile: str
    fmt: str
    phase: str
    tokens_per_s: float
    watts: float
    tokens_per_joule: float
    tokens_per_usd_hour: Optional[float]  # incl. capex amortization
    usd_per_mtok: Optional[float]


def efficiency(profile: DeviceProfile, fmt: str, phase: str = "decode",
               spec: LLMSpec = QWEN25_1P5B,
               power_usd_per_kwh: float = 0.10,
               amortization_years: float = 3.0) -> EfficiencyReport:
    """tokens/W and $/Mtok for one (device, format, phase) cell."""
    model = InferencePerfModel(profile, spec)
    est = model.decode(fmt) if phase == "decode" else model.prefill(fmt)
    tokens_per_usd_hour = None
    usd_per_mtok = None
    if profile.asp_usd is not None:
        usd_hour = (capex_usd_per_hour(profile, amortization_years)
                    + energy_usd_per_hour(est.watts, power_usd_per_kwh))
        tokens_per_usd_hour = est.tokens_per_s * 3600.0 / usd_hour
        usd_per_mtok = 1e6 / tokens_per_usd_hour
    return EfficiencyReport(
        profile=profile.name, fmt=fmt, phase=phase,
        tokens_per_s=est.tokens_per_s, watts=est.watts,
        tokens_per_joule=est.tokens_per_joule,
        tokens_per_usd_hour=tokens_per_usd_hour,
        usd_per_mtok=usd_per_mtok)


def efficiency_grid(profile_names: Iterable[str], fmts: Iterable[str],
                    phase: str = "decode") -> List[EfficiencyReport]:
    return [efficiency(get_profile(p), f, phase)
            for p in profile_names for f in fmts]


def request_energy_joules(profile: DeviceProfile, prompt_len: int,
                          gen_len: int, fmt: str,
                          spec: LLMSpec = QWEN25_1P5B,
                          phase: str = "both") -> float:
    """Joules to serve one request solo (``phase``: prefill/decode/both).

    The fleet simulator (`repro.fleet.node`) charges each request the
    solo cost of each phase *on the board that runs it* -- in a
    disaggregated fleet prefill and decode hit different device
    profiles.  Batched sharing of the streamed weights shows up in the
    node-level power integration instead, so the per-request figure
    stays comparable across load levels.
    """
    model = InferencePerfModel(profile, spec)
    joules = 0.0
    if phase in ("both", "prefill"):
        pre = model.prefill(fmt, prompt_len)
        joules += prompt_len / pre.tokens_per_joule
    if phase in ("both", "decode"):
        dec = model.decode(fmt, prompt_len + gen_len // 2)
        joules += gen_len / dec.tokens_per_joule
    return joules


# ----------------------------------------------------------------------
# Paper Table 1-1 / 1-2: CMP fleet sizing (Appendix Ex.1 methodology)
# ----------------------------------------------------------------------

#: Table 1-1: model -> (ASP midpoint $, FP16 TFLOPS).
CMP_LINEUP: Mapping[str, tuple] = {
    "cmp-30hx": (750.0, 10.05),
    "cmp-40hx": (650.0, 15.21),
    "cmp-50hx": (800.0, 22.15),
    "cmp-90hx": (1550.0, 21.89),
    "cmp-170hx": (4500.0, 50.53),
}

#: FY2022 crypto-related revenue (paper §1.1.1), USD.
FY2022_CMP_REVENUE = 550e6

#: Table 1-2 revenue-mix scenarios (fractions per model, paper order).
SCENARIOS: Mapping[str, tuple] = {
    "A": (0.15, 0.25, 0.25, 0.20, 0.15),
    "B": (0.25, 0.30, 0.20, 0.15, 0.10),
    "C": (0.10, 0.15, 0.20, 0.25, 0.30),
}


def estimate_sales(scenario: str,
                   revenue: float = FY2022_CMP_REVENUE) -> Dict[str, float]:
    """Units per model under a revenue-mix scenario (paper Table 1-2)."""
    mix = SCENARIOS[scenario]
    units: Dict[str, float] = {}
    for (name, (asp, _)), frac in zip(CMP_LINEUP.items(), mix):
        units[name] = revenue * frac / asp
    units["total"] = sum(units.values())
    return units


def stranded_fp16_tflops(scenario: str) -> float:
    """Aggregate stranded FP16 compute across the estimated fleet."""
    units = estimate_sales(scenario)
    return sum(units[name] * tf for name, (_, tf) in CMP_LINEUP.items())
