"""HLO-text analysis: collective bytes, remat duplication, op census.

``compiled.cost_analysis()`` reports FLOPs and bytes but *not* collective
traffic, so the roofline's third term is derived here by parsing the
(stable)HLO text of a lowered/compiled program: we sum operand sizes of
every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all``
/ ``collective-permute`` op.

The parser is intentionally tolerant: it works on both
``lowered.as_text()`` (StableHLO) and ``compiled.as_text()`` (post-SPMD
HLO), and counts per-partition traffic (the dry-run compiles with
``num_partitions = mesh size``, so op shapes are already per-shard).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Mapping, Tuple

_DTYPE_BYTES: Mapping[str, int] = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

#: HLO / StableHLO spellings of the collectives we count.
_COLLECTIVE_KINDS: Tuple[str, ...] = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
    # stablehlo spellings
    "all_gather", "all_reduce", "reduce_scatter", "all_to_all",
    "collective_permute",
)

_CANON = {
    "all_gather": "all-gather", "all_reduce": "all-reduce",
    "reduce_scatter": "reduce-scatter", "all_to_all": "all-to-all",
    "collective_permute": "collective-permute",
}

# e.g. "f32[8,128]{1,0}" or "bf16[2,4,128]"
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")

# result shape of an HLO instruction line: "  %x = f32[8,128]{1,0} all-gather(...)"
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z][a-z0-9]*\[[^=]*?)\s+([a-z][a-z0-9\-_]*)\(")

# stablehlo: `"stablehlo.all_gather"(%arg) ... : (tensor<8x128xf32>) -> ...`
_MLIR_OP_RE = re.compile(
    r"stablehlo\.([a-z_]+)[\"']?\(.*?:\s*\(([^)]*)\)\s*->\s*(.*)")
_MLIR_TENSOR_RE = re.compile(r"tensor<([0-9x]*)x?([a-z][a-z0-9]*)>")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _mlir_tensor_bytes(text: str) -> int:
    total = 0
    for dims, dtype in _MLIR_TENSOR_RE.findall(text):
        n = 1
        for d in [x for x in dims.split("x") if x]:
            n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Per-kind collective byte counts for one compiled program."""

    bytes_by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)
    count_by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def add(self, kind: str, nbytes: int) -> None:
        kind = _CANON.get(kind, kind)
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) + nbytes
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1

    def summary(self) -> str:
        parts = [f"{k}: n={self.count_by_kind[k]} "
                 f"bytes={self.bytes_by_kind[k]:,}"
                 for k in sorted(self.bytes_by_kind)]
        return "; ".join(parts) if parts else "no collectives"


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective in an HLO/StableHLO dump.

    We use the *result* shape as the traffic proxy: for all-gather the
    result is the gathered (full) buffer, for reduce-scatter the operand
    would be; result-shape is the standard single-number approximation
    used by roofline dashboards and is within 2x of exact link traffic
    for every kind.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        # post-SPMD HLO text
        m = _INSTR_RE.search(ls)
        if m:
            opname = m.group(2)
            if any(opname.startswith(k) for k in _COLLECTIVE_KINDS):
                nbytes = sum(
                    _shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(m.group(1)))
                if opname.endswith("-start"):
                    opname = opname[:-len("-start")]
                stats.add(opname, nbytes)
                continue
        # stablehlo MLIR text
        m2 = _MLIR_OP_RE.search(ls)
        if m2 and m2.group(1) in _CANON:
            stats.add(m2.group(1), _mlir_tensor_bytes(m2.group(3)))
    return stats


# ----------------------------------------------------------------------
# secondary diagnostics used by the perf loop
# ----------------------------------------------------------------------

def op_census(hlo_text: str) -> Dict[str, int]:
    """Instruction-count histogram (spotting remat-duplicated fusions)."""
    census: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line.strip())
        if m:
            op = m.group(2)
            census[op] = census.get(op, 0) + 1
    return census


def count_convert_pairs(hlo_text: str) -> int:
    """Layout-churn smell: reshape/transpose/copy op count."""
    census = op_census(hlo_text)
    return sum(census.get(k, 0) for k in ("reshape", "transpose", "copy",
                                          "bitcast"))
