"""First-principles inference performance model (paper C3 + C4).

Reproduces the paper's llama-bench evaluation (Graphs 4-1/4-2/4-3)
analytically from the :class:`~repro.core.device_profile.DeviceProfile`
capability tables.  The model captures the *mechanisms* the paper
identifies rather than curve-fitting individual bars:

1. **F32/F16 models** run their GEMMs in the vendor BLAS (pre-built
   binary) -> insensitive to the ``-fmad=false`` recompile.  The paper's
   "f32/f16 models showed no performance gains" falls out of
   ``profile.blas_tflops``.
2. **Quantized models** run llama.cpp's own kernels: bulk MACs on a
   BLAS-class f16 path after dequant (prompt batches) while the
   per-sub-block **scale/min epilogue runs on the FP32 path** -- the path
   the SKU throttles.  Disabling FMA reroutes that epilogue
   (0.39 -> 6.2 TFLOPS), so the quantized formats speed up and the
   smallest sub-blocks (Q2_K: 16-wide, asymmetric) gain the most --
   the paper's 2.31x.
3. **Decode** adds the memory term: every active weight byte streams once
   per token.  On the default build the FP32 epilogue can exceed the
   memory time for low-bit formats (=> noFMA lifts Q6/Q4/Q2 decode but
   not F32/F16/Q8, as observed).
4. **Theoretical ceilings** follow the paper's own scaling formulas:
   prefill ~ A100 x (70/108 SMs), decode ~ A100 x (1493/1555 GB/s).

Calibration constants (framework efficiency, epilogue ops/sub-block) are
documented inline; EXPERIMENTS.md validates the resulting predictions
against every *stated* claim band of the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional

from repro.core.device_profile import (A100_40G, DeviceProfile, Path)
from repro.quant.formats import DENSE_BPW, FORMATS, bytes_per_weight


# ----------------------------------------------------------------------
# Workload description
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LLMSpec:
    """Minimal architecture facts the model needs (paper: Qwen2.5-1.5B)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    tied_embeddings: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def params_nonembed(self) -> float:
        L, d, f = self.n_layers, self.d_model, self.d_ff
        kv = self.n_kv_heads * self.head_dim
        attn = d * d + 2 * d * kv + d * d          # q, k, v, o projections
        mlp = 3 * d * f                            # SwiGLU gate/up/down
        return float(L * (attn + mlp))

    @property
    def params_embed(self) -> float:
        n = self.d_model * self.vocab_size
        return float(n if self.tied_embeddings else 2 * n)

    @property
    def params_total(self) -> float:
        return self.params_nonembed + self.params_embed

    @property
    def active_weights(self) -> float:
        """Weights touched per token: blocks + the LM head (tied: read once)."""
        return self.params_nonembed + self.d_model * self.vocab_size

    def kv_bytes_per_token(self, kv_bytes: float = 2.0) -> float:
        return 2.0 * self.n_layers * self.n_kv_heads * self.head_dim * kv_bytes


# Paper section 4.1: Qwen2.5-1.5B (28L, d1536, 12Q/2KV GQA, tied emb).
QWEN25_1P5B = LLMSpec(
    name="qwen2.5-1.5b", n_layers=28, d_model=1536, n_heads=12,
    n_kv_heads=2, d_ff=8960, vocab_size=151936, tied_embeddings=True)

# Sibling of the paper's model, one size down (24L, d896, 14Q/2KV GQA):
# the second tenant in the multi-model serving experiments -- small
# enough that two models' weights plausibly share an 8 GB board.
QWEN25_0P5B = LLMSpec(
    name="qwen2.5-0.5b", n_layers=24, d_model=896, n_heads=14,
    n_kv_heads=2, d_ff=4864, vocab_size=151936, tied_embeddings=True)


# ----------------------------------------------------------------------
# Format -> path decomposition
# ----------------------------------------------------------------------

#: FP32 scale/min ops per sub-block element in a quantized kernel.  One
#: scale multiply + bookkeeping (symmetric), plus min-offset madd work
#: for asymmetric formats.  Calibrated (3.0 asym) against the paper's
#: "Q2_K prefill reaches 231% of the default-build rate".
_EPI_OPS_SYM = 2.0
_EPI_OPS_ASYM = 3.5


def f32_epilogue_ops_per_weight(fmt: str) -> float:
    if fmt in DENSE_BPW:
        return 0.0
    f = FORMATS[fmt]
    sub = f.sub_block or f.block
    return (_EPI_OPS_ASYM if f.asymmetric else _EPI_OPS_SYM) / sub


@dataclasses.dataclass(frozen=True)
class PhaseEstimate:
    tokens_per_s: float
    t_mac_s: float          # bulk MAC time per token
    t_epilogue_s: float     # f32 scale/min path time per token
    t_memory_s: float       # HBM streaming time per token
    bound: str              # "compute" | "memory"
    watts: float
    tokens_per_joule: float


class InferencePerfModel:
    """Predicts llama-bench prefill/decode throughput on a profile."""

    #: quantized-kernel MAC efficiency relative to the f16 BLAS rate
    #: (dequant-in-kernel overhead).
    QUANT_MAC_EFF = 0.85
    #: Per-op dynamic energy (pJ) by path; MUL_ADD issues 2 instructions.
    # System-level energy/op (~TDP/peak): FMA 20 pJ; the mul+add reroute
    # issues two instructions (~45 pJ) -- why the paper sees the noFMA
    # build trade efficiency for speed.  Matrix/integer engines are
    # cheaper per op.
    ENERGY_PJ = {Path.FMA: 20.0, Path.TENSOR: 3.5,
                 Path.MUL_ADD: 45.0, Path.DOT_I8: 6.0}
    #: decode GEMV re-uses unpacked scales across the activation row;
    #: its f32 epilogue is ~half the prefill epilogue per weight.
    DECODE_EPI_FACTOR = 0.6
    #: static/HBM power as a fraction of TDP.
    IDLE_FRACTION = 0.35

    def __init__(self, profile: DeviceProfile, spec: LLMSpec = QWEN25_1P5B):
        self.profile = profile
        self.spec = spec

    # ------------------------------------------------------------------
    def _f32_build_tput(self) -> float:
        path = self.profile.build_paths.get("f32", Path.FMA)
        return self.profile.throughput("f32", path)

    def _mac_tflops(self, fmt: str) -> float:
        """Effective TF of the bulk MAC path for a model format."""
        prof = self.profile
        if fmt == "f32":
            return prof.blas_tflops.get("f32", self._f32_build_tput())
        if fmt in ("f16", "bf16"):
            return prof.blas_tflops.get(
                "f16", prof.blas_tflops.get("bf16", 0.0)) or \
                prof.throughput("f16", prof.build_paths.get("f16", Path.FMA))
        # quantized: dequant + f16-class GEMM (llama.cpp prompt path)
        base = prof.blas_tflops.get("f16", 0.0) or prof.throughput(
            "f16", prof.build_paths.get("f16", Path.FMA))
        return base * self.QUANT_MAC_EFF

    def _per_token(self, fmt: str, context: int):
        spec, prof = self.spec, self.profile
        macs = spec.active_weights
        mac_tf = self._mac_tflops(fmt)
        if mac_tf <= 0:
            raise ValueError(f"{prof.name} has no MAC path for {fmt!r}")
        t_mac = 2.0 * macs / (mac_tf * 1e12)
        epi_ops = f32_epilogue_ops_per_weight(fmt) * macs
        f32_tf = self._f32_build_tput()
        t_epi = epi_ops / (f32_tf * 1e12) if epi_ops else 0.0
        w_bytes = macs * bytes_per_weight(fmt)
        kv_read = spec.kv_bytes_per_token() * context
        t_mem = (w_bytes + kv_read) / (prof.hbm_bw_gbps * 1e9
                                       * prof.gemv_efficiency)
        return t_mac, t_epi, t_mem, epi_ops, macs

    def _power(self, ops_by_path: Dict[Path, float], t_total: float) -> float:
        tdp = self.profile.tdp_watts
        dyn = sum(self.ENERGY_PJ.get(p, 1.0) * 1e-12 * n
                  for p, n in ops_by_path.items())
        return min(tdp, self.IDLE_FRACTION * tdp + dyn / max(t_total, 1e-12))

    def _mac_power_path(self, fmt: str) -> Path:
        if fmt in DENSE_BPW:
            return self.profile.build_paths.get(
                "f16" if fmt != "f32" else "f32", Path.FMA)
        return Path.DOT_I8 if ("i8", Path.DOT_I8) in self.profile.peak \
            else Path.FMA

    # -- phases ---------------------------------------------------------
    def prefill(self, fmt: str, prompt_len: int = 512,
                batch: int = 1) -> PhaseEstimate:
        """Compute-bound: all prompt tokens processed in parallel."""
        t_mac, t_epi, t_mem, epi_ops, macs = self._per_token(
            fmt, context=prompt_len // 2)
        n_tok = prompt_len * batch
        t_compute = (t_mac + t_epi) * n_tok
        t_total = max(t_compute, t_mem)   # weights stream once per pass
        tps = n_tok / t_total
        f32_path = self.profile.build_paths.get("f32", Path.FMA)
        watts = self._power({self._mac_power_path(fmt): 2 * macs * n_tok,
                             f32_path: epi_ops * n_tok}, t_total)
        return PhaseEstimate(
            tokens_per_s=tps, t_mac_s=t_mac, t_epilogue_s=t_epi,
            t_memory_s=t_mem, watts=watts, tokens_per_joule=tps / watts,
            bound="compute" if t_compute >= t_mem else "memory")

    def _decode_mac_tflops(self, fmt: str) -> float:
        """GEMV MAC path: quantized formats use the int8 dp4a vec_dot."""
        prof = self.profile
        if fmt in DENSE_BPW:
            return self._mac_tflops(fmt)
        i8 = prof.throughput("i8", Path.DOT_I8)
        return i8 if i8 > 0 else self._mac_tflops(fmt)

    def decode(self, fmt: str, context: int = 640,
               batch: int = 1) -> PhaseEstimate:
        """Memory-bound: every active weight byte streamed per token."""
        t_mac, t_epi, t_mem, epi_ops, macs = self._per_token(fmt, context)
        t_mac = 2.0 * macs / (self._decode_mac_tflops(fmt) * 1e12)
        t_epi = t_epi * self.DECODE_EPI_FACTOR
        epi_ops = epi_ops * self.DECODE_EPI_FACTOR
        t_compute = (t_mac + t_epi)
        t_total = max(t_compute, t_mem)
        tps = batch / t_total
        f32_path = self.profile.build_paths.get("f32", Path.FMA)
        watts = self._power({self._mac_power_path(fmt): 2 * macs,
                             f32_path: epi_ops}, t_total)
        return PhaseEstimate(
            tokens_per_s=tps, t_mac_s=t_mac, t_epilogue_s=t_epi,
            t_memory_s=t_mem, watts=watts, tokens_per_joule=tps / watts,
            bound="compute" if t_compute >= t_mem else "memory")

    # -- the paper's theoretical scalings --------------------------------
    def theoretical_prefill_tps(self, fmt: str, prompt_len: int = 512) -> float:
        """Paper eq. 4.2: A100-measured x (SMs_d / SMs_o) = x 70/108."""
        a100 = InferencePerfModel(A100_40G, self.spec)
        return a100.prefill(fmt, prompt_len).tokens_per_s * (70.0 / 108.0)

    def theoretical_decode_tps(self, fmt: str, context: int = 640) -> float:
        """Paper eq. 4.3: A100-measured x (bw_d / bw_o) = x 1493/1555."""
        a100 = InferencePerfModel(A100_40G, self.spec)
        return a100.decode(fmt, context).tokens_per_s * (1493.0 / 1555.0)


def sweep(profiles: Iterable[DeviceProfile],
          fmts: Iterable[str] = ("f32", "f16", "q8_0", "q6_k", "q4_k", "q2_k"),
          spec: LLMSpec = QWEN25_1P5B,
          ) -> Dict[str, Dict[str, Dict[str, PhaseEstimate]]]:
    """The full Graph 4-1/4-2 grid: profile x format x phase."""
    out: Dict[str, Dict[str, Dict[str, PhaseEstimate]]] = {}
    for prof in profiles:
        m = InferencePerfModel(prof, spec)
        out[prof.name] = {
            fmt: {"prefill": m.prefill(fmt), "decode": m.decode(fmt)}
            for fmt in fmts}
    return out
