"""Three-term roofline analysis from compiled dry-run artifacts.

For every (architecture x shape x mesh) cell the dry-run records
``cost_analysis()`` FLOPs/bytes and HLO-parsed collective bytes; this
module converts them into the three roofline terms

    compute    = HLO_FLOPs      / (chips x peak_FLOP/s)
    memory     = HLO_bytes      / (chips x HBM_bw)
    collective = collective_B   / (chips x link_bw)

identifies the dominant term, and computes the model-FLOPs utilization
ratio (6ND / HLO_FLOPs) that exposes remat / redundancy waste.

Hardware constants default to the TPU v5e target (197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Mapping, Optional

from repro.core.device_profile import TPU_V5E, DeviceProfile


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Roofline decomposition of one compiled step on one mesh."""

    cell: str                   # "<arch>/<shape>/<mesh>"
    chips: int
    hlo_flops: float            # whole-step, all chips
    hlo_bytes: float
    collective_bytes: float
    model_flops: float          # 6*N*D (dense) or 6*N_active*D (MoE)
    t_compute_s: float
    t_memory_s: float
    t_collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute_s, "memory": self.t_memory_s,
                 "collective": self.t_collective_s}
        return max(terms, key=terms.get)

    @property
    def step_seconds(self) -> float:
        """No-overlap upper bound = dominant term under perfect overlap."""
        return max(self.t_compute_s, self.t_memory_s, self.t_collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs -- remat & redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline the step achieves, assuming
        perfect overlap: useful model FLOPs / (step_time x fleet peak)."""
        denom = self.step_seconds
        if denom <= 0:
            return 0.0
        return self.t_compute_s * self.useful_flops_ratio / denom

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant,
                 step_seconds=self.step_seconds,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def analyze(cell: str, chips: int, hlo_flops: float, hlo_bytes: float,
            collective_bytes: float, model_flops: float,
            profile: DeviceProfile = TPU_V5E,
            peak_tflops: Optional[float] = None) -> RooflineTerms:
    """Build roofline terms for one cell.

    Args:
      hlo_flops / hlo_bytes: per-chip numbers from ``cost_analysis()`` of
        the partitioned module, multiplied by ``chips`` by the caller if
        it recorded whole-step numbers. We treat them as WHOLE-STEP sums.
      collective_bytes: per-chip collective traffic from HLO parsing,
        times chips (whole-step).
      model_flops: 6 * N_active * tokens for a train step; 2 * N_active *
        tokens for serving.
    """
    peak = (peak_tflops or profile.theoretical.get("bf16", 197.0)) * 1e12
    hbm = profile.hbm_bw_gbps * 1e9
    link = profile.interconnect_gbps * 1e9  # per the task spec: per-link bw
    return RooflineTerms(
        cell=cell, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes, model_flops=model_flops,
        t_compute_s=hlo_flops / (chips * peak),
        t_memory_s=hlo_bytes / (chips * hbm),
        t_collective_s=collective_bytes / (chips * link))


# ----------------------------------------------------------------------
# table rendering for EXPERIMENTS.md
# ----------------------------------------------------------------------

def markdown_table(rows: List[RooflineTerms]) -> str:
    hdr = ("| cell | chips | compute s | memory s | collective s | dominant "
           "| 6ND/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r.cell} | {r.chips} | {r.t_compute_s:.3e} | "
            f"{r.t_memory_s:.3e} | {r.t_collective_s:.3e} | {r.dominant} | "
            f"{r.useful_flops_ratio:.2f} | {r.roofline_fraction:.2%} |")
    return "\n".join(lines)


def load_jsonl(path: str) -> List[Dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows
