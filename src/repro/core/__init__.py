"""Core layer: the paper's contribution, generalized.

C1 capability characterization -> :mod:`repro.core.device_profile`
C2 compute-path rerouting      -> :mod:`repro.core.compute_path`
C3/C4 phase + format modeling  -> :mod:`repro.core.perf_model`
C5 energy / fleet economics    -> :mod:`repro.core.energy`
Roofline (dry-run analysis)    -> :mod:`repro.core.roofline`, ``hlo_analysis``
"""

from repro.core.compute_path import (OpDescriptor, PathDecision, PathPolicy,
                                     matmul_descriptor)
from repro.core.device_profile import (A100_40G, CMP_170HX, CMP_170HX_NOFMA,
                                       PROFILES, TPU_V5E, DeviceProfile, Path,
                                       get_profile, register_profile)
from repro.core.perf_model import (InferencePerfModel, LLMSpec, PhaseEstimate,
                                   QWEN25_1P5B, sweep)

__all__ = [
    "OpDescriptor", "PathDecision", "PathPolicy", "matmul_descriptor",
    "A100_40G", "CMP_170HX", "CMP_170HX_NOFMA", "PROFILES", "TPU_V5E",
    "DeviceProfile", "Path", "get_profile", "register_profile",
    "InferencePerfModel", "LLMSpec", "PhaseEstimate", "QWEN25_1P5B", "sweep",
]
