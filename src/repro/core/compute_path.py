"""Compute-path policy: route work onto the fastest unthrottled path (C2).

The paper's workaround -- compile llama.cpp / mixbench with
``-fmad=false`` so FP32 work flows through the (unthrottled) separate
multiply/add pipes -- generalizes to a *policy* object: given a
:class:`~repro.core.device_profile.DeviceProfile` and an operation
descriptor, pick the kernel variant with the highest modeled throughput.

Every hot kernel in :mod:`repro.kernels` registers its variants here:

========== ===========================  =====================================
variant     GPU meaning (paper)          TPU meaning (this system)
========== ===========================  =====================================
``fma``     default nvcc codegen         MXU systolic matmul (``jnp.dot``)
``mul_add`` ``-fmad=false`` build        VPU elementwise multiply + add
``dot_i8``  dp4a / quantized vec_dot     int8 MXU matmul with f32 rescale
========== ===========================  =====================================

The policy is consulted at *trace time* (it only affects which jitted
graph we build), mirroring the paper's compile-time switch.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.device_profile import DeviceProfile, Path

# Map kernel-variant names onto capability paths.
VARIANT_TO_PATH = {
    "fma": Path.FMA,
    "mxu": Path.TENSOR,
    "mul_add": Path.MUL_ADD,
    "dot_i8": Path.DOT_I8,
}


@dataclasses.dataclass(frozen=True)
class OpDescriptor:
    """What a kernel is about to do, for throughput modeling.

    Attributes:
      flops: floating/integer op count of the op.
      bytes_moved: HBM traffic in bytes.
      precision: compute precision ("f32", "bf16", "f16", "i8", ...).
      supports: which variants the kernel implements.
    """

    flops: float
    bytes_moved: float
    precision: str
    supports: Sequence[str] = ("fma", "mul_add")


@dataclasses.dataclass(frozen=True)
class PathDecision:
    variant: str
    path: Path
    modeled_seconds: float
    compute_seconds: float
    memory_seconds: float
    bound: str  # "compute" | "memory"


class PathPolicy:
    """Selects the best kernel variant for a device profile."""

    def __init__(self, profile: DeviceProfile,
                 force_variant: Optional[str] = None):
        self.profile = profile
        self.force_variant = force_variant

    # ------------------------------------------------------------------
    def _variant_precision(self, variant: str, precision: str) -> str:
        # int8-dot variants compute in i8 regardless of the nominal
        # activation precision (scales are applied in f32 epilogue).
        return "i8" if variant == "dot_i8" else precision

    def modeled_time(self, op: OpDescriptor, variant: str) -> Optional[PathDecision]:
        path = VARIANT_TO_PATH[variant]
        prec = self._variant_precision(variant, op.precision)
        tf = self.profile.throughput(prec, path)
        if tf <= 0.0:
            # TENSOR and FMA are interchangeable namings across SKUs.
            if path == Path.TENSOR:
                tf = self.profile.throughput(prec, Path.FMA)
            elif path == Path.FMA:
                tf = self.profile.throughput(prec, Path.TENSOR)
        if tf <= 0.0:
            return None
        t_compute = op.flops / (tf * 1e12)
        t_memory = op.bytes_moved / (self.profile.hbm_bw_gbps * 1e9)
        t = max(t_compute, t_memory)
        return PathDecision(
            variant=variant, path=path, modeled_seconds=t,
            compute_seconds=t_compute, memory_seconds=t_memory,
            bound="compute" if t_compute >= t_memory else "memory")

    def decide(self, op: OpDescriptor) -> PathDecision:
        """Pick the fastest supported variant (the paper's C2 reroute)."""
        if self.force_variant is not None:
            d = self.modeled_time(op, self.force_variant)
            if d is None:
                raise ValueError(
                    f"forced variant {self.force_variant!r} has no path on "
                    f"{self.profile.name}")
            return d
        best: Optional[PathDecision] = None
        for variant in op.supports:
            d = self.modeled_time(op, variant)
            if d is not None and (best is None
                                  or d.modeled_seconds < best.modeled_seconds):
                best = d
        if best is None:
            raise ValueError(
                f"no supported variant of {op} runs on {self.profile.name}")
        return best


def matmul_descriptor(m: int, n: int, k: int, precision: str,
                      bytes_per_weight: float = 2.0,
                      supports: Sequence[str] = ("fma", "mul_add"),
                      ) -> OpDescriptor:
    """Descriptor for an (m,k) x (k,n) matmul streaming W once."""
    act_bytes = {"f32": 4, "f16": 2, "bf16": 2, "i8": 1}.get(precision, 2)
    return OpDescriptor(
        flops=2.0 * m * n * k,
        bytes_moved=k * n * bytes_per_weight + (m * k + m * n) * act_bytes,
        precision=precision,
        supports=supports)
