"""Fault-tolerant checkpointing: atomic step directories, async writes,
resume-from-latest.

Layout:  <dir>/step_<N>/
             tree.json          -- pytree structure + shapes/dtypes
             shard_<i>.npz      -- leaf arrays (single-host: one shard)
             _COMPLETE          -- commit marker (atomicity)

Writes go to ``step_<N>.tmp`` and are renamed after the marker is
written, so a preemption mid-write can never corrupt the latest
checkpoint.  ``AsyncCheckpointer`` moves serialization off the training
thread (the standard overlap trick); ``restore_latest`` skips
uncommitted directories, which is the crash-recovery path.
"""

from __future__ import annotations

import concurrent.futures
import json
import os
import re
import shutil
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path)
        out.append((name, leaf))
    return out


def save(directory: str, step: int, tree) -> str:
    """Synchronous atomic checkpoint write. Returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    named = _flatten_with_names(tree)
    meta = {"step": step,
            "leaves": [{"name": n, "shape": list(np.shape(x)),
                        "dtype": str(np.asarray(x).dtype)}
                       for n, x in named]}
    arrays = {f"a{i}": np.asarray(x) for i, (n, x) in enumerate(named)}
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _committed_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "_COMPLETE")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = _committed_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, tree_like):
    """Restore into the structure of ``tree_like`` (shape-checked)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "tree.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    leaves = [data[f"a{i}"] for i in range(len(meta["leaves"]))]
    ref_flat, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(ref_flat) == len(leaves), (len(ref_flat), len(leaves))
    out = []
    for ref, arr, m in zip(ref_flat, leaves, meta["leaves"]):
        assert tuple(ref.shape) == tuple(arr.shape), \
            f"{m['name']}: {ref.shape} vs {arr.shape}"
        out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(directory: str, tree_like):
    step = latest_step(directory)
    if step is None:
        return None, None
    return step, restore(directory, step, tree_like)


def prune(directory: str, keep: int = 3) -> None:
    steps = _committed_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Serializes checkpoints on a background thread; at most one in
    flight (the training loop never blocks unless it laps the writer)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[concurrent.futures.Future] = None

    def save(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # device->host

        def _write():
            save(self.directory, step, host_tree)
            prune(self.directory, self.keep)

        self._pending = self._pool.submit(_write)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)
