from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_step,
                                           prune, restore, restore_latest,
                                           save)

__all__ = ["AsyncCheckpointer", "latest_step", "prune", "restore",
           "restore_latest", "save"]
