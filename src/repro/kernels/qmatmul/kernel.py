"""Dequantize-in-kernel matmul for ggml-family block formats (paper C4).

The paper's AI workload is llama.cpp quantized inference; its hot kernel
is "activation row x block-quantized weight matrix".  TPU adaptation:

* weights arrive as structure-of-arrays planes (see ``repro.quant``):
  an int8 / packed-uint8 value plane plus small scale planes, all tiled
  cleanly into VMEM via BlockSpecs (k-blocks are multiples of the
  256-element super-block so scale tiles align);
* ``variant="dequant_dot"`` dequantizes the (bk, bn) weight tile on the
  VPU (unpack shifts + two-level scale multiply) and feeds the MXU --
  llama.cpp's "dequantize + GEMM" prompt path;
* ``variant="dot_i8"`` (q8_0 only) quantizes the activation tile to int8
  per 32-element k-block inside the kernel and runs the int8 MXU path
  with an f32 rescale epilogue -- llama.cpp's dp4a vec_dot path, i.e.
  the integer pipe the CMP 170HX leaves unthrottled.

Grid: (M/bm, N/bn, K/bk), K innermost, f32 VMEM accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.formats import get_format
from repro.quant.quantize import QTensor


def _dequant_tile(fmt_name, v, sub_s, sub_m, sup_s, sup_m):
    """Dequantize one (bk, bn) weight tile from its VMEM planes (f32)."""
    fmt = get_format(fmt_name)
    if fmt_name == "q8_0":
        scale = jnp.repeat(sup_s, fmt.block, axis=0)
        return v.astype(jnp.float32) * scale
    sub = fmt.sub_block
    per = fmt.block // sub
    if fmt_name == "q6_k":
        eff = sub_s.astype(jnp.float32) * jnp.repeat(sup_s, per, axis=0)
        eff = jnp.where(eff == 0, 1.0, eff)
        return v.astype(jnp.float32) * jnp.repeat(eff, sub, axis=0)
    # q4_k / q2_k: packed values + asymmetric two-level scales
    bits = fmt.bits
    n_per_byte = 8 // bits
    mask = (1 << bits) - 1
    kp, bn = v.shape
    parts = [(v >> (bits * i)) & mask for i in range(n_per_byte)]
    q = jnp.stack(parts, axis=1).reshape(kp * n_per_byte, bn).astype(
        jnp.float32)
    eff_d = sub_s.astype(jnp.float32) * jnp.repeat(sup_s, per, axis=0)
    eff_d = jnp.where(eff_d == 0, 1.0, eff_d)
    eff_m = sub_m.astype(jnp.float32) * jnp.repeat(sup_m, per, axis=0)
    return q * jnp.repeat(eff_d, sub, axis=0) - jnp.repeat(eff_m, sub, axis=0)


def _qmatmul_dequant_kernel(fmt_name, x_ref, v_ref, sub_s_ref, sub_m_ref,
                            sup_s_ref, sup_m_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_tile(
        fmt_name, v_ref[...],
        None if sub_s_ref is None else sub_s_ref[...],
        None if sub_m_ref is None else sub_m_ref[...],
        sup_s_ref[...],
        None if sup_m_ref is None else sup_m_ref[...])
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _qmatmul_i8_kernel(x_ref, v_ref, sup_s_ref, o_ref, acc_ref, *,
                       qblock: int):
    """int8 x int8 -> int32 MXU path with f32 rescale (q8_0 only).

    The activation tile is quantized per (row, 32-wide k-block) inside the
    kernel; the dot is decomposed per k-block so each int32 partial can be
    rescaled by (x_scale * w_scale) -- the f32 epilogue whose cost the
    paper's -fmad story is about.
    """
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bk)
    bm, bk = x.shape
    nq = bk // qblock
    xb = x.reshape(bm, nq, qblock)
    x_scale = jnp.max(jnp.abs(xb), axis=2) / 127.0        # (bm, nq)
    x_scale = jnp.where(x_scale == 0, 1.0, x_scale)
    xq = jnp.clip(jnp.round(xb / x_scale[:, :, None]), -127, 127
                  ).astype(jnp.int8)
    wq = v_ref[...]                                        # (bk, bn) int8
    bn = wq.shape[1]
    wqb = wq.reshape(nq, qblock, bn)
    w_scale = sup_s_ref[...]                               # (nq, bn) f32
    # batched int8 dot per 32-block: (nq, bm, qblock) x (nq, qblock, bn)
    xqb = jnp.swapaxes(xq, 0, 1)                           # (nq, bm, qblock)
    part = jax.lax.dot_general(
        xqb.astype(jnp.int32), wqb.astype(jnp.int32),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)                  # (nq, bm, bn)
    # f32 rescale epilogue
    part_f = part.astype(jnp.float32)
    part_f *= jnp.swapaxes(x_scale, 0, 1)[:, :, None]      # x scales
    part_f *= w_scale[:, None, :]                          # w scales
    acc_ref[...] += jnp.sum(part_f, axis=0)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def qmatmul_pallas(x: jnp.ndarray, qt: QTensor, *, variant: str = "dequant_dot",
                   bm: int = 128, bk: int = 512, bn: int = 128,
                   out_dtype=jnp.float32,
                   interpret: bool = False) -> jnp.ndarray:
    """(M, K) activations x block-quantized (K, N) weights."""
    m, k = x.shape
    k2, n = qt.shape
    assert k == k2, (x.shape, qt.shape)
    fmt = qt.format
    bm, bn = min(bm, m), min(bn, n)
    bk = min(bk, k)
    bk = max(fmt.block, (bk // fmt.block) * fmt.block)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"({m},{k},{n}) vs blocks ({bm},{bk},{bn})")
    grid = (m // bm, n // bn, k // bk)
    x_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    out_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    out_shape = jax.ShapeDtypeStruct((m, n), out_dtype)
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]

    if variant == "dot_i8":
        if qt.fmt != "q8_0":
            raise ValueError("dot_i8 variant requires q8_0 weights")
        v_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
        s_rows = bk // fmt.block
        s_spec = pl.BlockSpec((s_rows, bn), lambda i, j, kk: (kk, j))
        kernel = functools.partial(_qmatmul_i8_kernel, qblock=fmt.block)
        return pl.pallas_call(
            kernel, grid=grid,
            in_specs=[x_spec, v_spec, s_spec],
            out_specs=out_spec, out_shape=out_shape,
            scratch_shapes=scratch, interpret=interpret,
        )(x, qt.values, qt.super_scales)

    if variant != "dequant_dot":
        raise ValueError(f"unknown variant {variant!r}")

    # --- dequant_dot: assemble per-format plane specs -------------------
    vals_per_byte = fmt.values_per_byte
    v_rows = bk // vals_per_byte
    v_spec = pl.BlockSpec((v_rows, bn), lambda i, j, kk: (kk, j))
    sup_rows = bk // fmt.block
    sup_spec = pl.BlockSpec((sup_rows, bn), lambda i, j, kk: (kk, j))
    operands = [x, qt.values]
    in_specs = [x_spec, v_spec]
    has_sub = qt.sub_scales is not None
    has_min = qt.sub_mins is not None
    if has_sub:
        sub_rows = bk // fmt.sub_block
        sub_spec = pl.BlockSpec((sub_rows, bn), lambda i, j, kk: (kk, j))
        operands.append(qt.sub_scales)
        in_specs.append(sub_spec)
        if has_min:
            operands.append(qt.sub_mins)
            in_specs.append(sub_spec)
    operands.append(qt.super_scales)
    in_specs.append(sup_spec)
    if has_min:
        operands.append(qt.super_mins)
        in_specs.append(sup_spec)

    def kernel(x_ref, *refs):
        # refs layout: v, [sub_s, [sub_m]], sup_s, [sup_m], o, acc
        o_ref, acc_ref = refs[-2], refs[-1]
        i = 0
        v_ref = refs[i]; i += 1
        sub_s_ref = refs[i] if has_sub else None
        i += int(has_sub)
        sub_m_ref = refs[i] if has_min else None
        i += int(has_min)
        sup_s_ref = refs[i]; i += 1
        sup_m_ref = refs[i] if has_min else None
        _qmatmul_dequant_kernel(qt.fmt, x_ref, v_ref, sub_s_ref, sub_m_ref,
                                sup_s_ref, sup_m_ref, o_ref, acc_ref)

    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=in_specs, out_specs=out_spec, out_shape=out_shape,
        scratch_shapes=scratch, interpret=interpret,
    )(*operands)
