from repro.kernels.qmatmul.kernel import qmatmul_pallas
from repro.kernels.qmatmul.ops import qmatmul, qmatmul_variant, select_variant
from repro.kernels.qmatmul.ref import qmatmul_i8_ref, qmatmul_ref

__all__ = ["qmatmul_pallas", "qmatmul", "qmatmul_variant", "select_variant",
           "qmatmul_i8_ref", "qmatmul_ref"]
