"""Jitted wrapper: capability-aware quantized matmul (paper C4).

On a profile with an unthrottled int8 path and a throttled f32 path (the
CMP 170HX), the policy picks ``dot_i8`` for q8_0 weights; on a TPU it
also picks ``dot_i8`` (int8 MXU = 2x bf16 throughput); formats without an
int8 plane fall back to ``dequant_dot``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.device_profile import DeviceProfile, Path
from repro.kernels.qmatmul.kernel import qmatmul_pallas
from repro.quant.quantize import QTensor


@functools.partial(jax.jit, static_argnames=("variant", "interpret",
                                             "bm", "bk", "bn"))
def qmatmul_variant(x: jnp.ndarray, qt: QTensor, *,
                    variant: str = "dequant_dot",
                    bm: int = 128, bk: int = 512, bn: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    return qmatmul_pallas(x, qt, variant=variant, bm=bm, bk=bk, bn=bn,
                          interpret=interpret)


def select_variant(qt_fmt: str, profile: Optional[DeviceProfile]) -> str:
    if qt_fmt != "q8_0" or profile is None:
        return "dequant_dot"
    i8 = profile.throughput("i8", Path.DOT_I8)
    f16 = max(profile.throughput("f16", Path.FMA),
              profile.throughput("bf16", Path.TENSOR),
              profile.throughput("f16", Path.MUL_ADD))
    return "dot_i8" if i8 > f16 * 0.5 else "dequant_dot"


def qmatmul(x: jnp.ndarray, qt: QTensor,
            profile: Optional[DeviceProfile] = None,
            interpret: bool = False) -> jnp.ndarray:
    variant = select_variant(qt.fmt, profile)
    return qmatmul_variant(x, qt, variant=variant, interpret=interpret)
