"""Pure-jnp oracles for the quantized matmul kernels."""

from __future__ import annotations

import jax.numpy as jnp

from repro.quant.quantize import QTensor, dequantize


def qmatmul_ref(x: jnp.ndarray, qt: QTensor,
                out_dtype=jnp.float32) -> jnp.ndarray:
    """Dequantize-then-matmul oracle (exact for dequant_dot)."""
    w = dequantize(qt)
    return jnp.dot(x.astype(jnp.float32), w).astype(out_dtype)


def qmatmul_i8_ref(x: jnp.ndarray, qt: QTensor, qblock: int = 32,
                   out_dtype=jnp.float32) -> jnp.ndarray:
    """Activation-quantized int8 dot oracle (exact for dot_i8, q8_0)."""
    assert qt.fmt == "q8_0"
    m, k = x.shape
    nq = k // qblock
    xb = x.astype(jnp.float32).reshape(m, nq, qblock)
    x_scale = jnp.max(jnp.abs(xb), axis=2) / 127.0
    x_scale = jnp.where(x_scale == 0, 1.0, x_scale)
    xq = jnp.clip(jnp.round(xb / x_scale[:, :, None]), -127, 127)
    wq = qt.values.astype(jnp.float32).reshape(nq, qblock, -1)
    w_scale = qt.super_scales                          # (nq, n)
    part = jnp.einsum("mqk,qkn->qmn", xq, wq)
    part = part * x_scale.T[:, :, None] * w_scale[:, None, :]
    return jnp.sum(part, axis=0).astype(out_dtype)
