"""Pure-jnp oracle for the path-selectable matmul."""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
               out_dtype=jnp.float32) -> jnp.ndarray:
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)
