"""Jitted public wrapper: capability-aware matmul (paper C2).

``matmul(x, w, policy=...)`` consults the
:class:`~repro.core.compute_path.PathPolicy` for the target device
profile and dispatches to the corresponding Pallas variant -- the
framework-level equivalent of the paper's "recompile with -fmad=false".
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.compute_path import PathPolicy, matmul_descriptor
from repro.kernels.fma_matmul.kernel import fma_matmul_pallas

_VARIANTS = ("mxu", "mul_add")


@functools.partial(jax.jit, static_argnames=("variant", "interpret", "bm",
                                             "bk", "bn"))
def matmul_variant(x: jnp.ndarray, w: jnp.ndarray, *, variant: str = "mxu",
                   bm: int = 128, bk: int = 128, bn: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    return fma_matmul_pallas(x, w, variant=variant, bm=bm, bk=bk, bn=bn,
                             interpret=interpret)


def matmul(x: jnp.ndarray, w: jnp.ndarray,
           policy: Optional[PathPolicy] = None,
           interpret: bool = False) -> jnp.ndarray:
    """Path-policy-dispatched matmul.

    With no policy (or a TPU profile) this takes the MXU path; with a
    CMP-170HX-style profile whose matrix path is throttled for the
    activation precision, the policy reroutes onto the decomposed
    multiply+add (VPU) variant.
    """
    variant = "mxu"
    if policy is not None:
        m, k = x.shape
        n = w.shape[1]
        prec = {"float32": "f32", "bfloat16": "bf16",
                "float16": "f16"}.get(str(x.dtype), "f32")
        desc = matmul_descriptor(m, n, k, prec, supports=("fma", "mul_add"))
        decision = policy.decide(desc)
        variant = "mxu" if decision.variant == "fma" else "mul_add"
    return matmul_variant(x, w, variant=variant, interpret=interpret)
