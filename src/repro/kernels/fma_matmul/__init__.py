from repro.kernels.fma_matmul.kernel import fma_matmul_pallas
from repro.kernels.fma_matmul.ops import matmul, matmul_variant
from repro.kernels.fma_matmul.ref import matmul_ref

__all__ = ["fma_matmul_pallas", "matmul", "matmul_variant", "matmul_ref"]
