"""Pallas matmul with selectable compute path (paper C2, TPU-native).

The paper restores the CMP 170HX's FP32 throughput by recompiling with
``-fmad=false`` so multiply-accumulate decomposes into separate multiply
and add instructions, dodging the throttled FMA pipe.  The TPU analogue
of "which pipe does the MAC go down" is **MXU vs VPU**:

* ``variant="mxu"``    -- ``jnp.dot`` on the block tile: lowers to the
  128x128 systolic array (the "fused" path).
* ``variant="mul_add"``-- explicit broadcast-multiply + reduce-add on the
  VPU: *no matrix unit involved*, mirroring the no-FMA build.  This is
  the path a capability-aware scheduler picks when the matrix unit is
  throttled/unavailable for a precision (the CMP's situation), at the
  cost of the VPU's lower ceiling.

Both variants share one grid/BlockSpec schedule: ``(M/bm, N/bn, K/bk)``
with K innermost so a VMEM accumulator carries partial sums across the
K-steps (standard TPU matmul pattern; block shapes are (8,128)-aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, variant: str):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    if variant == "mxu":
        acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    elif variant == "mul_add":
        # Decomposed multiply + add on the VPU: broadcast partial products
        # then reduce.  No dot/MXU op is emitted -- the TPU reading of the
        # paper's -fmad=false reroute.
        prod = x[:, :, None].astype(jnp.float32) * w[None, :, :].astype(
            jnp.float32)                      # (bm, bk, bn) elementwise mul
        acc_ref[...] += jnp.sum(prod, axis=1)  # separate adds
    else:
        raise ValueError(f"unknown variant {variant!r}")

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fma_matmul_pallas(x: jnp.ndarray, w: jnp.ndarray, *, variant: str = "mxu",
                      bm: int = 128, bk: int = 128, bn: int = 128,
                      out_dtype=jnp.float32,
                      interpret: bool = False) -> jnp.ndarray:
    """(M, K) @ (K, N) with an explicit compute-path choice."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"shape ({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn})")
    kernel = functools.partial(_matmul_kernel, variant=variant)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
