"""mixbench-style arithmetic-intensity sweep kernel (paper C1).

MixBench (paper §1.3.1) measures a device's compute/memory balance by
running, per element loaded from memory, a configurable number of
multiply-accumulate iterations -- sweeping ``compute_iters`` traces out
the roofline knee.  This is the kernel the paper uses to expose the
CMP 170HX's FMA throttle (Graphs 3-1..3-4).

TPU version: grid over 1-D blocks; each block is loaded from HBM into
VMEM once, then the VPU runs ``iters`` dependent multiply-add steps:

* ``variant="fma"``     -- ``y = y * a + b`` written so XLA may emit a
  fused multiply-add.
* ``variant="mul_add"`` -- explicitly decomposed: ``t = y * a`` then
  ``y = t + b`` with an intervening use that blocks fusion (the
  ``-fmad=false`` analogue).

Arithmetic intensity = ``2 * iters / dtype_bytes`` flops/byte.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mixbench_kernel(x_ref, o_ref, *, iters: int, variant: str):
    y = x_ref[...]
    a = jnp.asarray(0.999, y.dtype)
    b = jnp.asarray(1e-3, y.dtype)

    def fma_step(_, y):
        return y * a + b

    def mul_add_step(_, y):
        t = y * a              # separate multiply ...
        y = t + b              # ... separate add (no fused op)
        return y

    step = fma_step if variant == "fma" else mul_add_step
    y = jax.lax.fori_loop(0, iters, step, y)
    o_ref[...] = y


def mixbench_pallas(x: jnp.ndarray, *, iters: int = 64,
                    variant: str = "fma", block: int = 1024,
                    interpret: bool = False) -> jnp.ndarray:
    """Run the intensity-sweep kernel over a flat array."""
    (n,) = x.shape
    block = min(block, n)
    assert n % block == 0, (n, block)
    kernel = functools.partial(_mixbench_kernel, iters=iters, variant=variant)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)


def arithmetic_intensity(iters: int, dtype=jnp.float32) -> float:
    return 2.0 * iters / jnp.dtype(dtype).itemsize
