"""Pure-jnp oracle for the mixbench sweep kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mixbench_ref(x: jnp.ndarray, iters: int) -> jnp.ndarray:
    a = jnp.asarray(0.999, x.dtype)
    b = jnp.asarray(1e-3, x.dtype)
    return jax.lax.fori_loop(0, iters, lambda _, y: y * a + b, x)
