"""Jitted wrapper + analytic throughput for the mixbench sweep (C1).

``sweep_points`` returns, for a device profile and precision, the modeled
GFLOPS/GBps at each compute-iters setting -- reproducing the paper's
Graphs 3-1..3-5 without the hardware; the kernel itself validates the
numerics (tests) and is the artifact you would run on a real TPU.
"""

from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core.device_profile import DeviceProfile, Path
from repro.kernels.mixbench.kernel import arithmetic_intensity, mixbench_pallas


@functools.partial(jax.jit,
                   static_argnames=("iters", "variant", "interpret", "block"))
def mixbench(x: jnp.ndarray, *, iters: int = 64, variant: str = "fma",
             block: int = 1024, interpret: bool = False) -> jnp.ndarray:
    return mixbench_pallas(x, iters=iters, variant=variant, block=block,
                           interpret=interpret)


def sweep_points(profile: DeviceProfile, precision: str, path: Path,
                 iters_list=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024),
                 dtype_bytes: int = 4) -> List[Dict[str, float]]:
    """Modeled roofline sweep: throughput(iters) for one (precision, path).

    At low intensity the point sits on the bandwidth roof, at high
    intensity on the path's compute roof -- with the CMP 170HX's crippled
    FMA path the compute roof is 0.39 TFLOPS and the knee moves far right;
    the mul_add path restores it to 6.2 (paper Graph 3-1).
    """
    peak = profile.throughput(precision, path) * 1e12
    bw = profile.hbm_bw_gbps * 1e9
    out = []
    for iters in iters_list:
        ai = 2.0 * iters / dtype_bytes
        gflops = min(peak, ai * bw)
        out.append({
            "compute_iters": iters,
            "flops_per_byte": ai,
            "gflops": gflops / 1e9,
            "gbps": gflops / ai / 1e9,
        })
    return out
