from repro.kernels.mixbench.kernel import arithmetic_intensity, mixbench_pallas
from repro.kernels.mixbench.ops import mixbench, sweep_points
from repro.kernels.mixbench.ref import mixbench_ref

__all__ = ["arithmetic_intensity", "mixbench_pallas", "mixbench",
           "sweep_points", "mixbench_ref"]
