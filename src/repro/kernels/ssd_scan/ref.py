"""Oracle for the SSD chunk kernel: the jnp chunked implementation."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked, ssd_naive  # re-export oracles

__all__ = ["ssd_chunked", "ssd_naive", "ssd_intra_ref"]


def ssd_intra_ref(x, dt, a_log, b, c, chunk: int):
    """Intra-chunk-only reference (inter-chunk state zeroed)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = chunk
    nc = s // q
    f32 = jnp.float32
    xc = x.reshape(bsz, nc, q, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, q, h).astype(f32)
    bc = b.reshape(bsz, nc, q, n).astype(f32)
    cc = c.reshape(bsz, nc, q, n).astype(f32)
    la = (dtc * a_log[None, None, None, :]).transpose(0, 1, 3, 2)
    cum = jnp.cumsum(la, axis=-1)
    seg = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(mask, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bzqn,bzkn->bzqk", cc, bc)
    w = cb[:, :, None] * l_mat
    xdt = xc * dtc[..., None]
    y = jnp.einsum("bzhqk,bzkhp->bzqhp", w, xdt)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)
    states = jnp.einsum("bzhq,bzqn,bzqhp->bzhnp", decay_to_end, bc, xdt)
    chunk_decay = jnp.exp(cum[..., -1])
    return (y.reshape(bsz, s, h, p), states, chunk_decay)
