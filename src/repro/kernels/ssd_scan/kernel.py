"""Pallas SSD (Mamba-2) chunk kernel: intra-chunk dual form in VMEM.

The SSD layer splits into (a) an intra-chunk quadratic term that is
attention-like and MXU-friendly and (b) a cheap inter-chunk state
recurrence.  This kernel computes (a) plus each chunk's contribution to
the boundary state, blocked so one (chunk x chunk) tile lives in VMEM:

grid (B, H, n_chunks); per step it loads the chunk's x/dt/B/C tiles,
forms the log-decay cumulative sums on the VPU, runs the two einsums on
the MXU, and writes  y_intra  and the per-chunk boundary state S_z.  The
O(n_chunks) sequential state recurrence stays in jnp (ops.py) -- it is
0.1% of the FLOPs and latency-bound, exactly what the paper's roofline
logic says to leave off the matrix unit.

Shapes: x (B,S,H,P); dt (B,S,H); a_log (H,); b/c (B,S,N).
Outputs: y_intra (B,S,H,P); states (B,NC,H,N,P); chunk_decay (B,NC,H).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref,
                      y_ref, s_ref, dec_ref, *, q: int):
    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    a = alog_ref[0]                                    # scalar (per head)
    b = b_ref[0].astype(jnp.float32)                   # (Q, N)
    c = c_ref[0].astype(jnp.float32)                   # (Q, N)

    la = dt * a                                        # (Q,) log decay
    cum = jnp.cumsum(la)                               # (Q,)
    # lower-tri decay matrix L[i,j] = exp(cum_i - cum_j), j<=i
    seg = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(mask, jnp.exp(seg), 0.0)

    xdt = x * dt[:, None]                              # (Q, P)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # (Q, Q)
    w = cb * l_mat
    y_ref[0, :, 0, :] = jnp.dot(
        w, xdt, preferred_element_type=jnp.float32).astype(y_ref.dtype)

    # chunk boundary state: S = sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cum[-1] - cum)              # (Q,)
    bw = b * decay_to_end[:, None]                     # (Q, N)
    s_ref[0, 0, 0] = jnp.dot(
        bw.T, xdt, preferred_element_type=jnp.float32).astype(s_ref.dtype)
    dec_ref[0, 0, 0] = jnp.exp(cum[-1]).astype(dec_ref.dtype)


def ssd_chunk_pallas(x, dt, a_log, b, c, *, chunk: int = 128,
                     interpret: bool = False):
    """Intra-chunk SSD pass. Returns (y_intra, states, chunk_decay)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q
    kernel = functools.partial(_ssd_chunk_kernel, q=q)
    grid = (bsz, h, nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bb, hh, z: (bb, z, hh, 0)),
            pl.BlockSpec((1, q, 1), lambda bb, hh, z: (bb, z, hh)),
            pl.BlockSpec((1,), lambda bb, hh, z: (hh,)),
            pl.BlockSpec((1, q, n), lambda bb, hh, z: (bb, z, 0)),
            pl.BlockSpec((1, q, n), lambda bb, hh, z: (bb, z, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bb, hh, z: (bb, z, hh, 0)),
            pl.BlockSpec((1, 1, 1, n, p),
                         lambda bb, hh, z: (bb, z, hh, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bb, hh, z: (bb, z, hh)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a_log, b, c)
