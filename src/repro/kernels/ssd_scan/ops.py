"""Full SSD via the Pallas chunk kernel + jnp inter-chunk recurrence."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_chunk_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, a_log, b, c, *, chunk: int = 128,
               interpret: bool = False):
    """Drop-in equivalent of models.ssm.ssd_chunked using the kernel."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    nc = s // q
    y_intra, states, chunk_decay = ssd_chunk_pallas(
        x, dt, a_log, b, c, chunk=q, interpret=interpret)

    # inter-chunk recurrence (latency-bound, off the matrix unit)
    def step(hstate, inp):
        s_z, dec = inp
        h_in = hstate
        hstate = hstate * dec[..., None, None] + s_z
        return hstate, h_in

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, h_starts = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_starts = h_starts.swapaxes(0, 1)                 # (B,NC,H,N,P)

    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    la = (dtc * a_log[None, None, None, :]).transpose(0, 1, 3, 2)
    cum = jnp.cumsum(la, axis=-1)
    cc = c.reshape(bsz, nc, q, n).astype(jnp.float32)
    y_inter = jnp.einsum("bzhq,bzqn,bzhnp->bzqhp", jnp.exp(cum), cc,
                         h_starts)
    return (y_intra + y_inter.reshape(bsz, s, h, p)).astype(x.dtype)
