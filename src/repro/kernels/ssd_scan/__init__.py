from repro.kernels.ssd_scan.kernel import ssd_chunk_pallas
from repro.kernels.ssd_scan.ops import ssd_pallas
from repro.kernels.ssd_scan.ref import ssd_chunked, ssd_intra_ref, ssd_naive

__all__ = ["ssd_chunk_pallas", "ssd_pallas", "ssd_chunked", "ssd_intra_ref",
           "ssd_naive"]
