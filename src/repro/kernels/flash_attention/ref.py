"""Pure-jnp oracle for flash attention (causal / GQA / sliding window)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window=None,
                  scale=None) -> jnp.ndarray:
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    denom = jnp.where(denom == 0.0, 1.0, denom)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / denom, vv.astype(jnp.float32))
    return out.astype(q.dtype)
