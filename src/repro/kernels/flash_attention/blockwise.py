"""Blockwise (flash-style) attention in pure jnp: O(Sq x block) memory.

This is the XLA-lowered sibling of the Pallas kernel: a ``lax.scan`` over
key blocks with an online-softmax carry.  It exists because

* the dry-run compiles 32k/500k-sequence cells on the CPU backend, where
  a naive (Sq x Sk) score tensor would be hundreds of GiB -- the scan
  bounds every intermediate to (B, H, Sq, block);
* under GSPMD it shards cleanly: with q/k/v sequence-sharded over the
  `model` axis, each scan step all-gathers only one KV block -- a
  ring-attention-like schedule the partitioner derives automatically.

GQA is computed grouped (no KV head replication is materialized).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: Optional[int] = None,
                        scale=None, block: int = 512,
                        q_offset: int = 0) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D) -> (B, H, Sq, D).

    ``q_offset`` positions the query block globally (used by chunked
    prefill where Sq < Sk).
    """
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    block = min(block, sk)
    assert sk % block == 0, (sk, block)
    nblk = sk // block
    scale = float(scale if scale is not None else d ** -0.5)

    qg = q.reshape(b, hkv, group, sq, d).astype(jnp.float32) * scale
    kb = k.reshape(b, hkv, nblk, block, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nblk, block, d).transpose(2, 0, 1, 3, 4)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        j, kj, vj = inp
        kj = kj.astype(jnp.float32)
        vj = vj.astype(jnp.float32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kj)
        k_pos = j * block + jnp.arange(block)
        mask = jnp.ones((sq, block), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vj)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, group, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(nblk), kb, vb))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(b, h, sq, d)
    return out.astype(q.dtype)
