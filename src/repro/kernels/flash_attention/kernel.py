"""Flash attention (prefill) Pallas kernel: causal, GQA, sliding-window.

Streaming-softmax attention with the canonical TPU schedule: grid
``(batch, q_heads, Sq/bq, Sk/bk)`` with the key dimension innermost and a
VMEM-resident running (max, sum, accumulator) carried across key blocks.
GQA is handled in the BlockSpec index maps (``kv_head = h // group``), so
no KV replication is materialized.  ``window`` enables the
sliding-window mask used by the hybrid (Hymba-style) architectures at
long context.

Block shapes are MXU/VPU aligned ((8,128) multiples); head_dim is the
lane dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window, bq: int, bk: int):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...][:, 0]                          # (bq,)
    l_prev = l_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)                        # kill masked cols
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]

    @pl.when(j == pl.num_programs(3) - 1)
    def _store():
        l = l_ref[...][:, 0]
        l = jnp.where(l == 0.0, 1.0, l)                # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window=None,
                           scale=None, bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = float(scale if scale is not None else d ** -0.5)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, hh, i, j: (bb, hh, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, i, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, i, j: (bb, hh // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, hh, i, j: (bb, hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
