"""Jitted wrapper for flash attention with three lowering paths.

* ``use_pallas=True``  -- the Pallas TPU kernel (tests run interpret=True)
* default              -- blockwise jnp scan: O(Sq x block) memory, the
  path the distributed dry-run lowers (GSPMD-shardable, CPU-compilable)
* ``naive=True``       -- (Sq x Sk) reference, used only as the oracle in
  kernel tests.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.blockwise import blockwise_attention
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                             "interpret", "bq", "bk",
                                             "naive", "block"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    use_pallas: bool = False, interpret: bool = False,
                    naive: bool = False, block: int = 512,
                    bq: int = 128, bk: int = 128):
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      bq=bq, bk=bk, interpret=interpret)
    if naive:
        return attention_ref(q, k, v, causal=causal, window=window)
    return blockwise_attention(q, k, v, causal=causal, window=window,
                               block=block)
