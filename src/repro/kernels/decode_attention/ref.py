"""Pure-jnp oracles for decode attention (dense + q8 KV)."""

from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, kv_lengths, *, scale=None):
    """q: (B, H, D); k/v: (B, Hkv, S, D); kv_lengths: (B,).

    GQA is computed grouped (q reshaped to (B, Hkv, G, D)) -- no KV head
    replication is materialized, which both saves memory and keeps GSPMD
    shardings aligned to the Hkv axis for every head count (40H, 25H...).
    """
    b, h, d = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(sk)[None, None, None, :] < \
        kv_lengths[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    denom = jnp.where(denom == 0.0, 1.0, denom)
    out = jnp.einsum("bkgs,bksd->bkgd", p / denom, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def dequant_kv_q8(k_q, k_scale, qblock: int = 32):
    """(B, Hkv, S, D) int8 + (B, Hkv, S/qblock, 1) f32 -> f32 KV."""
    scales = jnp.repeat(k_scale, qblock, axis=2)
    return k_q.astype(jnp.float32) * scales


def quantize_kv_q8(k, qblock: int = 32):
    """Per-(head, 32-key-block) symmetric int8 KV quantization."""
    b, hkv, s, d = k.shape
    kb = k.astype(jnp.float32).reshape(b, hkv, s // qblock, qblock, d)
    amax = jnp.max(jnp.abs(kb), axis=(3, 4), keepdims=True)
    scale = (amax / 127.0).reshape(b, hkv, s // qblock, 1)
    scale = jnp.where(scale == 0, 1.0, scale)
    kq = jnp.clip(jnp.round(
        kb / scale[..., None, :]), -127, 127).astype(jnp.int8)
    return kq.reshape(b, hkv, s, d), scale


def decode_attention_q8_ref(q, k_q, k_scale, v_q, v_scale, kv_lengths, *,
                            scale=None, qblock: int = 32):
    k = dequant_kv_q8(k_q, k_scale, qblock)
    v = dequant_kv_q8(v_q, v_scale, qblock)
    return decode_attention_ref(q, k, v, kv_lengths, scale=scale)


# ----------------------------------------------------------------------
# paged (block-table) oracles
# ----------------------------------------------------------------------

def gather_pages(pages: jnp.ndarray, block_tables: jnp.ndarray
                 ) -> jnp.ndarray:
    """Materialize each lane's logical KV view from the page pool.

    pages: (P, Hkv, ps, D); block_tables: (B, T) int32 physical page ids
    in logical order -> (B, Hkv, T*ps, D).  Because the table lists the
    lane's pages in logical order, the gathered array holds exactly the
    values a dense per-lane cache would -- the paged-vs-dense parity
    tests lean on this being an identity up to page naming.
    """
    g = pages[block_tables]                    # (B, T, Hkv, ps, D)
    b, t, hkv, ps, d = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, hkv, t * ps, d)


def decode_attention_paged_ref(q, k_pages, v_pages, block_tables,
                               kv_lengths, *, scale=None):
    """q: (B, H, D); pools (P, Hkv, ps, D); block_tables (B, T)."""
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    return decode_attention_ref(q, k, v, kv_lengths, scale=scale)


def decode_attention_paged_q8_ref(q, k_pages, k_scale_pages, v_pages,
                                  v_scale_pages, block_tables, kv_lengths,
                                  *, scale=None, qblock: int = 32):
    """Paged q8 oracle; scale pools are (P, Hkv, ps/qblock, 1)."""
    k = dequant_kv_q8(gather_pages(k_pages, block_tables),
                      gather_pages(k_scale_pages, block_tables), qblock)
    v = dequant_kv_q8(gather_pages(v_pages, block_tables),
                      gather_pages(v_scale_pages, block_tables), qblock)
    return decode_attention_ref(q, k, v, kv_lengths, scale=scale)
