from repro.kernels.decode_attention.kernel import (
    decode_attention_lengthaware_pallas, decode_attention_paged_pallas,
    decode_attention_paged_q8_pallas, decode_attention_pallas,
    decode_attention_q8_lengthaware_pallas, decode_attention_q8_pallas,
    kv_blocks_fetched, kv_pages_fetched)
from repro.kernels.decode_attention.ops import (decode_attention,
                                                decode_attention_paged,
                                                decode_attention_paged_q8,
                                                decode_attention_q8)
from repro.kernels.decode_attention.ref import (
    decode_attention_paged_q8_ref, decode_attention_paged_ref,
    decode_attention_q8_ref, decode_attention_ref, dequant_kv_q8,
    gather_pages, quantize_kv_q8)

__all__ = ["decode_attention_pallas", "decode_attention_q8_pallas",
           "decode_attention_lengthaware_pallas",
           "decode_attention_q8_lengthaware_pallas",
           "decode_attention_paged_pallas",
           "decode_attention_paged_q8_pallas",
           "kv_blocks_fetched", "kv_pages_fetched",
           "decode_attention", "decode_attention_q8",
           "decode_attention_paged", "decode_attention_paged_q8",
           "decode_attention_q8_ref", "decode_attention_ref",
           "decode_attention_paged_ref", "decode_attention_paged_q8_ref",
           "dequant_kv_q8", "gather_pages", "quantize_kv_q8"]
