"""Split-K decode attention Pallas kernel (the paper's key phase, C3).

LLM decode reads one query token against a long KV cache -- *pure
bandwidth*, the workload where the paper shows a mining GPU matching an
A100.  The TPU kernel streams the KV cache through VMEM in key blocks
(grid ``(B, H, Sk/bk)``) with a running-softmax state in VMEM scratch --
i.e. FlashDecoding adapted to the HBM->VMEM hierarchy.

A quantized-KV variant (q8_0 per-32-block scales along the key axis)
halves the cache traffic: the dequantize happens on the VPU right after
the VMEM load, upstream of the (tiny) MXU dots.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, bk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (1, bk)

    # mask beyond the live cache length (ragged batches)
    kv_len = len_ref[0]
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = k_pos < kv_len
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)         # (1, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = (l_prev * alpha + jnp.sum(p))[None, None]
    m_ref[...] = m_new[None, None]
    acc_ref[...] = (acc_ref[...] * alpha
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))

    @pl.when(j == pl.num_programs(2) - 1)
    def _store():
        l = l_ref[0, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            kv_lengths: jnp.ndarray, *, scale=None,
                            bk: int = 512,
                            interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, D); k/v: (B, Hkv, S, D); kv_lengths: (B,) int32."""
    b, h, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    bk = min(bk, sk)
    assert sk % bk == 0
    scale = float(scale if scale is not None else d ** -0.5)
    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk)
    q4 = q[:, :, None, :]                                 # (B, H, 1, D)
    return pl.pallas_call(
        kernel,
        grid=(b, h, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bb, hh, j: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1,), lambda bb, hh, j: (bb,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bb, hh, j: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k, v, kv_lengths)[:, :, 0, :]


# ----------------------------------------------------------------------
# quantized-KV variant (q8_0 along the key axis)
# ----------------------------------------------------------------------

def _decode_q8_kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, len_ref, o_ref,
                      acc_ref, m_ref, l_ref, *, scale: float, bk: int,
                      qblock: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    # dequantize KV tile on the VPU, straight out of VMEM
    kqv = kq_ref[0, 0].astype(jnp.float32)                # (bk, d) int8
    ksc = jnp.repeat(ks_ref[0, 0], qblock, axis=0)        # (bk, 1) -> rows
    k = kqv * ksc
    vqv = vq_ref[0, 0].astype(jnp.float32)
    vsc = jnp.repeat(vs_ref[0, 0], qblock, axis=0)
    v = vqv * vsc
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

    kv_len = len_ref[0]
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = k_pos < kv_len
    s = jnp.where(mask, s, _NEG_INF)
    m_prev, l_prev = m_ref[0, 0], l_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = (l_prev * alpha + jnp.sum(p))[None, None]
    m_ref[...] = m_new[None, None]
    acc_ref[...] = (acc_ref[...] * alpha
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))

    @pl.when(j == pl.num_programs(2) - 1)
    def _store():
        l = l_ref[0, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_q8_pallas(q, k_q, k_scale, v_q, v_scale, kv_lengths, *,
                               scale=None, bk: int = 512, qblock: int = 32,
                               interpret: bool = False):
    """Quantized-KV decode.

    k_q/v_q: (B, Hkv, S, D) int8; k_scale/v_scale: (B, Hkv, S/qblock, 1)
    f32 per-32-key-block scales (per head, shared across D).
    """
    b, h, d = q.shape
    _, hkv, sk, _ = k_q.shape
    group = h // hkv
    bk = min(bk, sk)
    assert sk % bk == 0 and bk % qblock == 0
    scale = float(scale if scale is not None else d ** -0.5)
    srows = bk // qblock
    kernel = functools.partial(_decode_q8_kernel, scale=scale, bk=bk,
                               qblock=qblock)
    q4 = q[:, :, None, :]
    return pl.pallas_call(
        kernel,
        grid=(b, h, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bb, hh, j: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1, srows, 1),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1, srows, 1),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1,), lambda bb, hh, j: (bb,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bb, hh, j: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k_q, k_scale, v_q, v_scale, kv_lengths)[:, :, 0, :]
