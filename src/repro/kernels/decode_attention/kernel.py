"""Split-K decode attention Pallas kernel (the paper's key phase, C3).

LLM decode reads one query token against a long KV cache -- *pure
bandwidth*, the workload where the paper shows a mining GPU matching an
A100.  The TPU kernel streams the KV cache through VMEM in key blocks
(grid ``(B, H, Sk/bk)``) with a running-softmax state in VMEM scratch --
i.e. FlashDecoding adapted to the HBM->VMEM hierarchy.

A quantized-KV variant (q8_0 per-32-block scales along the key axis)
halves the cache traffic: the dequantize happens on the VPU right after
the VMEM load, upstream of the (tiny) MXU dots.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


# One online-softmax accumulation shared by all four kernel bodies
# (masked/length-aware x dense/q8): the variants differ only in how the
# (k, v) tile is materialized and in whether dead blocks are skipped.

def _flash_init(acc_ref, m_ref, l_ref):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)


def _flash_block(q, k, v, kv_len, j, bk: int, acc_ref, m_ref, l_ref):
    """Fold one (bk, d) KV tile into the running softmax state, masking
    positions beyond the live cache length (ragged batches)."""
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (1, bk)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = k_pos < kv_len
    s = jnp.where(mask, s, _NEG_INF)
    m_prev, l_prev = m_ref[0, 0], l_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)         # (1, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = (l_prev * alpha + jnp.sum(p))[None, None]
    m_ref[...] = m_new[None, None]
    acc_ref[...] = (acc_ref[...] * alpha
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))


def _flash_store(o_ref, acc_ref, l_ref):
    l = l_ref[0, 0]
    l = jnp.where(l == 0.0, 1.0, l)                      # all-dead lane
    o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _dequant_tile(vals_ref, scale_ref, qblock: int):
    """(bk, d) int8 tile + (bk/qblock, 1) scales -> f32, on the VPU
    straight out of VMEM."""
    return (vals_ref[0, 0].astype(jnp.float32)
            * jnp.repeat(scale_ref[0, 0], qblock, axis=0))


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, bk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _flash_init(acc_ref, m_ref, l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    _flash_block(q, k, v, len_ref[0], j, bk, acc_ref, m_ref, l_ref)

    @pl.when(j == pl.num_programs(2) - 1)
    def _store():
        _flash_store(o_ref, acc_ref, l_ref)


def decode_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            kv_lengths: jnp.ndarray, *, scale=None,
                            bk: int = 512,
                            interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, D); k/v: (B, Hkv, S, D); kv_lengths: (B,) int32."""
    b, h, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    bk = min(bk, sk)
    assert sk % bk == 0
    scale = float(scale if scale is not None else d ** -0.5)
    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk)
    q4 = q[:, :, None, :]                                 # (B, H, 1, D)
    return pl.pallas_call(
        kernel,
        grid=(b, h, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bb, hh, j: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1,), lambda bb, hh, j: (bb,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bb, hh, j: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k, v, kv_lengths)[:, :, 0, :]


# ----------------------------------------------------------------------
# length-aware variant: HBM traffic proportional to live context
# ----------------------------------------------------------------------
#
# The masked kernel above streams all Sk/bk key blocks per lane and
# relies on the softmax mask to drop dead positions -- HBM reads scale
# with max_len.  Here the per-lane lengths are scalar-prefetched
# (available before the kernel body runs), so the k/v BlockSpec index
# maps can clamp the block index to the last LIVE block: once the grid
# walks past ceil(len/bk) blocks, the index map keeps returning the same
# block, and the pipeline skips the DMA for a block it already holds.
# Compute for dead blocks is skipped with pl.when.  Reads scale with the
# live cache length; the masked kernel stays as the parity reference.


def _last_live_block(lens_ref, bb, bk: int):
    """Index of the last block holding live keys for lane ``bb`` (>= 0
    so a length-0 lane still maps to block 0: one block fetched, all
    compute skipped)."""
    n_live = pl.cdiv(lens_ref[bb], bk)
    return jnp.maximum(n_live - 1, 0)


def _decode_la_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                      l_ref, *, scale: float, bk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _flash_init(acc_ref, m_ref, l_ref)

    kv_len = len_ref[pl.program_id(0)]

    @pl.when(j * bk < kv_len)                  # skip dead blocks entirely
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        _flash_block(q, k, v, kv_len, j, bk, acc_ref, m_ref, l_ref)

    @pl.when(j == pl.num_programs(2) - 1)
    def _store():
        _flash_store(o_ref, acc_ref, l_ref)


def decode_attention_lengthaware_pallas(q: jnp.ndarray, k: jnp.ndarray,
                                        v: jnp.ndarray,
                                        kv_lengths: jnp.ndarray, *,
                                        scale=None, bk: int = 512,
                                        interpret: bool = False
                                        ) -> jnp.ndarray:
    """Length-aware decode attention: same contract as
    :func:`decode_attention_pallas`, but key blocks past the live cache
    length are never fetched from HBM."""
    b, h, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    bk = min(bk, sk)
    assert sk % bk == 0
    scale = float(scale if scale is not None else d ** -0.5)
    kernel = functools.partial(_decode_la_kernel, scale=scale, bk=bk)
    q4 = q[:, :, None, :]

    def kv_index(bb, hh, j, lens_ref):
        jj = jnp.minimum(j, _last_live_block(lens_ref, bb, bk))
        return (bb, hh // group, jj, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda bb, hh, j, lens_ref: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), kv_index),
            pl.BlockSpec((1, 1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda bb, hh, j, lens_ref: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(kv_lengths.astype(jnp.int32), q4, k, v)[:, :, 0, :]


def kv_blocks_fetched(kv_lengths, sk: int, bk: int = 512):
    """Modeled K-block fetch count per lane for the length-aware kernel.

    A lane of length L DMAs ``max(ceil(L/bk), 1)`` key blocks (a dead
    lane still pins block 0); the masked kernel always fetches ``sk/bk``.
    Returns an int array shaped like ``kv_lengths``.
    """
    import numpy as np
    lens = np.asarray(kv_lengths)
    bk = min(bk, sk)
    return np.maximum(-(-lens // bk), 1).astype(np.int64)


def kv_pages_fetched(kv_lengths, bt_width: int, page_size: int):
    """Modeled page fetch count per lane for the paged kernels.

    Follows the block-table index map exactly: a lane of length L DMAs
    ``clip(ceil(L/page_size), 1, bt_width)`` pages -- identical to
    :func:`kv_blocks_fetched` when ``page_size == bk``, which is the
    paged-vs-dense bytes/token parity the bench pins.
    """
    import numpy as np
    lens = np.asarray(kv_lengths)
    return np.clip(-(-lens // page_size), 1, bt_width).astype(np.int64)


# ----------------------------------------------------------------------
# paged (block-table) variants: the KV lives in a global page pool
# ----------------------------------------------------------------------
#
# The length-aware kernels above still address a dense per-lane cache
# (B, Hkv, S, D): capacity is partitioned at allocation time.  Here the
# cache is a page POOL (P, Hkv, ps, D) shared by all lanes, and each
# lane's pages are named by a block table (B, T) of physical page ids in
# logical order.  Both the per-lane lengths and the block tables are
# scalar-prefetched, so the k/v index maps can (a) translate the logical
# page index through the table and (b) keep the live-length clamp: pages
# past ceil(len/ps) are never fetched.  Table slot ``j`` holds logical
# positions [j*ps, (j+1)*ps) -- a sliding-window lane rotates pages at
# the table level (slot = position mod window), which is safe because
# the online softmax is permutation-invariant once every slot is live.


def _paged_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, acc_ref,
                  m_ref, l_ref, *, scale: float, ps: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _flash_init(acc_ref, m_ref, l_ref)

    kv_len = len_ref[pl.program_id(0)]

    @pl.when(j * ps < kv_len)                  # skip dead pages entirely
    def _page():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (ps, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (ps, d)
        _flash_block(q, k, v, kv_len, j, ps, acc_ref, m_ref, l_ref)

    @pl.when(j == pl.num_programs(2) - 1)
    def _store():
        _flash_store(o_ref, acc_ref, l_ref)


def decode_attention_paged_pallas(q: jnp.ndarray, k_pages: jnp.ndarray,
                                  v_pages: jnp.ndarray,
                                  block_tables: jnp.ndarray,
                                  kv_lengths: jnp.ndarray, *, scale=None,
                                  interpret: bool = False) -> jnp.ndarray:
    """Block-table decode attention over a global page pool.

    q: (B, H, D); k_pages/v_pages: (P, Hkv, ps, D); block_tables: (B, T)
    int32 physical page ids in logical order; kv_lengths: (B,) int32.
    One grid step streams one page; the table walk is clamped to the
    last live page, so HBM reads scale with the live context at page
    granularity.
    """
    b, h, d = q.shape
    _, hkv, ps, _ = k_pages.shape
    t = block_tables.shape[1]
    assert h % hkv == 0
    group = h // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    kernel = functools.partial(_paged_kernel, scale=scale, ps=ps)
    q4 = q[:, :, None, :]

    def kv_index(bb, hh, j, lens_ref, bt_ref):
        jj = jnp.minimum(j, _last_live_block(lens_ref, bb, ps))
        return (bt_ref[bb, jj], hh // group, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, t),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda bb, hh, j, lens_ref, bt_ref: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, ps, d), kv_index),
            pl.BlockSpec((1, 1, ps, d), kv_index),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, d),
            lambda bb, hh, j, lens_ref, bt_ref: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(kv_lengths.astype(jnp.int32), block_tables.astype(jnp.int32),
      q4, k_pages, v_pages)[:, :, 0, :]


def _paged_q8_kernel(len_ref, bt_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
                     o_ref, acc_ref, m_ref, l_ref, *, scale: float, ps: int,
                     qblock: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _flash_init(acc_ref, m_ref, l_ref)

    kv_len = len_ref[pl.program_id(0)]

    @pl.when(j * ps < kv_len)                  # skip dead pages entirely
    def _page():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = _dequant_tile(kq_ref, ks_ref, qblock)
        v = _dequant_tile(vq_ref, vs_ref, qblock)
        _flash_block(q, k, v, kv_len, j, ps, acc_ref, m_ref, l_ref)

    @pl.when(j == pl.num_programs(2) - 1)
    def _store():
        _flash_store(o_ref, acc_ref, l_ref)


def decode_attention_paged_q8_pallas(q, k_pages, k_scale_pages, v_pages,
                                     v_scale_pages, block_tables,
                                     kv_lengths, *, scale=None,
                                     qblock: int = 32,
                                     interpret: bool = False):
    """Paged quantized-KV decode: q8 pages (values AND scales) are
    fetched through the block table; pages past the live length are
    never fetched.

    k_pages/v_pages: (P, Hkv, ps, D) int8; scale pages:
    (P, Hkv, ps/qblock, 1) f32 per-``qblock``-key scales (``qblock``
    must divide the page size -- pass ``qblock=16`` for the engine's
    default 16-token pages).  Like the dense q8 kernel, this is the
    kernel-level artifact for per-block-scale caches; the MODEL's int8
    paged cache keeps per-token scales and dequantizes at the attention
    read (``attention_decode_paged``), mirroring the dense int8 path.
    """
    b, h, d = q.shape
    _, hkv, ps, _ = k_pages.shape
    t = block_tables.shape[1]
    group = h // hkv
    assert ps % qblock == 0
    scale = float(scale if scale is not None else d ** -0.5)
    srows = ps // qblock
    kernel = functools.partial(_paged_q8_kernel, scale=scale, ps=ps,
                               qblock=qblock)
    q4 = q[:, :, None, :]

    def kv_index(bb, hh, j, lens_ref, bt_ref):
        jj = jnp.minimum(j, _last_live_block(lens_ref, bb, ps))
        return (bt_ref[bb, jj], hh // group, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, t),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda bb, hh, j, lens_ref, bt_ref: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, ps, d), kv_index),
            pl.BlockSpec((1, 1, srows, 1), kv_index),
            pl.BlockSpec((1, 1, ps, d), kv_index),
            pl.BlockSpec((1, 1, srows, 1), kv_index),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, d),
            lambda bb, hh, j, lens_ref, bt_ref: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(kv_lengths.astype(jnp.int32), block_tables.astype(jnp.int32),
      q4, k_pages, k_scale_pages, v_pages, v_scale_pages)[:, :, 0, :]


# ----------------------------------------------------------------------
# quantized-KV variant (q8_0 along the key axis)
# ----------------------------------------------------------------------

def _decode_q8_kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, len_ref, o_ref,
                      acc_ref, m_ref, l_ref, *, scale: float, bk: int,
                      qblock: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _flash_init(acc_ref, m_ref, l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = _dequant_tile(kq_ref, ks_ref, qblock)
    v = _dequant_tile(vq_ref, vs_ref, qblock)
    _flash_block(q, k, v, len_ref[0], j, bk, acc_ref, m_ref, l_ref)

    @pl.when(j == pl.num_programs(2) - 1)
    def _store():
        _flash_store(o_ref, acc_ref, l_ref)


def decode_attention_q8_pallas(q, k_q, k_scale, v_q, v_scale, kv_lengths, *,
                               scale=None, bk: int = 512, qblock: int = 32,
                               interpret: bool = False):
    """Quantized-KV decode.

    k_q/v_q: (B, Hkv, S, D) int8; k_scale/v_scale: (B, Hkv, S/qblock, 1)
    f32 per-32-key-block scales (per head, shared across D).
    """
    b, h, d = q.shape
    _, hkv, sk, _ = k_q.shape
    group = h // hkv
    bk = min(bk, sk)
    assert sk % bk == 0 and bk % qblock == 0
    scale = float(scale if scale is not None else d ** -0.5)
    srows = bk // qblock
    kernel = functools.partial(_decode_q8_kernel, scale=scale, bk=bk,
                               qblock=qblock)
    q4 = q[:, :, None, :]
    return pl.pallas_call(
        kernel,
        grid=(b, h, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bb, hh, j: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1, srows, 1),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1, srows, 1),
                         lambda bb, hh, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1,), lambda bb, hh, j: (bb,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bb, hh, j: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k_q, k_scale, v_q, v_scale, kv_lengths)[:, :, 0, :]


def _decode_q8_la_kernel(len_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
                         o_ref, acc_ref, m_ref, l_ref, *, scale: float,
                         bk: int, qblock: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _flash_init(acc_ref, m_ref, l_ref)

    kv_len = len_ref[pl.program_id(0)]

    @pl.when(j * bk < kv_len)                  # skip dead blocks entirely
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = _dequant_tile(kq_ref, ks_ref, qblock)
        v = _dequant_tile(vq_ref, vs_ref, qblock)
        _flash_block(q, k, v, kv_len, j, bk, acc_ref, m_ref, l_ref)

    @pl.when(j == pl.num_programs(2) - 1)
    def _store():
        _flash_store(o_ref, acc_ref, l_ref)


def decode_attention_q8_lengthaware_pallas(q, k_q, k_scale, v_q, v_scale,
                                           kv_lengths, *, scale=None,
                                           bk: int = 512, qblock: int = 32,
                                           interpret: bool = False):
    """Length-aware quantized-KV decode: q8 tiles (values AND scales)
    past the live length are never fetched."""
    b, h, d = q.shape
    _, hkv, sk, _ = k_q.shape
    group = h // hkv
    bk = min(bk, sk)
    assert sk % bk == 0 and bk % qblock == 0
    scale = float(scale if scale is not None else d ** -0.5)
    srows = bk // qblock
    kernel = functools.partial(_decode_q8_la_kernel, scale=scale, bk=bk,
                               qblock=qblock)
    q4 = q[:, :, None, :]

    def kv_index(bb, hh, j, lens_ref):
        jj = jnp.minimum(j, _last_live_block(lens_ref, bb, bk))
        return (bb, hh // group, jj, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda bb, hh, j, lens_ref: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), kv_index),
            pl.BlockSpec((1, 1, srows, 1), kv_index),
            pl.BlockSpec((1, 1, bk, d), kv_index),
            pl.BlockSpec((1, 1, srows, 1), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda bb, hh, j, lens_ref: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(kv_lengths.astype(jnp.int32), q4, k_q, k_scale, v_q,
      v_scale)[:, :, 0, :]
