"""Jitted wrapper for decode attention, dense + quantized-KV.

``length_aware=True`` (the default for the Pallas path) routes to the
scalar-prefetch kernels whose HBM reads scale with the live cache
length; ``length_aware=False`` keeps the masked full-``max_len`` stream
as the parity reference.  The jnp oracle is unaffected by the flag.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import (
    decode_attention_lengthaware_pallas, decode_attention_paged_pallas,
    decode_attention_paged_q8_pallas, decode_attention_pallas,
    decode_attention_q8_lengthaware_pallas, decode_attention_q8_pallas)
from repro.kernels.decode_attention.ref import (
    decode_attention_paged_q8_ref, decode_attention_paged_ref,
    decode_attention_q8_ref, decode_attention_ref)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "bk",
                                             "length_aware"))
def decode_attention(q, k, v, kv_lengths, *, use_pallas: bool = False,
                     interpret: bool = False, bk: int = 512,
                     length_aware: bool = True):
    if use_pallas:
        if length_aware:
            return decode_attention_lengthaware_pallas(
                q, k, v, kv_lengths, bk=bk, interpret=interpret)
        return decode_attention_pallas(q, k, v, kv_lengths, bk=bk,
                                       interpret=interpret)
    return decode_attention_ref(q, k, v, kv_lengths)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "bk",
                                             "qblock", "length_aware"))
def decode_attention_q8(q, k_q, k_scale, v_q, v_scale, kv_lengths, *,
                        use_pallas: bool = False, interpret: bool = False,
                        bk: int = 512, qblock: int = 32,
                        length_aware: bool = True):
    if use_pallas:
        if length_aware:
            return decode_attention_q8_lengthaware_pallas(
                q, k_q, k_scale, v_q, v_scale, kv_lengths, bk=bk,
                qblock=qblock, interpret=interpret)
        return decode_attention_q8_pallas(q, k_q, k_scale, v_q, v_scale,
                                          kv_lengths, bk=bk, qblock=qblock,
                                          interpret=interpret)
    return decode_attention_q8_ref(q, k_q, k_scale, v_q, v_scale, kv_lengths,
                                   qblock=qblock)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention_paged(q, k_pages, v_pages, block_tables, kv_lengths, *,
                           use_pallas: bool = False,
                           interpret: bool = False):
    """Block-table decode attention over a global page pool.

    The Pallas path is always length-aware (the table walk is clamped to
    the last live page); the jnp oracle gathers the pages first.
    """
    if use_pallas:
        return decode_attention_paged_pallas(q, k_pages, v_pages,
                                             block_tables, kv_lengths,
                                             interpret=interpret)
    return decode_attention_paged_ref(q, k_pages, v_pages, block_tables,
                                      kv_lengths)


@functools.partial(jax.jit,
                   static_argnames=("use_pallas", "interpret", "qblock"))
def decode_attention_paged_q8(q, k_pages, k_scale_pages, v_pages,
                              v_scale_pages, block_tables, kv_lengths, *,
                              use_pallas: bool = False,
                              interpret: bool = False, qblock: int = 32):
    if use_pallas:
        return decode_attention_paged_q8_pallas(
            q, k_pages, k_scale_pages, v_pages, v_scale_pages,
            block_tables, kv_lengths, qblock=qblock, interpret=interpret)
    return decode_attention_paged_q8_ref(
        q, k_pages, k_scale_pages, v_pages, v_scale_pages, block_tables,
        kv_lengths, qblock=qblock)
