"""Jitted wrapper for decode attention, dense + quantized-KV."""

from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import (decode_attention_pallas,
                                                   decode_attention_q8_pallas)
from repro.kernels.decode_attention.ref import (decode_attention_q8_ref,
                                                decode_attention_ref)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "bk"))
def decode_attention(q, k, v, kv_lengths, *, use_pallas: bool = False,
                     interpret: bool = False, bk: int = 512):
    if use_pallas:
        return decode_attention_pallas(q, k, v, kv_lengths, bk=bk,
                                       interpret=interpret)
    return decode_attention_ref(q, k, v, kv_lengths)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "bk",
                                             "qblock"))
def decode_attention_q8(q, k_q, k_scale, v_q, v_scale, kv_lengths, *,
                        use_pallas: bool = False, interpret: bool = False,
                        bk: int = 512, qblock: int = 32):
    if use_pallas:
        return decode_attention_q8_pallas(q, k_q, k_scale, v_q, v_scale,
                                          kv_lengths, bk=bk, qblock=qblock,
                                          interpret=interpret)
    return decode_attention_q8_ref(q, k_q, k_scale, v_q, v_scale, kv_lengths,
                                   qblock=qblock)
