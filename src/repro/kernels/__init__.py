"""Pallas TPU kernels (each: kernel.py + ops.py wrapper + ref.py oracle).

``fma_matmul``      -- compute-path-selectable matmul (paper C2)
``qmatmul``         -- dequant-in-kernel block-quantized matmul (C4)
``mixbench``        -- arithmetic-intensity sweep (C1)
``flash_attention`` -- prefill attention (causal / GQA / sliding window)
``decode_attention``-- split-K decode attention, dense + q8 KV (C3)
"""
