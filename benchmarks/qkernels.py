"""Kernel micro-benchmarks: quantized matmul + path-variant matmul +
decode attention, timed on this host (XLA-CPU via the jnp reference
path; interpret-mode Pallas timings are reported separately because the
interpreter is not a performance proxy).

Derived column: correctness vs the pure-jnp oracle + modeled TPU-v5e
time from the compute-path policy.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_call
from repro.core.compute_path import PathPolicy, matmul_descriptor
from repro.core.device_profile import CMP_170HX, CMP_170HX_NOFMA, TPU_V5E
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_q8,
                                            quantize_kv_q8)
from repro.kernels.fma_matmul import matmul_ref, matmul_variant
from repro.kernels.qmatmul import qmatmul_ref, qmatmul_variant
from repro.quant import quantize

M, K, N = 128, 1024, 512


def rows() -> List[Row]:
    out: List[Row] = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    ref = matmul_ref(x, w)

    for variant in ("mxu", "mul_add"):
        us = time_call(matmul_variant, x, w, variant=variant,
                       interpret=True, iters=2)
        got = matmul_variant(x, w, variant=variant, interpret=True)
        err = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))
        out.append(Row(f"fma_matmul[{variant}]", us, f"rel_err={err:.1e}"))

    for fmt in ("q8_0", "q6_k", "q4_k", "q2_k"):
        qt = quantize(w, fmt)
        us = time_call(qmatmul_variant, x, qt, variant="dequant_dot",
                       interpret=True, iters=2)
        got = qmatmul_variant(x, qt, variant="dequant_dot", interpret=True)
        r = qmatmul_ref(x, qt)
        err = float(jnp.max(jnp.abs(got - r)) / jnp.max(jnp.abs(r)))
        out.append(Row(f"qmatmul[{fmt}/dequant_dot]", us,
                       f"rel_err={err:.1e}"))
    qt8 = quantize(w, "q8_0")
    us = time_call(qmatmul_variant, x, qt8, variant="dot_i8",
                   interpret=True, iters=2)
    out.append(Row("qmatmul[q8_0/dot_i8]", us, "int8-MXU path"))

    # decode attention dense vs q8 KV
    B, H, Hkv, S, D = 2, 8, 2, 1024, 64
    q = jax.random.normal(key, (B, H, D), jnp.float32)
    kc = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, S, D), jnp.float32)
    vc = jax.random.normal(jax.random.PRNGKey(3), (B, Hkv, S, D), jnp.float32)
    lens = jnp.full((B,), S, jnp.int32)
    us = time_call(decode_attention, q, kc, vc, lens, iters=3)
    out.append(Row("decode_attention[dense]", us, f"S={S}"))
    kq, ks = quantize_kv_q8(kc)
    vq, vs = quantize_kv_q8(vc)
    us = time_call(decode_attention_q8, q, kq, ks, vq, vs, lens, iters=3)
    dense = decode_attention(q, kc, vc, lens)
    q8 = decode_attention_q8(q, kq, ks, vq, vs, lens)
    err = float(jnp.max(jnp.abs(q8 - dense)))
    out.append(Row("decode_attention[q8_kv]", us,
                   f"abs_err_vs_dense={err:.3f} traffic=0.27x"))

    # path-policy decisions (the C2 reroute, programmatically)
    desc = matmul_descriptor(M, N, K, "f32")
    for prof in (CMP_170HX, CMP_170HX_NOFMA, TPU_V5E):
        d = PathPolicy(prof).decide(desc)
        out.append(Row(f"path_policy[{prof.name}/f32]", 0.0,
                       f"variant={d.variant} "
                       f"modeled={d.modeled_seconds*1e6:.1f}us "
                       f"bound={d.bound}"))
    return out
