"""Paper Graphs 3-1..3-4 + EX.1: per-precision, per-path compute peaks.

For each (device profile, precision, path) the capability model gives the
achievable T(FL)OPS (the bar heights of the paper's graphs); the mixbench
Pallas kernel is run in interpret mode at a small size as the functional
artifact (the thing you'd run on real hardware), and the headline claims
are checked:

* FP32 default = 0.39 TFLOPS ~ 1/32 of 12.63 theoretical
* FP32 noFMA   = 6.2  TFLOPS ~ 1/2  -> >15x recovery (the paper's title claim)
* FP16 path unaffected by FMA status
* FP64 ~ 1/32 default, halves again without FMA
* INT8 dp4a essentially unthrottled
"""

from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_call
from repro.core.device_profile import (A100_40G, CMP_170HX, CMP_170HX_NOFMA,
                                       TPU_V5E, Path)
from repro.kernels.mixbench import mixbench, sweep_points

_PROFILES = (CMP_170HX, CMP_170HX_NOFMA, A100_40G, TPU_V5E)


def claim_checks() -> List[str]:
    c = CMP_170HX
    n = CMP_170HX_NOFMA
    out = []
    f32_default = c.throughput("f32", Path.FMA)
    f32_nofma = n.throughput("f32", Path.MUL_ADD)
    out.append(f"fp32_recovery={f32_nofma / f32_default:.1f}x"
               f"{'(PASS>15x)' if f32_nofma / f32_default > 15 else '(FAIL)'}")
    frac = n.fraction_of_theoretical("f32", Path.MUL_ADD)
    out.append(f"fp32_nofma_frac={frac:.2f}"
               f"{'(PASS~0.5)' if 0.4 < frac < 0.6 else '(FAIL)'}")
    f16_same = abs(c.throughput("f16", Path.MUL_ADD)
                   - n.throughput("f16", Path.MUL_ADD)) < 1e-6
    out.append(f"fp16_fma_insensitive={'PASS' if f16_same else 'FAIL'}")
    f64_frac = c.throughput("f64", Path.FMA) / c.theoretical["f64"]
    f64_half = n.throughput("f64", Path.MUL_ADD) / c.throughput(
        "f64", Path.FMA)
    out.append(f"fp64_frac={f64_frac:.4f}(~1/32) nofma_ratio={f64_half:.2f}"
               f"{'(PASS<0.6)' if f64_half < 0.6 else '(FAIL)'}")
    return out


def rows() -> List[Row]:
    out: List[Row] = []
    # functional kernel artifact (interpret mode, small size)
    x = jnp.linspace(0, 1, 8192, dtype=jnp.float32)
    for variant in ("fma", "mul_add"):
        us = time_call(mixbench, x, iters=2, variant=variant, interpret=True)
        ref = mixbench(x, iters=64, variant="fma", interpret=True)
        got = mixbench(x, iters=64, variant=variant, interpret=True)
        ok = bool(jnp.allclose(ref, got))
        out.append(Row(f"mixbench_kernel[{variant}]", us,
                       f"allclose={ok}"))
    # modeled bar heights per profile x precision (peak of the sweep)
    for prof in _PROFILES:
        for (prec, path), tf in sorted(prof.peak.items(),
                                       key=lambda kv: (kv[0][0],
                                                       kv[0][1].value)):
            pts = sweep_points(prof, prec, path)
            peak = max(p["gflops"] for p in pts) / 1e3
            out.append(Row(f"compute[{prof.name}/{prec}/{path.value}]",
                           0.0, f"{peak:.2f}TFLOPS"))
    # control group (paper SS1.3.3/SS3.2): PyTorch + GPU-Burn lower f16
    # through the framework FMA path and see only ~6.3 TF -- the paper's
    # framework-limitation finding, reproduced by reading the same
    # capability table through build_paths.
    fw_f16 = CMP_170HX.throughput("f16", Path.FMA)
    out.append(Row("control[pytorch|gpuburn/f16]", 0.0,
                   f"{fw_f16:.1f}TFLOPS(framework path; "
                   f"OpenCL half2 reaches "
                   f"{CMP_170HX.throughput('f16', Path.MUL_ADD):.1f})"))
    for check in claim_checks():
        out.append(Row("claim_3x", 0.0, check))
    return out
