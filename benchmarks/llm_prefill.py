"""Paper Graph 4-1: llama-bench prefill speed, Qwen2.5-1.5B x 6 formats.

Rows per (profile, format): modeled tokens/s + fraction of the paper's
theoretical ceiling (A100-measured x 70/108 SMs).  Claims checked:

* noFMA prefill gains are quantized-only (f32/f16 = 1.00x)
* Q2_K shows the largest gain, ~2.31x
* noFMA prefill lands within the paper's 14-45% of theoretical band
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.device_profile import (A100_40G, CMP_170HX, CMP_170HX_NOFMA)
from repro.core.perf_model import InferencePerfModel

FMTS = ("f32", "f16", "q8_0", "q6_k", "q4_k", "q2_k")


def rows() -> List[Row]:
    out: List[Row] = []
    md = InferencePerfModel(CMP_170HX)
    mn = InferencePerfModel(CMP_170HX_NOFMA)
    ma = InferencePerfModel(A100_40G)
    gains = {}
    fracs = {}
    for fmt in FMTS:
        pd_ = md.prefill(fmt).tokens_per_s
        pn = mn.prefill(fmt).tokens_per_s
        pa = ma.prefill(fmt).tokens_per_s
        theo = md.theoretical_prefill_tps(fmt)
        gains[fmt] = pn / pd_
        fracs[fmt] = pn / theo
        out.append(Row(f"prefill[cmp-170hx/{fmt}]", 0.0,
                       f"{pd_:.0f}t/s"))
        out.append(Row(f"prefill[cmp-170hx-nofma/{fmt}]", 0.0,
                       f"{pn:.0f}t/s gain={pn/pd_:.2f}x "
                       f"frac={pn/theo:.0%}"))
        out.append(Row(f"prefill[a100/{fmt}]", 0.0, f"{pa:.0f}t/s"))
    ok_dense = abs(gains["f32"] - 1) < 0.01 and abs(gains["f16"] - 1) < 0.01
    out.append(Row("claim_4-1_dense_no_gain", 0.0,
                   f"f32={gains['f32']:.2f}x f16={gains['f16']:.2f}x "
                   f"{'(PASS)' if ok_dense else '(FAIL)'}"))
    best = max(gains, key=gains.get)
    ok_q2 = best == "q2_k" and 2.0 < gains["q2_k"] < 2.6
    out.append(Row("claim_4-1_q2k_max_gain", 0.0,
                   f"best={best} gain={gains['q2_k']:.2f}x (paper 2.31x) "
                   f"{'(PASS)' if ok_q2 else '(FAIL)'}"))
    in_band = all(0.14 <= fracs[f] <= 0.45 for f in FMTS)
    out.append(Row("claim_4-1_band_14_45", 0.0,
                   " ".join(f"{f}={fracs[f]:.0%}" for f in FMTS)
                   + (" (PASS)" if in_band else " (FAIL)")))
    return out
