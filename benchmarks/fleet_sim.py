"""Beyond-paper: trace-driven fleet simulation (SS6.2 made dynamic).

Replays one seeded bursty trace against the planner's disaggregated
mixed fleet (2xA100 prefill + 8x CMP-170HX-noFMA decode) and both
homogeneous same-hardware baselines, reporting tail latency, power and
$/Mtok -- the dimensions the static planner cannot see.  A final row
cross-checks the simulator's steady state against ``plan_fleet`` on a
constant-rate trace (the two share one phase model, so they must
agree).
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.fleet import (FleetSim, LeastLoadedRouter, LengthDist, NodeSpec,
                         PreemptionPolicy, bursty_trace, constant_trace,
                         fleet_from_plan, multimodel_trace, poisson_trace,
                         shared_prefix_trace)
from repro.serving import Workload, plan_fleet

WL = Workload(prompt_len=512, gen_len=128, fmt="q8_0")
SLO = dict(ttft_slo_s=2.0, tpot_slo_s=0.05)
LANES = 4


def _sim_row(tag: str, report) -> Row:
    return Row(f"fleet_sim[{tag}]", 0.0,
               f"goodput={report.goodput_rps:.2f}req/s "
               f"ttft_p50={report.ttft_p50_s * 1e3:.0f}ms "
               f"ttft_p99={report.ttft_p99_s * 1e3:.0f}ms "
               f"tpot_p99={report.tpot_p99_s * 1e3:.2f}ms "
               f"watts={report.avg_watts:.0f} "
               f"$per_mtok={report.usd_per_mtok:.3f}")


def rows() -> List[Row]:
    out: List[Row] = []
    plan = plan_fleet({"a100-40g": 2, "cmp-170hx-nofma": 8}, WL)
    trace = bursty_trace(rate_on_rps=60.0, duration_s=120.0, seed=0,
                         prompt=LengthDist(WL.prompt_len),
                         gen=LengthDist(WL.gen_len))

    mixed = FleetSim(fleet_from_plan(plan, decode_lanes=LANES), trace,
                     fmt=WL.fmt, **SLO).run()
    homo_a = FleetSim([NodeSpec("a100-40g", 2, "both", LANES)], trace,
                      fmt=WL.fmt, **SLO).run()
    homo_c = FleetSim([NodeSpec("cmp-170hx-nofma", 8, "both", LANES)],
                      trace, fmt=WL.fmt, **SLO).run()
    out.append(_sim_row("bursty_mixed_2xA100+8xCMP", mixed))
    out.append(_sim_row("bursty_homog_2xA100", homo_a))
    out.append(_sim_row("bursty_homog_8xCMP", homo_c))
    gain = mixed.goodput_rps / max(homo_a.goodput_rps, homo_c.goodput_rps)
    out.append(Row("fleet_sim_goodput_gain", 0.0,
                   f"{gain:.2f}x_vs_best_homogeneous"))

    steady = FleetSim(
        fleet_from_plan(plan),
        constant_trace(plan.requests_per_s * 1.2, 60.0,
                       WL.prompt_len, WL.gen_len),
        fmt=WL.fmt).run()
    out.append(Row("fleet_sim_vs_planner", 0.0,
                   f"sim={steady.requests_per_s:.2f}req/s "
                   f"plan={plan.requests_per_s:.2f}req/s "
                   f"ratio={steady.requests_per_s / plan.requests_per_s:.3f}"))
    out.extend(preemption_rows())
    out.extend(prefix_rows())
    out.extend(multimodel_rows())
    out.extend(fault_rows())
    return out


def preemption_rows() -> List[Row]:
    """Page-exhaustion preemption relieving a saturated board.

    One decode board gets a page pool too small for its lane count (its
    KV grows over-committed mid-trace and spills over the PCIe 1.1 x4
    host link at ~1000x HBM cost); a peer board has headroom.  With
    migration enabled the router sheds the longest resident decodes to
    the peer, paying the page-granular transfer instead of the spill.
    """
    specs = [NodeSpec("a100-40g", 1, "prefill"),
             NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                      kv_pool_pages=40, page_size=16),
             NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                      kv_pool_pages=512, page_size=16)]
    trace = poisson_trace(3.0, 40.0, seed=2,
                          prompt=LengthDist(256, cv=0.3),
                          gen=LengthDist(128, cv=0.5))
    base = FleetSim(specs, trace, fmt=WL.fmt).run()
    mig = FleetSim(specs, trace, fmt=WL.fmt,
                   preemption=PreemptionPolicy()).run()
    return [
        Row("fleet_preempt[spill_no_migration]", 0.0,
            f"completed={base.completed}/{base.offered} "
            f"tpot_p99={base.tpot_p99_s * 1e3:.2f}ms "
            f"preemptions={base.preemptions}"),
        Row("fleet_preempt[page_exhaustion_migration]", 0.0,
            f"completed={mig.completed}/{mig.offered} "
            f"tpot_p99={mig.tpot_p99_s * 1e3:.2f}ms "
            f"preemptions={mig.preemptions} "
            f"pages_migrated={mig.pages_migrated} "
            f"tpot_p99_gain={base.tpot_p99_s / mig.tpot_p99_s:.2f}x"),
    ]


def prefix_rows() -> List[Row]:
    """Shared-prefix trace on a page-starved decode board, KV prefix
    sharing on vs off.

    Every request opens with its family's common template head (50% of
    the mean prompt), so with sharing ON the board charges a resident
    family's prefix pages ONCE instead of once per lane -- the same
    trace fits more concurrent decodes in the same pool, over-commit
    spills recede, and the decode tail tightens.  With sharing OFF the
    identical workload over-commits and pays the ~1000x host-link spill
    penalty (the engine-measured counterpart is the bench's
    ``prefix`` section in BENCH_decode.json).
    """
    def fleet(sharing):
        return [NodeSpec("a100-40g", 1, "prefill"),
                NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                         kv_pool_pages=64, page_size=16,
                         prefix_sharing=sharing)]

    # two heavyweight templates (192 of ~256 prompt tokens = 12 of a
    # slot's ~24 pages) at a rate that keeps several same-family
    # decodes resident at once -- the regime the radix cache targets
    trace = shared_prefix_trace(
        poisson_trace(16.0, 40.0, seed=2, prompt=LengthDist(256, cv=0.3),
                      gen=LengthDist(128, cv=0.5)),
        prefix_len=192, n_prefixes=2, seed=1)
    off = FleetSim(fleet(False), trace, fmt=WL.fmt).run()
    on = FleetSim(fleet(True), trace, fmt=WL.fmt).run()
    return [
        Row("fleet_prefix[sharing_off]", 0.0,
            f"completed={off.completed}/{off.offered} "
            f"goodput={off.goodput_rps:.2f}req/s "
            f"tpot_p99={off.tpot_p99_s * 1e3:.2f}ms"),
        Row("fleet_prefix[sharing_on]", 0.0,
            f"completed={on.completed}/{on.offered} "
            f"goodput={on.goodput_rps:.2f}req/s "
            f"tpot_p99={on.tpot_p99_s * 1e3:.2f}ms "
            f"goodput_gain={on.goodput_rps / off.goodput_rps:.2f}x "
            f"tpot_p99_gain={off.tpot_p99_s / on.tpot_p99_s:.2f}x"),
    ]


def multimodel_rows() -> List[Row]:
    """Swap-cost vs resident-affinity routing on a two-model trace.

    Two CMP decode boards, each 2 GB -- too small to co-host both
    models' weights -- one seeded with each model.  The affinity-aware
    router keeps every request on the board where its model is HOT
    (zero swaps); the affinity-blind baseline load-balances obliviously,
    thrashing weights over the PCIe 1.1 x4 link and shrinking the page
    pools under the swapped-in weights -- the decode tail pays for it.
    Per-model rows carry the tokens/joule accounting.
    """
    from repro.core.perf_model import QWEN25_0P5B, QWEN25_1P5B

    model_specs = {"qwen2.5-1.5b": QWEN25_1P5B,
                   "qwen2.5-0.5b": QWEN25_0P5B}

    def fleet():
        return [NodeSpec("a100-40g", 1, "prefill",
                         model_ids=tuple(model_specs), hbm_gb=40.0),
                NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                         model_ids=tuple(model_specs),
                         resident=("qwen2.5-1.5b",), hbm_gb=2.0,
                         page_size=16),
                NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                         model_ids=tuple(model_specs),
                         resident=("qwen2.5-0.5b",), hbm_gb=2.0,
                         page_size=16)]

    trace = multimodel_trace(
        poisson_trace(2.0, 60.0, seed=3, prompt=LengthDist(256, cv=0.3),
                      gen=LengthDist(128, cv=0.4)),
        {"qwen2.5-1.5b": 1, "qwen2.5-0.5b": 1}, seed=1)
    aware = FleetSim(fleet(), trace, fmt=WL.fmt, model_specs=model_specs,
                     router=LeastLoadedRouter()).run()
    blind = FleetSim(fleet(), trace, fmt=WL.fmt, model_specs=model_specs,
                     router=LeastLoadedRouter(model_aware=False)).run()
    rows = [
        Row("fleet_multimodel[affinity_aware]", 0.0,
            f"completed={aware.completed}/{aware.offered} "
            f"model_swaps={aware.model_swaps} "
            f"swap_bytes={aware.swap_bytes / 1e9:.2f}GB "
            f"tpot_p99={aware.tpot_p99_s * 1e3:.2f}ms"),
        Row("fleet_multimodel[affinity_blind]", 0.0,
            f"completed={blind.completed}/{blind.offered} "
            f"model_swaps={blind.model_swaps} "
            f"swap_bytes={blind.swap_bytes / 1e9:.2f}GB "
            f"tpot_p99={blind.tpot_p99_s * 1e3:.2f}ms "
            f"tail_vs_aware={blind.tpot_p99_s / aware.tpot_p99_s:.1f}x"),
    ]
    for mid, tpot_p50, toks, tpj in aware.per_model:
        rows.append(Row(f"fleet_multimodel[per_model/{mid}]", 0.0,
                        f"tpot_p50={tpot_p50 * 1e3:.2f}ms "
                        f"gen_tokens={toks} tokens_per_joule={tpj:.1f}"))
    return rows


def fault_reports():
    """One deterministic fault scenario, simulated three ways.

    A mixed fleet (1 prefill board + 3 CMP decode boards) serves a
    40 s trace while the fault plan kills one decode board mid-trace,
    thermally derates another for a window, stalls the third briefly,
    and flaps the prefill board's host link.  Three decode boards
    matter for the straggler monitor: with two, the fleet median is
    the mean of the pair and a derated board converges to exactly
    ``threshold`` x median without ever crossing it.  Returns
    ``(fault_free, with_recovery, without_recovery)`` reports: with a
    :class:`RecoveryPolicy` the crashed board's live lanes resume from
    checkpoints (or replay from the prompt) and orphaned requests
    retry with backoff; without one, whatever the crash touched is
    LOST.  Shared by ``fault_rows`` and the BENCH_decode.json
    ``faults`` gate.
    """
    from repro.fleet import (FaultEvent, FaultPlan, RecoveryPolicy,
                             RetryPolicy)

    specs = [NodeSpec("a100-40g", 1, "prefill"),
             NodeSpec("cmp-170hx-nofma", 3, "decode", decode_lanes=8,
                      kv_pool_pages=512, page_size=16)]
    trace = poisson_trace(6.0, 40.0, seed=2,
                          prompt=LengthDist(256, cv=0.3),
                          gen=LengthDist(512, cv=0.5))
    plan = FaultPlan(events=(
        FaultEvent("derate", node="cmp-170hx-nofma/decode#1", at_s=5.0,
                   factor=3.0, duration_s=12.0),
        FaultEvent("crash", node="cmp-170hx-nofma/decode#2", at_s=20.1),
        FaultEvent("transient", node="cmp-170hx-nofma/decode#3",
                   at_s=30.0, duration_s=0.25),
    )) + FaultPlan.flap("a100-40g/prefill#0", t0=8.0, period_s=2.0,
                        n_flaps=3, factor=4.0)
    slo = dict(ttft_slo_s=2.0, tpot_slo_s=0.08)
    recovery = RecoveryPolicy(checkpoint_interval_s=0.5,
                              retry=RetryPolicy(max_attempts=4))
    base = FleetSim(specs, trace, fmt=WL.fmt, **slo).run()
    rec = FleetSim(specs, trace, fmt=WL.fmt, faults=plan,
                   recovery=recovery, **slo).run()
    norec = FleetSim(specs, trace, fmt=WL.fmt, faults=plan, **slo).run()
    return base, rec, norec


def fault_rows() -> List[Row]:
    """Crash/derate/flap scenario: goodput and decode tail with and
    without checkpointed recovery, against the fault-free baseline."""
    base, rec, norec = fault_reports()

    def fmt(r):
        return (f"completed={r.completed}/{r.offered} "
                f"goodput={r.goodput_rps:.2f}req/s "
                f"tpot_p99={r.tpot_p99_s * 1e3:.2f}ms")

    return [
        Row("fleet_faults[fault_free]", 0.0, fmt(base)),
        Row("fleet_faults[crash+flap_with_recovery]", 0.0,
            fmt(rec) + f" crashes={rec.crashes} "
            f"recovered={rec.recovered_lanes} "
            f"replayed={rec.replayed_from_prompt} retries={rec.retries} "
            f"lost={rec.requests_lost} "
            f"goodput_vs_base={rec.goodput_rps / base.goodput_rps:.2f}"),
        Row("fleet_faults[crash+flap_no_recovery]", 0.0,
            fmt(norec) + f" lost={norec.requests_lost} "
            f"goodput_vs_base={norec.goodput_rps / base.goodput_rps:.2f}"),
        Row("fleet_faults[derate_detection]", 0.0,
            f"straggler_flags={len(rec.derate_detected)} "
            + (rec.derate_detected[0].replace(",", ";")
               if rec.derate_detected else "none")),
    ]


def execution_replay_rows(dispatch_n: int = 8) -> List[Row]:
    """Execution-backed rows: replay a tiny trace on the REAL engine with
    the multi-token dispatch and report the host-dispatch economics the
    pure simulator cannot see.  Not part of ``rows()`` (it runs the jax
    engine); invoked via ``python -m benchmarks.fleet_sim --execution``.
    """
    import jax
    from repro.configs import get_config
    from repro.fleet import FleetRequest, run_trace_on_engine
    from repro.models import build_model

    cfg = get_config("qwen2.5-1.5b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    trace = [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=6 + i,
                          gen_len=8) for i in range(6)]
    exe = run_trace_on_engine(trace, cfg, params, n_lanes=2, max_len=32,
                              dispatch_n=dispatch_n)
    base = run_trace_on_engine(trace, cfg, params, n_lanes=2, max_len=32,
                               dispatch_n=1)
    assert exe.gen_by_uid == base.gen_by_uid, "dispatch-size variance"
    return [Row(f"fleet_exec[dispatch_n={dispatch_n}]", 0.0,
                f"gen={exe.gen_tokens}tok "
                f"dispatches={exe.decode_dispatches} "
                f"disp_per_tok={exe.decode_dispatches / exe.gen_tokens:.3f} "
                f"baseline={base.decode_dispatches / base.gen_tokens:.3f} "
                f"reduction={base.decode_dispatches / exe.decode_dispatches:.1f}x")]


if __name__ == "__main__":
    import sys
    mods = rows() + (execution_replay_rows()
                     if "--execution" in sys.argv else [])
    print("name,us_per_call,derived")
    for r in mods:
        print(f"{r.name},{r.us_per_call:.1f},"
              f"{str(r.derived).replace(',', ';')}")
