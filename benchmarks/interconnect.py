"""Paper Graph EX.2: interconnect bandwidth (PCIe 1.1 x4 -> TPU ICI).

The CMP 170HX's PCIe 1.1 x4 (~1 GB/s) is its deployment Achilles' heel
(model load time, multi-board scaling); the TPU target's ICI is three
orders of magnitude faster, which is what makes the multi-pod collective
roofline term viable at all.  Rows: per-device link bandwidths + derived
model-load and all-reduce time for the paper's 1.5B model.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.device_profile import (A100_40G, CMP_170HX, TPU_V5E)
from repro.core.perf_model import QWEN25_1P5B
from repro.quant.formats import bytes_per_weight


def rows() -> List[Row]:
    out: List[Row] = []
    model_bytes = QWEN25_1P5B.params_total * bytes_per_weight("q8_0")
    for prof in (CMP_170HX, A100_40G, TPU_V5E):
        bw = prof.total_interconnect_gbps() * 1e9
        load_s = model_bytes / bw
        out.append(Row(f"interconnect[{prof.name}]", 0.0,
                       f"{prof.total_interconnect_gbps():.0f}GB/s "
                       f"load_1.5B_q8={load_s:.2f}s"))
    # ring all-reduce of 1 GiB grads across 256 chips on ICI
    n, payload = 256, 1 << 30
    ring = 2 * (n - 1) / n * payload / (TPU_V5E.interconnect_gbps * 1e9)
    out.append(Row("allreduce_1GiB_256chips", 0.0, f"{ring*1e3:.1f}ms"))
    return out
