"""Shared benchmark plumbing: timing + CSV row conventions.

Every benchmark module exposes ``rows() -> List[Row]``; ``run.py``
aggregates and prints ``name,us_per_call,derived`` CSV.  ``us_per_call``
is measured on this CPU host (harness cost); ``derived`` carries the
modeled/derived quantity the paper's artifact is about (TFLOPS, tokens/s,
tokens/W, ...).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3,
              **kwargs) -> float:
    """Median wall-time per call in microseconds (jits on the warmup)."""
    import jax
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
