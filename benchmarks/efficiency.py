"""Paper Graph 4-3: decode power efficiency (tokens/s/W).

Claims checked:

* CMP 170HX decode efficiency is A100-comparable (within 0.6-1.2x of the
  A100-scaled theoretical efficiency) for the memory-bound formats
  (F32/F16/Q8) -- the paper's "energy efficiency consistent with GA100".
* the noFMA build does NOT improve efficiency (the mul+add path costs
  ~2 instructions/MAC); the paper measured a small decline.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.device_profile import (A100_40G, CMP_170HX, CMP_170HX_NOFMA)
from repro.core.energy import efficiency
from repro.core.perf_model import InferencePerfModel

FMTS = ("f32", "f16", "q8_0", "q6_k", "q4_k", "q2_k")


def rows() -> List[Row]:
    out: List[Row] = []
    ratios = {}
    declines = {}
    for fmt in FMTS:
        e_c = efficiency(CMP_170HX, fmt)
        e_n = efficiency(CMP_170HX_NOFMA, fmt)
        e_a = efficiency(A100_40G, fmt)
        ratios[fmt] = e_c.tokens_per_joule / e_a.tokens_per_joule
        declines[fmt] = e_n.tokens_per_joule / e_c.tokens_per_joule
        out.append(Row(f"efficiency[cmp/{fmt}]", 0.0,
                       f"{e_c.tokens_per_joule:.2f}t/J @{e_c.watts:.0f}W "
                       f"vsA100={ratios[fmt]:.2f}x"))
        out.append(Row(f"efficiency[cmp-nofma/{fmt}]", 0.0,
                       f"{e_n.tokens_per_joule:.2f}t/J "
                       f"vs_default={declines[fmt]:.2f}x"))
    comparable = all(0.6 <= ratios[f] <= 1.2 for f in ("f32", "f16", "q8_0"))
    out.append(Row("claim_4-3_a100_comparable", 0.0,
                   " ".join(f"{f}={ratios[f]:.2f}" for f in
                            ("f32", "f16", "q8_0"))
                   + (" (PASS)" if comparable else " (FAIL)")))
    no_gain = all(declines[f] <= 1.02 for f in FMTS)
    out.append(Row("claim_4-3_nofma_no_efficiency_gain", 0.0,
                   " ".join(f"{f}={declines[f]:.2f}" for f in FMTS)
                   + (" (PASS)" if no_gain else " (FAIL)")))
    return out
