"""Beyond-paper: heterogeneous prefill/decode disaggregation (SS6.2 realized).

Plans a mixed fleet (A100s for compute-bound prefill, reclaimed CMP
boards for bandwidth-bound decode) and compares requests/s and $/Mtok
against homogeneous fleets of the same hardware budget.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.serving.disaggregation import (Workload, homogeneous_baseline,
                                          plan_fleet)


def rows() -> List[Row]:
    out: List[Row] = []
    wl = Workload(prompt_len=512, gen_len=128, fmt="q8_0")
    mixed = plan_fleet({"a100-40g": 2, "cmp-170hx-nofma": 8}, wl)
    out.append(Row("fleet[mixed_2xA100+8xCMP]", 0.0,
                   f"{mixed.requests_per_s:.2f}req/s "
                   f"${mixed.usd_per_mtok:.3f}/Mtok roles="
                   + ",".join(f"{a.profile}:{a.role}"
                              for a in mixed.assignments)))
    homo_a = homogeneous_baseline("a100-40g", 2, wl)
    homo_c = homogeneous_baseline("cmp-170hx-nofma", 8, wl)
    out.append(Row("fleet[homog_2xA100]", 0.0,
                   f"{homo_a.requests_per_s:.2f}req/s "
                   f"${homo_a.usd_per_mtok:.3f}/Mtok"))
    out.append(Row("fleet[homog_8xCMP]", 0.0,
                   f"{homo_c.requests_per_s:.2f}req/s "
                   f"${homo_c.usd_per_mtok:.3f}/Mtok"))
    gain = mixed.requests_per_s / max(homo_a.requests_per_s,
                                      homo_c.requests_per_s)
    out.append(Row("fleet_disaggregation_gain", 0.0,
                   f"{gain:.2f}x_vs_best_homogeneous"))
    return out
