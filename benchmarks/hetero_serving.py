"""Beyond-paper: heterogeneous prefill/decode disaggregation (SS6.2 realized).

Plans a mixed fleet (A100s for compute-bound prefill, reclaimed CMP
boards for bandwidth-bound decode) and compares requests/s and $/Mtok
against homogeneous fleets of the same hardware budget.  Each analytic
row is paired with a simulator-derived latency row (`repro.fleet` on a
near-capacity Poisson trace): the planner says what the fleet
sustains, the simulator says what a request *feels*.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.fleet import FleetSim, LengthDist, fleet_from_plan, poisson_trace
from repro.serving.disaggregation import (FleetPlan, Workload,
                                          homogeneous_baseline, plan_fleet)


def _sim_latency_row(tag: str, plan: FleetPlan, wl: Workload) -> Row:
    """TTFT/TPOT tails of this plan's fleet at 80% of planned capacity."""
    trace = poisson_trace(rate_rps=0.8 * plan.requests_per_s,
                          duration_s=60.0, seed=0,
                          prompt=LengthDist(wl.prompt_len),
                          gen=LengthDist(wl.gen_len))
    rep = FleetSim(fleet_from_plan(plan, decode_lanes=4), trace,
                   fmt=wl.fmt).run()
    return Row(f"fleet_latency[{tag}]", 0.0,
               f"ttft_p50={rep.ttft_p50_s * 1e3:.0f}ms "
               f"ttft_p99={rep.ttft_p99_s * 1e3:.0f}ms "
               f"tpot_p99={rep.tpot_p99_s * 1e3:.2f}ms "
               f"sim_{rep.requests_per_s:.2f}req/s")


def rows() -> List[Row]:
    out: List[Row] = []
    wl = Workload(prompt_len=512, gen_len=128, fmt="q8_0")
    mixed = plan_fleet({"a100-40g": 2, "cmp-170hx-nofma": 8}, wl)
    out.append(Row("fleet[mixed_2xA100+8xCMP]", 0.0,
                   f"{mixed.requests_per_s:.2f}req/s "
                   f"${mixed.usd_per_mtok:.3f}/Mtok roles="
                   + ",".join(f"{a.profile}:{a.role}"
                              for a in mixed.assignments)))
    out.append(_sim_latency_row("mixed_2xA100+8xCMP", mixed, wl))
    homo_a = homogeneous_baseline("a100-40g", 2, wl)
    homo_c = homogeneous_baseline("cmp-170hx-nofma", 8, wl)
    out.append(Row("fleet[homog_2xA100]", 0.0,
                   f"{homo_a.requests_per_s:.2f}req/s "
                   f"${homo_a.usd_per_mtok:.3f}/Mtok"))
    out.append(_sim_latency_row("homog_2xA100", homo_a, wl))
    out.append(Row("fleet[homog_8xCMP]", 0.0,
                   f"{homo_c.requests_per_s:.2f}req/s "
                   f"${homo_c.usd_per_mtok:.3f}/Mtok"))
    out.append(_sim_latency_row("homog_8xCMP", homo_c, wl))
    gain = mixed.requests_per_s / max(homo_a.requests_per_s,
                                      homo_c.requests_per_s)
    out.append(Row("fleet_disaggregation_gain", 0.0,
                   f"{gain:.2f}x_vs_best_homogeneous"))
    return out
