"""Roofline report: dryrun_results.jsonl -> EXPERIMENTS.md tables.

Usage:  PYTHONPATH=src python -m benchmarks.roofline_report \
            [--in dryrun_results.jsonl] [--mesh 16x16]

Per (arch x shape) cell: the three roofline terms (seconds), dominant
bottleneck, 6ND/HLO utilization ratio, memory fit, and a one-line
suggestion for the dominant term.
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.core.roofline import analyze

SUGGEST = {
    "compute": ("raise arithmetic efficiency: larger microbatch / fuse "
                "epilogues / bf16-ize f32 epilogue ops"),
    "memory": ("cut HBM traffic: quantize weights (decode) or widen remat "
               "granularity (train)"),
    "collective": ("re-shard: weight-stationary layout / overlap via "
                   "microbatch pipelining / int8-compress the cross-pod "
                   "axis"),
}


def load(path: str, mesh: str) -> List[Dict]:
    rows = []
    for line in open(path):
        line = line.strip()
        if not line:
            continue
        r = json.loads(line)
        if r.get("mesh") == mesh and "error" not in r:
            rows.append(r)
    return rows


def to_terms(r: Dict):
    return analyze(
        cell=f"{r['arch']}/{r['shape']}", chips=r["chips"],
        hlo_flops=r["hlo_flops"], hlo_bytes=r["hlo_bytes"],
        collective_bytes=r["collective_bytes"],
        model_flops=r["model_flops"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="dryrun_results.jsonl")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--markdown", action="store_true", default=True)
    args = ap.parse_args()
    rows = load(args.inp, args.mesh)
    print(f"| cell | kind | compute s | memory s | collective s | dominant "
          f"| 6ND/HLO | roofline | fits 16G | method |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    ranked = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        t = to_terms(r)
        ranked.append((t.roofline_fraction, t.dominant, r, t))
        print(f"| {t.cell} | {r['kind']} | {t.t_compute_s:.3e} | "
              f"{t.t_memory_s:.3e} | {t.t_collective_s:.3e} | {t.dominant} "
              f"| {t.useful_flops_ratio:.2f} | {t.roofline_fraction:.1%} | "
              f"{'Y' if r.get('fits_16g') else 'N'} | "
              f"{r.get('cost_method', '?')} |")
    print()
    if ranked:
        worst = min(ranked, key=lambda x: x[0])
        coll = [x for x in ranked if x[1] == "collective"]
        print(f"worst roofline fraction: {worst[3].cell} "
              f"({worst[0]:.1%}, {worst[1]}-dominant)")
        if coll:
            most_coll = max(coll, key=lambda x: x[3].t_collective_s)
            print(f"most collective-bound: {most_coll[3].cell}")
        for frac, dom, r, t in ranked:
            if frac < 0.25:
                print(f"  {t.cell}: {dom}-bound -> {SUGGEST[dom]}")


if __name__ == "__main__":
    main()
