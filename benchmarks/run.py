"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:

  compute_sweep  -> Graphs 3-1..3-4, EX.1 (per-path compute peaks)
  membw          -> Graph 3-5 (HBM bandwidth)
  interconnect   -> Graph EX.2 (PCIe/ICI)
  llm_prefill    -> Graph 4-1 (prefill t/s x quant formats)
  llm_decode     -> Graph 4-2 (decode t/s x quant formats)
  efficiency     -> Graph 4-3 (tokens/W)
  cost_model     -> Tables 1-1/1-2 (fleet economics)
  hetero_serving -> SS6.2 operationalized (beyond paper)
  fleet_sim      -> SS6.2 made dynamic (trace-driven fleet simulator)
  qkernels       -> kernel micro-benchmarks (Pallas artifacts)
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (compute_sweep, cost_model, efficiency, fleet_sim,
                            hetero_serving, interconnect, llm_decode,
                            llm_prefill, membw, qkernels)
    modules = [compute_sweep, membw, interconnect, llm_prefill, llm_decode,
               efficiency, cost_model, hetero_serving, fleet_sim, qkernels]
    print("name,us_per_call,derived")
    failures = 0
    for mod in modules:
        try:
            for row in mod.rows():
                derived = str(row.derived).replace(",", ";")
                print(f"{row.name},{row.us_per_call:.1f},{derived}")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{mod.__name__},0.0,ERROR")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
