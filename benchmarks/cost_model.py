"""Paper Tables 1-1/1-2 + SS6.2: fleet economics of reclaimed mining GPUs.

Rows: sales-volume estimates per scenario (Appendix Ex.1 methodology),
aggregate stranded FP16 compute, and $/Mtok of decode service on CMP
boards vs A100 -- the paper's cost argument quantified.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.device_profile import A100_40G, CMP_170HX_NOFMA
from repro.core.energy import (SCENARIOS, efficiency, estimate_sales,
                               stranded_fp16_tflops)


def rows() -> List[Row]:
    out: List[Row] = []
    for sc in SCENARIOS:
        units = estimate_sales(sc)
        out.append(Row(f"sales[scenario_{sc}]", 0.0,
                       f"total={units['total']:,.0f}units "
                       f"170hx={units['cmp-170hx']:,.0f}"))
        out.append(Row(f"stranded_fp16[scenario_{sc}]", 0.0,
                       f"{stranded_fp16_tflops(sc)/1e6:.1f}EFLOPS"))
    # paper Table 1-2 reference totals: ~582k / ~640k / ~463k
    ref = {"A": 582714, "B": 640127, "C": 463133}
    ok = all(abs(estimate_sales(s)["total"] - ref[s]) / ref[s] < 0.02
             for s in ref)
    out.append(Row("claim_1-2_sales_totals", 0.0,
                   "PASS" if ok else "FAIL"))
    for fmt in ("q8_0", "q4_k"):
        e_c = efficiency(CMP_170HX_NOFMA, fmt)
        e_a = efficiency(A100_40G, fmt)
        out.append(Row(f"usd_per_mtok[{fmt}]", 0.0,
                       f"cmp=${e_c.usd_per_mtok:.3f} "
                       f"a100=${e_a.usd_per_mtok:.3f} "
                       f"saving={e_a.usd_per_mtok/e_c.usd_per_mtok:.1f}x"))
    return out
