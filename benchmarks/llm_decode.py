"""Paper Graph 4-2: llama-bench decode speed, Qwen2.5-1.5B x 6 formats.

Decode is bandwidth-bound; the theoretical ceiling is the paper's
A100-measured x (1493/1555) scaling.  Claims checked:

* default build lands in the 39-78% band
* noFMA lands in the 50-78% band
* f32/f16/q8_0 decode is FMA-insensitive

Beyond the model rows, ``decode_path_metrics`` (and ``python -m
benchmarks.llm_decode``, see ``make bench-smoke``) measures the REAL
serving decode path on a smoke config and emits ``BENCH_decode.json``:

* ``dispatches_per_token`` -- Python dispatches per generated token for
  the multi-token engine vs the per-token baseline (the host-sync cost
  the refactor removes);
* ``bytes_read_per_token`` at 25/50/100% lane occupancy -- KV bytes the
  length-aware kernel DMAs per generated token vs the masked kernel's
  occupancy-blind full-``max_len`` stream (block fetch counts are exact
  by construction of the kernel's index map, costed at the paper's KV
  layout);
* ``greedy_token_exact`` -- the batched engine reproduces the per-token
  engine's greedy stream token for token.

The ``paged`` section compares the page-pool cache against the dense
fixed-lane layout at EQUAL KV memory: admission capacity at 25/50/100%
mean live context (paged admits by bytes, dense by lanes), token-exact
parity of the paged engine, and bytes-read/token parity of the
block-table kernel vs the length-aware dense kernel at full occupancy
(``make bench-smoke`` gates on <= 10%).

The ``migration`` section exercises evict-and-replay preemption on the
paged engine (checkpoint a lane mid-decode, release its pages, restore
through the normal reserve/alloc route): resumed greedy AND temperature
streams must be token-exact vs the unpreempted run, and the
transfer-cost model prices ``ceil(ctx/page_size)`` pages over the CMP
170HX's PCIe 1.1 x4 host link (``make bench-smoke`` gates on resume
exactness and non-zero migration counters).

The ``multimodel`` section serves TWO models through one
``MultiModelServeEngine`` on a roomy and a tight HBM budget: per-model
streams must be bit-identical to single-model engines (greedy AND
temperature), token counts budget invariant, and the tight budget must
show real weight-swap churn (``make bench-smoke`` gates on all three).

The ``telemetry`` section exercises the ``repro.obs`` layer on the same
decode path: tracing must be exactness-neutral (identical token streams
and compile counters traced vs untraced), the ``decode.dispatch`` span
count must equal the dispatch counter, per-phase span durations are
summarised (p50/p99), and the sim-to-real calibration gate
(:func:`repro.obs.predict_replay` vs a measured
``run_trace_on_engine`` replay) must fit within tolerance -- while a
deliberately perturbed phase model must FAIL the same gate
(``make bench-smoke`` gates on all of it).

The ``faults`` section checks the fault-tolerance contract ("a crash
costs time, never tokens") on both layers: the engine oracle
(:func:`repro.fleet.execution.validate_recovery_exactness`) crashes a
node mid-replay and requires checkpointed lanes AND
replayed-from-prompt lanes to reproduce the undisturbed greedy streams
bit for bit; the fleet simulator runs the shared
``benchmarks.fleet_sim.fault_reports`` scenario (derate + link flap +
crash + transient) and requires zero lost requests, >= 90% of the
fault-free goodput, and at least one straggler-monitor flag -- while
the same scenario WITHOUT a recovery policy must visibly lose requests
(``make bench-smoke`` gates on all of it).

The ``slo_tracing`` section pins the request-scoped observability
stack: full stack on (tracing + flight ring + SLO controller) must keep
bit-identical streams at <5% steady-state decode overhead; a crash
replay under one shared tracer must yield a gap-free
``RequestTimeline`` for every request (checkpointed lanes spanning both
engines), exactly one flight-recorder dump, and a demonstrable ladder
escalation; and a seeded ``FleetSim`` fault scenario must drive the
burn-rate controller through a full escalate -> de-escalate cycle back
to ``normal`` (``make bench-smoke`` gates on all of it).

Every run also appends one row (tokens/s, TTFT/dispatch percentiles,
git sha, per-section verdicts) to ``BENCH_history.jsonl`` next to the
``--out`` file and FAILS on a >10% tokens/s regression against the
previous row.
"""

from __future__ import annotations

import os
import time
from typing import List

from benchmarks.common import Row
from repro.core.device_profile import CMP_170HX, CMP_170HX_NOFMA
from repro.core.perf_model import InferencePerfModel

FMTS = ("f32", "f16", "q8_0", "q6_k", "q4_k", "q2_k")


def rows() -> List[Row]:
    out: List[Row] = []
    md = InferencePerfModel(CMP_170HX)
    mn = InferencePerfModel(CMP_170HX_NOFMA)
    frac_d, frac_n = {}, {}
    for fmt in FMTS:
        dd = md.decode(fmt).tokens_per_s
        dn = mn.decode(fmt).tokens_per_s
        theo = md.theoretical_decode_tps(fmt)
        frac_d[fmt] = dd / theo
        frac_n[fmt] = dn / theo
        out.append(Row(f"decode[cmp-170hx/{fmt}]", 0.0,
                       f"{dd:.0f}t/s frac={dd/theo:.0%} "
                       f"bound={md.decode(fmt).bound}"))
        out.append(Row(f"decode[cmp-170hx-nofma/{fmt}]", 0.0,
                       f"{dn:.0f}t/s frac={dn/theo:.0%} gain={dn/dd:.2f}x"))
    band_d = all(0.35 <= frac_d[f] <= 0.80 for f in FMTS)
    band_n = all(0.50 <= frac_n[f] <= 0.80 for f in FMTS)
    out.append(Row("claim_4-2_default_band_39_78", 0.0,
                   " ".join(f"{f}={frac_d[f]:.0%}" for f in FMTS)
                   + (" (PASS)" if band_d else " (FAIL)")))
    out.append(Row("claim_4-2_nofma_band_50_78", 0.0,
                   " ".join(f"{f}={frac_n[f]:.0%}" for f in FMTS)
                   + (" (PASS)" if band_n else " (FAIL)")))
    stable = all(abs(frac_n[f] / frac_d[f] - 1) < 0.02
                 for f in ("f32", "f16", "q8_0"))
    out.append(Row("claim_4-2_dense_q8_fma_insensitive", 0.0,
                   "PASS" if stable else "FAIL"))
    return out


# ----------------------------------------------------------------------
# measured decode path (the serving hot loop, not the perf model)
# ----------------------------------------------------------------------

def _kv_bytes_per_step(lens, cfg, max_len: int, bk: int,
                       length_aware: bool) -> int:
    """KV bytes one decode step streams for the given per-lane lengths.

    Fetch counts follow the kernel's BlockSpec index maps exactly: the
    masked kernel walks every block of every lane; the length-aware one
    clamps to the last live block (dead lanes pin a single block).
    Costed per layer x kv-head at the cache dtype (int8 caches stream
    1-byte values plus their f32 per-token scales).
    """
    import numpy as np
    from repro.kernels.decode_attention import kv_blocks_fetched
    bk = min(bk, max_len)
    if length_aware:
        blocks = int(kv_blocks_fetched(np.asarray(lens), max_len, bk).sum())
    else:
        blocks = len(lens) * (max_len // bk)
    if cfg.kv_quant == "int8":
        per_row = cfg.hd * 1 + 4               # int8 values + f32 scale
    else:
        per_row = cfg.hd * (
            2 if str(cfg.compute_dtype) == "bfloat16" else 4)
    per_block = bk * per_row * cfg.n_kv_heads
    return blocks * per_block * 2 * cfg.n_layers          # k + v


def _legacy_greedy(cfg, params, prompt, max_new: int, max_len: int):
    """Pre-refactor decode semantics: unbucketed prefill, jitted
    single-token decode step, host-side argmax, one dispatch per token."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models.transformer import (init_cache, lm_decode_step,
                                          lm_prefill_batched)

    jit_step = jax.jit(lambda c, t: lm_decode_step(params, cfg, c, t))
    logits, (k, v) = lm_prefill_batched(
        params, jnp.asarray(prompt, jnp.int32)[None, :], cfg)
    cache = init_cache(cfg, 1, max_len)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["len"] = cache["len"].at[0].set(len(prompt))
    tok = int(np.argmax(np.asarray(logits)[0]))
    out = []
    for _ in range(max_new):
        logits, cache = jit_step(cache, jnp.asarray([tok], jnp.int32))
        tok = int(np.argmax(np.asarray(logits)[0]))
        out.append(tok)
        if int(cache["len"][0]) >= max_len - 1:
            break
    return out


def _kv_bytes_per_step_paged(lens, cfg, bt_width: int, page_size: int) -> int:
    """KV bytes one paged decode step streams, following the block-table
    index map: ``clip(ceil(len/ps), 1, T)`` pages per lane, costed like
    :func:`_kv_bytes_per_step`."""
    import numpy as np
    from repro.kernels.decode_attention import kv_pages_fetched
    pages = int(kv_pages_fetched(np.asarray(lens), bt_width,
                                 page_size).sum())
    if cfg.kv_quant == "int8":
        per_row = cfg.hd * 1 + 4
    else:
        per_row = cfg.hd * (
            2 if str(cfg.compute_dtype) == "bfloat16" else 4)
    per_page = page_size * per_row * cfg.n_kv_heads
    return pages * per_page * 2 * cfg.n_layers                # k + v


def paged_metrics(cfg, params, prompts, *, n_lanes: int, max_len: int,
                  max_new: int, dispatch_n: int, page_size: int) -> dict:
    """Paged-vs-dense section of BENCH_decode.json.

    The pool is sized to EXACTLY the dense engine's KV memory
    (``n_lanes`` full contexts); the paged engine gets a wider batch
    (4x lanes) so the admission test measures the POOL, not the batch
    width.  Capacity at mean live context c is how many concurrent
    requests fit before ``admit`` refuses -- dense is always
    ``n_lanes``.
    """
    import numpy as np
    from repro.serving import Request, ServeEngine

    bt_width = max_len // page_size
    pool_pages = n_lanes * bt_width

    # -- admission capacity vs mean context --------------------------
    rng = np.random.default_rng(1)
    capacity = {}
    for frac in (0.25, 0.5, 1.0):
        ctx = max(2, int(max_len * frac))
        plen = max(1, ctx // 2)
        gen = ctx - plen - 1
        eng = ServeEngine(cfg, params, n_lanes=4 * n_lanes,
                          max_len=max_len, dispatch_n=dispatch_n,
                          paged=True, page_size=page_size,
                          n_pages=pool_pages)
        admitted = 0
        for uid in range(8 * n_lanes):
            prompt = rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
            if not eng.admit(Request(uid=uid, prompt=prompt,
                                     max_new_tokens=max(gen, 1))):
                break
            admitted += 1
        capacity[f"{int(frac * 100)}%"] = {
            "mean_context": ctx,
            "paged_admitted": admitted,
            "dense_admitted": n_lanes,
            "admission_gain_x": round(admitted / n_lanes, 2),
        }

    # -- token-exact parity (same lanes => same admission order) ------
    def serve(paged):
        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        eng = ServeEngine(cfg, params, n_lanes=n_lanes, max_len=max_len,
                          dispatch_n=dispatch_n, paged=paged,
                          page_size=page_size)
        eng.run(reqs)
        return [tuple(r.generated) for r in reqs], eng

    dense_out, _ = serve(False)
    paged_out, peng = serve(True)
    peng.pool.check()

    # -- bytes/token parity at full occupancy -------------------------
    lens = [max_len] * n_lanes
    paged_bytes = _kv_bytes_per_step_paged(lens, cfg, bt_width, page_size)
    dense_bytes = _kv_bytes_per_step(lens, cfg, max_len, page_size,
                                     length_aware=True)
    return {
        "page_size": page_size,
        "pool_pages": pool_pages,
        "block_table_width": bt_width,
        "dense_lane_capacity": n_lanes,
        "admission_capacity": capacity,
        "token_exact_vs_dense": dense_out == paged_out,
        "kv_pages_hwm": peng.stats["kv_pages_hwm"],
        "kv_admit_blocked": peng.stats["kv_admit_blocked"],
        "pool_leak_free": (peng.pool.n_in_use == 0
                          and peng.pool.n_free == pool_pages),
        "bytes_read_per_token_full_occupancy": {
            "paged": paged_bytes // n_lanes,
            "dense_lengthaware": dense_bytes // n_lanes,
            "ratio": round(paged_bytes / dense_bytes, 4),
        },
    }


def migration_metrics(cfg, params, *, n_lanes: int, max_len: int,
                      max_new: int, dispatch_n: int,
                      page_size: int) -> dict:
    """Preemption / migration section of BENCH_decode.json.

    Replays one trace through the paged engine with evict-and-replay
    churn injected at every dispatch boundary (greedy AND temperature)
    and diffs the token streams against the unpreempted run -- the
    resumed RNG stream must be bit-identical.  The transfer-cost model
    prices what the fleet pays per move: ``ceil(ctx/page_size)`` pages
    over the CMP 170HX's PCIe 1.1 x4 host link.
    """
    from repro.core.device_profile import CMP_170HX_NOFMA
    from repro.core.perf_model import QWEN25_1P5B
    from repro.fleet.execution import validate_preemption_exactness
    from repro.fleet.workload import FleetRequest
    from repro.serving import kv_handoff_seconds

    trace = [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=5 + i,
                          gen_len=max_new) for i in range(2 * n_lanes)]
    kw = dict(n_lanes=n_lanes, max_len=max_len, dispatch_n=dispatch_n,
              page_size=page_size)
    greedy = validate_preemption_exactness(trace, cfg, params,
                                           preempt_every=1, **kw)
    temp = validate_preemption_exactness(trace, cfg, params,
                                         preempt_every=1,
                                         temperature=0.8, **kw)

    # page-granular transfer over the host link (per migrated context)
    spec = QWEN25_1P5B
    link = CMP_170HX_NOFMA.total_interconnect_gbps()
    transfer = {}
    for ctx in (128, 512, 2048):
        pages = -(-ctx // page_size)
        transfer[f"ctx={ctx}"] = {
            "pages": pages,
            "mbytes": round(pages * page_size
                            * spec.kv_bytes_per_token() / 1e6, 2),
            "transfer_ms": round(kv_handoff_seconds(
                CMP_170HX_NOFMA, pages * page_size, spec) * 1e3, 2),
        }
    return {
        "preempt_every": 1,
        "preemptions": greedy["preemptions"],
        "restores": greedy["restores"],
        "pages_migrated": greedy["pages_migrated"],
        "resume_token_exact": {"greedy": greedy["resume_exact"],
                               "temperature": temp["resume_exact"]},
        "transfer_model": {
            "page_size": page_size,
            "kv_bytes_per_token": spec.kv_bytes_per_token(),
            "host_link_gbps": link,
            "per_context": transfer,
        },
    }


def multimodel_metrics(cfg, params, *, n_lanes: int, max_len: int,
                       max_new: int, dispatch_n: int,
                       page_size: int) -> dict:
    """Multi-model section of BENCH_decode.json.

    Two models (the smoke config with independent weights, plus the
    olmo smoke config) share one board through
    :class:`~repro.serving.modelpool.MultiModelServeEngine`, twice: on
    a ROOMY budget (both dense-resident, one cold load each) and on a
    TIGHT budget (weights must page over the host link, KV pools
    shrink).  Gated claims: per-model token streams are bit-identical
    to single-model engines (greedy AND temperature), token counts are
    budget invariant, and the tight budget shows real swap churn.
    """
    import jax
    from repro.configs import get_config
    from repro.fleet.execution import (dense_hbm_bytes,
                                       run_multimodel_trace_on_engine,
                                       validate_multimodel_exactness)
    from repro.fleet.workload import FleetRequest
    from repro.models import build_model
    from repro.serving import kv_page_bytes, params_nbytes

    cfg_b = get_config("olmo-1b", smoke=True)
    params_b = build_model(cfg_b).init(jax.random.PRNGKey(1))
    models = {"qwen-smoke": (cfg, params), "olmo-smoke": (cfg_b, params_b)}
    trace = [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=5 + i % 4,
                          gen_len=max_new,
                          model_id="qwen-smoke" if i % 2 == 0
                          else "olmo-smoke")
             for i in range(2 * n_lanes)]
    kw = dict(n_lanes=n_lanes, max_len=max_len, dispatch_n=dispatch_n,
              page_size=page_size)

    roomy_b = dense_hbm_bytes(models, n_lanes=n_lanes, max_len=max_len,
                              page_size=page_size)
    bt = max_len // page_size
    pb_a = kv_page_bytes(cfg, page_size)
    pb_b = kv_page_bytes(cfg_b, page_size)
    # one page short of co-residency at the one-full-context floors:
    # every model switch must evict the idle tenant and reload it later
    tight_b = (sum(params_nbytes(p) for _, p in models.values())
               + (bt + 1) * pb_a + (bt + 1) * pb_b - min(pb_a, pb_b))
    roomy = run_multimodel_trace_on_engine(trace, models, **kw)
    tight = run_multimodel_trace_on_engine(trace, models,
                                           hbm_bytes=tight_b, **kw)
    greedy = validate_multimodel_exactness(trace, models,
                                           hbm_bytes=tight_b, **kw)
    temp = validate_multimodel_exactness(trace, models, hbm_bytes=tight_b,
                                         temperature=0.8, **kw)
    return {
        "models": sorted(models),
        "weight_bytes": {mid: params_nbytes(p)
                         for mid, (_, p) in models.items()},
        "hbm_budget_bytes": {"roomy": roomy_b, "tight": tight_b},
        "gen_by_model": roomy.gen_by_model,
        "token_counts_budget_invariant":
            tight.gen_by_uid == roomy.gen_by_uid,
        "per_model_token_exact": {"greedy": greedy["exact"],
                                  "temperature": temp["exact"]},
        "model_swaps": {"roomy": roomy.model_swaps,
                        "tight": tight.model_swaps},
        "swap_bytes": {"roomy": roomy.swap_bytes,
                       "tight": tight.swap_bytes},
        "weight_evictions": {"roomy": roomy.weight_evictions,
                             "tight": tight.weight_evictions},
        "kv_pages_shrunk_tight": tight.kv_pages_shrunk,
    }


def telemetry_metrics(cfg, params, prompts, *, n_lanes: int,
                      max_len: int, max_new: int, dispatch_n: int,
                      page_size: int) -> dict:
    """Telemetry section of BENCH_decode.json.

    Three claims about the ``repro.obs`` layer, measured on the real
    paged decode path:

    * **overhead budget** -- the SAME workload served with tracing on
      and off produces identical token streams and identical
      prefill/ssm/decode compile counters (spans wrap host work only;
      nothing enters a jitted computation);
    * **span/counter agreement** -- one ``decode.dispatch`` span per
      counted dispatch, per-phase host durations folded to p50/p99;
    * **sim-to-real calibration** -- :func:`repro.obs.predict_replay`
      (the pure-host scheduling mirror) must match a measured
      ``run_trace_on_engine`` replay's dispatch counts, decode steps,
      token totals, and page high-water mark within tolerance, and a
      deliberately mis-modeled phase model (wrong ``dispatch_n``, wrong
      page geometry) must FAIL the same gate -- the gate's self-test.
    """
    from repro.fleet.execution import run_trace_on_engine
    from repro.fleet.workload import FleetRequest
    from repro.obs import (MetricsRegistry, SpanTracer, calibrate_replay,
                           fit_dispatch_time_model, predict_replay)
    from repro.serving import Request, ServeEngine

    # -- overhead budget: tracing changes nothing observable ----------
    def serve(traced: bool):
        registry = MetricsRegistry()
        tracer = SpanTracer(enabled=traced, registry=registry)
        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        eng = ServeEngine(cfg, params, n_lanes=n_lanes, max_len=max_len,
                          dispatch_n=dispatch_n, paged=True,
                          page_size=page_size, tracer=tracer,
                          registry=registry)
        eng.run(reqs)
        return [tuple(r.generated) for r in reqs], dict(eng.stats), tracer

    plain_out, plain_stats, _ = serve(False)
    traced_out, traced_stats, tracer = serve(True)
    compile_keys = ("prefill_compiles", "ssm_prefill_compiles",
                    "decode_compiles")
    neutral = (plain_out == traced_out
               and all(plain_stats[k] == traced_stats[k]
                       for k in compile_keys))
    n_dispatch_spans = len(tracer.spans_named("decode.dispatch"))

    # -- calibration replay (traced, separate registry) ---------------
    trace = [FleetRequest(uid=i, arrival_s=0.05 * i,
                          prompt_len=4 + i % 5, gen_len=3 + i % 6)
             for i in range(3 * n_lanes)]
    cal_registry = MetricsRegistry()
    cal_tracer = SpanTracer(enabled=True, registry=cal_registry)
    real = run_trace_on_engine(trace, cfg, params, n_lanes=n_lanes,
                               max_len=max_len, dispatch_n=dispatch_n,
                               paged=True, page_size=page_size,
                               tracer=cal_tracer, registry=cal_registry)
    model_kw = dict(n_lanes=n_lanes, max_len=max_len, paged=True)
    sim = predict_replay(trace, dispatch_n=dispatch_n,
                         page_size=page_size, **model_kw)
    report = calibrate_replay(real, sim, spans=cal_tracer.spans)
    # gate self-test: a mis-modeled phase model must fail loudly
    pert_dispatch = calibrate_replay(
        real, predict_replay(trace, dispatch_n=1, page_size=page_size,
                             **model_kw))
    pert_pages = calibrate_replay(
        real, predict_replay(trace, dispatch_n=dispatch_n,
                             page_size=max(1, page_size // 4),
                             **model_kw))

    phases = {
        name: {k: (v if k == "count" else round(v, 6))
               for k, v in cal_registry[name].summary().items()}
        for name in cal_registry.names() if name.startswith("span.")}
    return {
        "overhead_budget": {
            "token_stream_identical": plain_out == traced_out,
            "compile_counters": {k: {"untraced": plain_stats[k],
                                     "traced": traced_stats[k]}
                                 for k in compile_keys},
            "tracing_neutral": neutral,
        },
        "decode_dispatch_spans": n_dispatch_spans,
        "dispatch_span_count_matches_stats":
            n_dispatch_spans == traced_stats["decode_dispatches"],
        "well_nested": tracer.check_well_nested(),
        "phase_durations_s": phases,
        "dispatch_time_fit": {
            k: (v if k == "n_spans" else round(v, 9))
            for k, v in fit_dispatch_time_model(cal_tracer.spans).items()},
        "calibration": report.as_dict(),
        "perturbation_check": {
            "dispatch_n=1_fails": not pert_dispatch.ok,
            "page_size_div4_fails": not pert_pages.ok,
            "gate_self_test_pass": (not pert_dispatch.ok
                                    and not pert_pages.ok),
        },
    }


def faults_metrics(cfg, params) -> dict:
    """Faults section of BENCH_decode.json.

    Two layers, same contract ("a crash costs time, never tokens"):

    * **engine oracle** -- :func:`repro.fleet.execution.
      validate_recovery_exactness` replays a seeded trace on the REAL
      paged engine with a transient fault, periodic checkpoint ticks
      and a mid-trace node crash; lanes resumed from checkpoints AND
      lanes replayed from the prompt must reproduce the undisturbed
      greedy streams bit for bit;
    * **fleet sim** -- the shared ``benchmarks.fleet_sim.fault_reports``
      scenario (derate + link flap + crash + transient on a 4-board
      fleet): with a :class:`RecoveryPolicy` nothing is lost and
      goodput stays >= 90% of the fault-free baseline; without one the
      crash visibly loses requests -- the no-recovery arm is the
      gate's self-test.
    """
    from benchmarks.fleet_sim import fault_reports
    from repro.fleet.execution import validate_recovery_exactness
    from repro.fleet.workload import LengthDist, poisson_trace

    trace = poisson_trace(2.0, 6.0, seed=3, prompt=LengthDist(12, cv=0.3),
                          gen=LengthDist(14, cv=0.4))
    # crash at dispatch 10 exercises BOTH recovery paths on this trace:
    # one live lane has a checkpoint (resumes), one does not (replays)
    oracle = validate_recovery_exactness(
        trace, cfg, params, crash_at_dispatch=10, checkpoint_every=3,
        transient_dispatches=(2,), n_lanes=2, max_len=32, dispatch_n=4,
        page_size=8, seed=5)
    oracle.pop("mismatches", None)      # int-keyed; not JSON material

    base, rec, norec = fault_reports()
    return {
        "engine_oracle": oracle,
        "sim": {
            "fault_free_goodput_rps": round(base.goodput_rps, 3),
            "with_recovery": {
                "goodput_rps": round(rec.goodput_rps, 3),
                "goodput_vs_base": round(
                    rec.goodput_rps / base.goodput_rps, 4),
                "crashes": rec.crashes,
                "recovered_lanes": rec.recovered_lanes,
                "replayed_from_prompt": rec.replayed_from_prompt,
                "checkpoints": rec.checkpoints,
                "retries": rec.retries,
                "requests_lost": rec.requests_lost,
                "straggler_flags": len(rec.derate_detected),
            },
            "without_recovery": {
                "goodput_rps": round(norec.goodput_rps, 3),
                "goodput_vs_base": round(
                    norec.goodput_rps / base.goodput_rps, 4),
                "requests_lost": norec.requests_lost,
            },
        },
    }


def prefix_metrics(cfg, params, *, n_lanes: int, max_len: int,
                   max_new: int, dispatch_n: int, page_size: int) -> dict:
    """Prefix-sharing section of BENCH_decode.json.

    Three experiments over the copy-on-write radix prompt cache:

    * exactness -- shared-prefix workloads (full-page hits plus one
      partial-page hit that forces a CoW split) served with sharing on
      vs off, greedy and temperature, dense and int8 KV: token streams
      must be bit-identical (sharing is a memory optimization, not a
      model change);
    * TTFT -- admission latency of a prompt whose prefix is cached vs
      the same-shape cache miss, on one engine with both compile paths
      warmed: the hit prefills only the unmatched tail;
    * effective admission -- concurrent requests admitted at ~50%
      prompt overlap with a warm cache vs the no-sharing baseline on
      the same pool: hits reserve only their tail pages.
    """
    import dataclasses as _dc

    import jax
    import numpy as np
    from repro.serving import Request, ServeEngine

    ps = page_size
    rng = np.random.default_rng(11)
    head = rng.integers(0, cfg.vocab_size, 2 * ps, dtype=np.int32)

    # donor, an extension of it (partial-page hit => CoW on prefill),
    # and full-page-hit siblings with unique tails
    donor = np.concatenate([head, rng.integers(0, cfg.vocab_size,
                                               ps // 2, dtype=np.int32)])
    extension = np.concatenate([donor, rng.integers(0, cfg.vocab_size,
                                                    4, dtype=np.int32)])
    prompts = [donor, extension] + [
        np.concatenate([head, rng.integers(0, cfg.vocab_size, ps // 2,
                                           dtype=np.int32)])
        for _ in range(2 * n_lanes - 2)]

    def serve(c, sharing, temperature=0.0):
        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        eng = ServeEngine(c, params, n_lanes=n_lanes, max_len=max_len,
                          dispatch_n=dispatch_n, paged=True,
                          page_size=ps, temperature=temperature,
                          prefix_sharing=sharing)
        eng.run(reqs)
        stats = dict(eng.stats)
        if eng.prefix_cache is not None:
            eng.prefix_cache.flush()
        eng.pool.check()
        leak_free = eng.pool.n_in_use == 0
        return [tuple(r.generated) for r in reqs], stats, leak_free

    cfg_int8 = _dc.replace(cfg, kv_quant="int8")
    runs = {"greedy": (cfg, 0.0), "temperature": (cfg, 0.8),
            "int8_greedy": (cfg_int8, 0.0)}
    exact, leak_free, shared_stats = {}, True, None
    for name, (c, temp) in runs.items():
        base, _, lf0 = serve(c, False, temp)
        shared, stats, lf1 = serve(c, True, temp)
        exact[name] = base == shared
        leak_free = leak_free and lf0 and lf1
        if name == "greedy":
            shared_stats = stats

    # -- TTFT: cache hit vs same-shape miss on one warmed engine ------
    # long-context probe: the miss pays a batched prefill over the full
    # power-of-two bucket; the hit matches the donor's whole prompt
    # (full pages AND its partial last page -> one CoW split) and
    # streams only the single-token tail
    ttft_len = 4 * max_len
    long_donor = rng.integers(0, cfg.vocab_size, ttft_len - 2,
                              dtype=np.int32)
    consumer = np.concatenate(
        [long_donor, rng.integers(0, cfg.vocab_size, 1, dtype=np.int32)])
    eng = ServeEngine(cfg, params, n_lanes=2, max_len=ttft_len,
                      dispatch_n=dispatch_n, paged=True, page_size=ps,
                      prefix_sharing=True)

    def drain(e):
        while e.live_lanes():
            e.decode_n()

    def timed_admit(e, prompt, uid):
        req = Request(uid=uid, prompt=prompt.copy(), max_new_tokens=2)
        t0 = time.perf_counter()
        assert e.admit(req), "TTFT probe must fit an empty engine"
        jax.block_until_ready(e._next_token)
        dt = time.perf_counter() - t0
        drain(e)
        return dt

    t_miss, t_hit = [], []
    for rep in range(3):                 # rep 0 pays the compiles
        eng.prefix_cache.flush()         # donor admission = true miss
        t_miss.append(timed_admit(eng, long_donor, 100 + 2 * rep))
        t_hit.append(timed_admit(eng, consumer, 101 + 2 * rep))
    ttft_miss = min(t_miss[1:])
    ttft_hit = min(t_hit[1:])

    # -- effective admission at ~50% prompt overlap -------------------
    # pool sized so the marginal arithmetic is visible: misses need 4
    # pages (prompt 63 + write slot @ ps=16), hits on the 2-page cached
    # template reserve only their 2 tail pages -- 10 pages admit 2
    # without sharing (the cache itself holds 2) vs 4 with it
    pool_pages = 10
    overlap_plen = 4 * ps - 1

    def admitted(sharing):
        e = ServeEngine(cfg, params, n_lanes=3 * n_lanes,
                        max_len=max_len, dispatch_n=dispatch_n,
                        paged=True, page_size=ps, n_pages=pool_pages,
                        prefix_sharing=sharing)
        if sharing:                      # warm the cache, then retire
            e.run([Request(uid=0, prompt=head.copy(),
                           max_new_tokens=1)])
        count = 0
        for uid in range(1, 3 * n_lanes):
            tail = rng.integers(0, cfg.vocab_size,
                                overlap_plen - len(head), dtype=np.int32)
            prompt = np.concatenate([head, tail])
            if not e.admit(Request(uid=uid, prompt=prompt,
                                   max_new_tokens=1)):
                break
            count += 1
        return count

    adm_off = admitted(False)
    adm_on = admitted(True)

    return {
        "page_size": ps,
        "prefix_len": len(head),
        "token_exact_vs_unshared": exact,
        "pool_leak_free": leak_free,
        "prefix_hits": shared_stats["prefix_hits"],
        "prefix_tokens_matched": shared_stats["prefix_tokens_matched"],
        "pages_saved": shared_stats["prefix_pages_saved"],
        "cow_copies": shared_stats["prefix_cow_copies"],
        "ttft": {
            "prompt_len": int(len(consumer)),
            "matched_tokens_on_hit": int(len(consumer)) - 1,
            "miss_ms": round(ttft_miss * 1e3, 3),
            "hit_ms": round(ttft_hit * 1e3, 3),
            "speedup_x": round(ttft_miss / max(ttft_hit, 1e-9), 2),
        },
        "effective_admission": {
            "pool_pages": pool_pages,
            "prompt_len": overlap_plen,
            "overlap_fraction": round(len(head) / overlap_plen, 3),
            "admitted_no_sharing": adm_off,
            "admitted_sharing": adm_on,
            "admission_gain_x": round(adm_on / max(adm_off, 1), 2),
        },
    }


def sanitize_metrics(cfg, params, prompts, *, n_lanes: int, max_len: int,
                     max_new: int, dispatch_n: int, page_size: int) -> dict:
    """Sanitizer section of BENCH_decode.json.

    The page-lifecycle sanitizer (``ServeEngine(sanitize=True)``) is an
    always-on-capable production guard, so the bench holds it to two
    gates: a real shared-prefix workload (prefill, prefix hits, CoW
    splits) runs with ZERO violations and bit-identical streams, and
    the steady-state decode overhead vs the unsanitized engine stays
    under 5% (warm-then-timed on the same engine, like the headline
    tokens/s number).  Also pins the OFF mode to its contract: no
    monitor attached, one attribute check on the hot path.
    """
    import numpy as np
    from repro.serving import Request, ServeEngine

    ps = page_size
    rng = np.random.default_rng(13)
    head = rng.integers(0, cfg.vocab_size, 2 * ps, dtype=np.int32)
    family = [np.concatenate([head,
                              rng.integers(0, cfg.vocab_size, 4 + i,
                                           dtype=np.int32)])
              for i in range(len(prompts))]

    def build(sanitize):
        # warm the engine once so timed passes measure steady state
        eng = ServeEngine(cfg, params, n_lanes=n_lanes, max_len=max_len,
                          dispatch_n=dispatch_n, paged=True,
                          page_size=ps, prefix_sharing=True,
                          sanitize=sanitize)
        eng.run([Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                 for i, p in enumerate(family)])
        return eng

    def timed_pass(eng):
        eng.stats = {k: 0 for k in eng.stats}
        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                for i, p in enumerate(family)]
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        return ([tuple(r.generated) for r in reqs],
                eng.stats["generated_tokens"] / dt,
                eng.stats["prefix_hits"])

    # the sanitizer delta is small against run-to-run jitter and slow
    # machine drift over the full bench, so interleave best-of-3 timed
    # passes off/on (same scheme as the slo_tracing overhead arm)
    base_eng, eng = build(False), build(True)
    base_streams = streams = None
    base_tps = tps = hits = 0.0
    for _ in range(3):
        base_streams, t, _ = timed_pass(base_eng)
        base_tps = max(base_tps, t)
        streams, t, hits = timed_pass(eng)
        tps = max(tps, t)

    def wind_down(e):
        e.prefix_cache.flush()
        e.pool.check()
        leak_free = e.pool.n_in_use == 0
        if e._sanitizer is not None:
            e._sanitizer.crosscheck(e.pool)
        return leak_free

    base_leak = wind_down(base_eng)
    leak_free = wind_down(eng)
    san = eng._sanitizer

    return {
        "page_size": ps,
        "token_exact_vs_unsanitized": streams == base_streams,
        "violations": len(san.violations),
        "ops_checked": san.ops_seen,
        "prefix_hits": int(hits),
        "pool_leak_free": bool(base_leak and leak_free),
        "off_mode_monitor_detached": base_eng.pool.monitor is None
        and base_eng._sanitizer is None,
        "tokens_per_s_off": round(base_tps, 2),
        "tokens_per_s_on": round(tps, 2),
        "overhead_frac": round(1.0 - tps / base_tps, 4),
    }


def slo_tracing_metrics(cfg, params, prompts, *, n_lanes: int,
                        max_len: int, max_new: int, dispatch_n: int,
                        page_size: int) -> dict:
    """SLO-tracing section of BENCH_decode.json.

    Three claims about the request-scoped observability layer
    (``repro.obs.requests`` / ``flight`` / ``slo``):

    * **overhead budget** -- the SAME paged workload served with the
      full stack on (tracing, flight ring tapped into the tracer, SLO
      controller stepped every dispatch) vs everything off must keep
      bit-identical token streams and steady-state decode overhead
      under 5% (warm-then-timed on one engine, like the sanitizer
      gate);
    * **crash-replay timelines** -- the recovery-oracle trace replayed
      with a node crash under ONE shared tracer: every request
      reconstructs a GAP-FREE :class:`~repro.obs.RequestTimeline`,
      checkpointed lanes span BOTH engines (the migration hop), the
      dying engine leaves exactly one flight dump, the tight-objective
      burn-rate controller escalates the ladder, and the streams still
      match an unobserved replay bit for bit;
    * **burn-rate control loop** -- a seeded :class:`FleetSim` scenario
      (board crash, then a bounded thermal derate) drives the monitor
      through a full escalate -> de-escalate cycle back to ``normal``,
      with the crash dumping the sim's flight ring.
    """
    import tempfile

    from repro.fleet import (FaultEvent, FaultPlan, FleetSim, NodeSpec,
                             RecoveryPolicy)
    from repro.fleet.execution import run_trace_with_faults
    from repro.fleet.workload import LengthDist, poisson_trace
    from repro.obs import (BurnRateMonitor, FlightRecorder,
                           MetricsRegistry, SLOController, SLOObjective,
                           SpanTracer, request_timelines)
    from repro.serving import DegradationLadder, Request, ServeEngine

    # -- overhead budget: the full stack changes nothing observable ---
    def build(observed: bool) -> ServeEngine:
        registry = MetricsRegistry()
        tracer = SpanTracer(enabled=observed, registry=registry)
        flight = FlightRecorder(name="bench") if observed else None
        slo = None
        if observed:
            # loose objectives: the controller runs its full per-dispatch
            # path (clock reads, window maintenance, update) but never
            # alerts, so the ladder stays at normal
            monitor = BurnRateMonitor(
                SLOObjective(ttft_s=60.0, tpot_s=1.0), registry=registry)
            slo = SLOController(monitor, DegradationLadder())
        eng = ServeEngine(cfg, params, n_lanes=n_lanes, max_len=max_len,
                          dispatch_n=dispatch_n, paged=True,
                          page_size=page_size, tracer=tracer,
                          registry=registry, flight=flight, slo=slo)
        # warm pass: compile once so timed passes measure steady state
        eng.run([Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                 for i, p in enumerate(prompts)])
        return eng

    def timed_pass(eng: ServeEngine):
        eng.stats = {k: 0 for k in eng.stats}
        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        return ([tuple(r.generated) for r in reqs],
                eng.stats["generated_tokens"] / dt)

    # the obs delta is small against run-to-run jitter AND against slow
    # machine-state drift (thermal, background load) over the bench, so:
    # warm both engines up front, then INTERLEAVE best-of-3 timed passes
    # off/on -- drift hits both arms equally instead of biasing whichever
    # arm happens to run last
    eng_off, eng_on = build(False), build(True)
    off_streams = on_streams = None
    off_tps = on_tps = 0.0
    for _ in range(3):
        off_streams, tps = timed_pass(eng_off)
        off_tps = max(off_tps, tps)
        on_streams, tps = timed_pass(eng_on)
        on_tps = max(on_tps, tps)

    # -- crash replay: gap-free cross-engine timelines ----------------
    trace = poisson_trace(2.0, 6.0, seed=3, prompt=LengthDist(12, cv=0.3),
                          gen=LengthDist(14, cv=0.4))
    replay_kw = dict(crash_at_dispatch=10, checkpoint_every=3,
                     transient_dispatches=(2,), n_lanes=2, max_len=32,
                     dispatch_n=4, page_size=8, seed=5)
    base = run_trace_with_faults(trace, cfg, params, **replay_kw)
    registry = MetricsRegistry()
    tracer = SpanTracer(enabled=True, registry=registry)
    # a tpot objective no real dispatch can meet: every sample violates,
    # both burn windows saturate, the controller MUST escalate
    ctl = SLOController(
        BurnRateMonitor(SLOObjective(tpot_s=1e-9, error_budget=0.05),
                        registry=registry),
        DegradationLadder())
    with tempfile.TemporaryDirectory() as tmp:
        obs = run_trace_with_faults(trace, cfg, params, tracer=tracer,
                                    registry=registry, flight_dir=tmp,
                                    slo=ctl, **replay_kw)
        dump_headers = [FlightRecorder.load(p)[0]
                        for p in obs.flight_dumps]
    tls = request_timelines(tracer)
    incomplete = {uid: tl.gaps() for uid, tl in tls.items()
                  if not tl.complete}
    migrated = [uid for uid, tl in tls.items() if tl.hops >= 1]

    # -- fleet sim: full escalate -> de-escalate cycle ----------------
    # crash one of three boards at 6s, then derate a survivor 8x for a
    # bounded 10s window: the tpot objective burns hard while the derate
    # holds, then recovers -- so the controller must walk the ladder up
    # AND back down to normal before the trace drains
    sim_trace = poisson_trace(2.0, 40.0, seed=3,
                              prompt=LengthDist(128, cv=0.3),
                              gen=LengthDist(64, cv=0.4))
    plan = FaultPlan(events=(
        FaultEvent("crash", node=1, at_s=6.0),
        FaultEvent("derate", node=0, at_s=8.0, factor=8.0,
                   duration_s=10.0)))
    sim_registry = MetricsRegistry()
    sim_tracer = SpanTracer(enabled=True, registry=sim_registry)
    sim_ladder = DegradationLadder()
    sim_ctl = SLOController(
        BurnRateMonitor(SLOObjective(tpot_s=0.008, error_budget=0.05),
                        short_window_s=4.0, long_window_s=15.0,
                        registry=sim_registry),
        sim_ladder, escalate_every_s=2.0, relax_every_s=3.0)
    cwd = os.getcwd()
    with tempfile.TemporaryDirectory() as tmp:
        os.chdir(tmp)            # the sim dumps its flight ring to CWD
        try:
            report = FleetSim([NodeSpec("cmp-170hx-nofma", 3, "both")],
                              sim_trace, faults=plan,
                              recovery=RecoveryPolicy(),
                              tracer=sim_tracer, registry=sim_registry,
                              slo=sim_ctl,
                              flight=FlightRecorder(name="fleet")).run()
            sim_dumps = sorted(os.listdir(tmp))
        finally:
            os.chdir(cwd)
    sim_tls = request_timelines(sim_tracer)

    return {
        "overhead": {
            "token_exact_vs_unobserved": on_streams == off_streams,
            "tokens_per_s_off": round(off_tps, 2),
            "tokens_per_s_on": round(on_tps, 2),
            "overhead_frac": round(1.0 - on_tps / off_tps, 4),
        },
        "crash_replay": {
            "token_exact_vs_unobserved": obs.streams == base.streams,
            "crashes": obs.crashes,
            "flight_dumps": len(obs.flight_dumps),
            "flight_dump_reason": (dump_headers[0].get("reason", "")
                                   if dump_headers else ""),
            "requests": len(tls),
            "complete_timelines": sum(1 for tl in tls.values()
                                      if tl.complete),
            "incomplete": {str(u): g for u, g in incomplete.items()},
            "migrated_requests": len(migrated),
            "max_hops": max((tl.hops for tl in tls.values()), default=0),
            "controller_escalated": ctl.escalated,
            "alerts_fired": ctl.monitor.alerts_fired,
        },
        "fleet_sim": {
            "offered": report.offered,
            "completed": report.completed,
            "requests_lost": report.requests_lost,
            "timelines": len(sim_tls),
            "complete_timelines": sum(1 for tl in sim_tls.values()
                                      if tl.complete),
            "flight_dumps": len(sim_dumps),
            "escalated": sim_ctl.escalated,
            "deescalated": sim_ctl.deescalated,
            "final_level": sim_ladder.level_name,
            "actions": [[round(t, 3), a, lvl]
                        for t, a, lvl in sim_ctl.actions],
            "alerts_fired": sim_ctl.monitor.alerts_fired,
        },
    }


def decode_path_metrics(arch: str = "qwen2.5-1.5b", n_lanes: int = 4,
                        max_len: int = 64, prompt_len: int = 8,
                        max_new: int = 16, n_requests: int = 8,
                        dispatch_n: int = 8, bk: int = 16,
                        seed: int = 0) -> dict:
    """Run the real ServeEngine decode path on a smoke config and return
    the BENCH_decode.json payload."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import Request, ServeEngine

    cfg = get_config(arch, smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len, dtype=np.int32)
               for _ in range(n_requests)]

    def serve(n):
        # jit caches are per-engine, so warm and time the SAME engine:
        # the first full pass pays every trace/compile, the timed second
        # workload (fresh requests, counters zeroed) measures steady
        # state only.
        eng = ServeEngine(cfg, params, n_lanes=n_lanes, max_len=max_len,
                          dispatch_n=n)
        eng.run([Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                 for i, p in enumerate(prompts)])
        eng.stats = {k: 0 for k in eng.stats}
        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        return reqs, eng.stats, dt

    base_reqs, base_stats, base_dt = serve(1)      # per-token baseline
    new_reqs, new_stats, new_dt = serve(dispatch_n)

    base_dpt = base_stats["decode_dispatches"] / base_stats["generated_tokens"]
    new_dpt = new_stats["decode_dispatches"] / new_stats["generated_tokens"]
    # token-exact both against the per-token dispatch AND against a
    # legacy-style reference (jitted single step, host-side argmax) --
    # the latter catches regressions in the fused path itself
    legacy = [_legacy_greedy(cfg, params, p, max_new, max_len)
              for p in prompts[:n_lanes]]
    exact = (all(a.generated == b.generated
                 for a, b in zip(base_reqs, new_reqs))
             and all(list(r.generated) == l
                     for r, l in zip(new_reqs, legacy)))

    ctx = prompt_len + max_new // 2
    occupancy = {}
    for frac in (0.25, 0.5, 1.0):
        live = max(1, int(round(n_lanes * frac)))
        lens = [ctx] * live + [0] * (n_lanes - live)
        la = _kv_bytes_per_step(lens, cfg, max_len, bk, length_aware=True)
        masked = _kv_bytes_per_step(lens, cfg, max_len, bk,
                                    length_aware=False)
        occupancy[f"{int(frac * 100)}%"] = {
            "live_lanes": live, "context_len": ctx,
            "lengthaware_bytes_per_token": la // live,
            "masked_bytes_per_token": masked // live,
            "traffic_ratio": round(la / masked, 4),
        }

    # full occupancy, growing live context: length-aware reads grow with
    # the context while the masked kernel is pinned at max_len
    context_sweep = {}
    for frac in (0.25, 0.5, 1.0):
        c = max(bk, int(max_len * frac))
        lens = [c] * n_lanes
        la = _kv_bytes_per_step(lens, cfg, max_len, bk, length_aware=True)
        masked = _kv_bytes_per_step(lens, cfg, max_len, bk,
                                    length_aware=False)
        context_sweep[f"ctx={c}"] = {
            "lengthaware_bytes_per_token": la // n_lanes,
            "masked_bytes_per_token": masked // n_lanes,
            "traffic_ratio": round(la / masked, 4),
        }

    return {
        "arch": arch, "n_lanes": n_lanes, "max_len": max_len,
        "prompt_len": prompt_len, "max_new": max_new,
        "dispatch_n": dispatch_n, "kernel_bk": bk,
        "generated_tokens": new_stats["generated_tokens"],
        "tokens_per_s": round(new_stats["generated_tokens"] / new_dt, 2),
        "baseline_tokens_per_s": round(
            base_stats["generated_tokens"] / base_dt, 2),
        "dispatches_per_token": round(new_dpt, 4),
        "baseline_dispatches_per_token": round(base_dpt, 4),
        "dispatch_reduction_x": round(base_dpt / new_dpt, 2),
        "prefill_compiles": new_stats["prefill_compiles"],
        "greedy_token_exact": exact,
        "bytes_read_per_token": occupancy,
        "bytes_read_context_sweep": context_sweep,
        # steady-state compile counters (the timed second workload above
        # ran with counters zeroed: any non-zero value is a recompile on
        # the hot path) plus the persistent jit-cache dir, when the
        # launch env (scripts/serve_env.sh) configured one
        "warm_start": {
            "steady_state_prefill_compiles": new_stats["prefill_compiles"],
            "steady_state_ssm_prefill_compiles": new_stats[
                "ssm_prefill_compiles"],
            "compilation_cache_dir": os.environ.get(
                "JAX_COMPILATION_CACHE_DIR"),
        },
        "paged": paged_metrics(cfg, params, prompts, n_lanes=n_lanes,
                               max_len=max_len, max_new=max_new,
                               dispatch_n=dispatch_n, page_size=bk),
        "prefix": prefix_metrics(cfg, params, n_lanes=n_lanes,
                                 max_len=max_len, max_new=max_new,
                                 dispatch_n=dispatch_n, page_size=bk),
        "migration": migration_metrics(cfg, params, n_lanes=n_lanes,
                                       max_len=max_len, max_new=max_new,
                                       dispatch_n=dispatch_n,
                                       page_size=bk),
        "multimodel": multimodel_metrics(cfg, params, n_lanes=n_lanes,
                                         max_len=max_len, max_new=max_new,
                                         dispatch_n=dispatch_n,
                                         page_size=bk),
        "telemetry": telemetry_metrics(cfg, params, prompts,
                                       n_lanes=n_lanes, max_len=max_len,
                                       max_new=max_new,
                                       dispatch_n=dispatch_n,
                                       page_size=bk),
        "faults": faults_metrics(cfg, params),
        "sanitize": sanitize_metrics(cfg, params, prompts,
                                     n_lanes=n_lanes, max_len=max_len,
                                     max_new=max_new,
                                     dispatch_n=dispatch_n,
                                     page_size=bk),
        "slo_tracing": slo_tracing_metrics(cfg, params, prompts,
                                           n_lanes=n_lanes,
                                           max_len=max_len,
                                           max_new=max_new,
                                           dispatch_n=dispatch_n,
                                           page_size=bk),
    }


def _git_sha():
    """Short HEAD sha for the bench-history row, or None outside git."""
    import subprocess
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None
    except Exception:
        return None


def _last_history_row(path: str):
    """Last JSON row of BENCH_history.jsonl, or None."""
    import json
    try:
        with open(path) as f:
            lines = [ln for ln in f if ln.strip()]
        return json.loads(lines[-1]) if lines else None
    except (OSError, ValueError):
        return None


def main(argv=None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_decode.json")
    ap.add_argument("--arch", default="qwen2.5-1.5b")
    ap.add_argument("--dispatch-n", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--n-requests", type=int, default=8)
    args = ap.parse_args(argv)
    rec = decode_path_metrics(arch=args.arch, dispatch_n=args.dispatch_n,
                              max_new=args.max_new,
                              n_requests=args.n_requests)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    print(json.dumps(rec, indent=2))
    sweep = [v["lengthaware_bytes_per_token"]
             for v in rec["bytes_read_context_sweep"].values()]
    paged = rec.get("paged", {})
    paged_ok = (
        bool(paged)
        and paged["token_exact_vs_dense"]
        and paged["pool_leak_free"]
        # paged bytes/token within 10% of dense at full occupancy
        and abs(paged["bytes_read_per_token_full_occupancy"]["ratio"] - 1.0)
        <= 0.10
        # admission proportional to bytes: strictly beats the dense lane
        # count whenever mean live context < max_len / 2
        and paged["admission_capacity"]["25%"]["paged_admitted"]
        > paged["dense_lane_capacity"]
        and paged["admission_capacity"]["50%"]["paged_admitted"]
        > paged["dense_lane_capacity"])
    ok = (rec["greedy_token_exact"]
          and rec["dispatch_reduction_x"] >= 5.0
          and all(a < b for a, b in zip(sweep, sweep[1:]))
          and rec["bytes_read_per_token"]["25%"][
              "lengthaware_bytes_per_token"]
          < rec["bytes_read_per_token"]["25%"]["masked_bytes_per_token"]
          and paged_ok)
    pfx = rec.get("prefix", {})
    pfx_ok = (
        bool(pfx)
        # sharing is a memory optimization: streams must not move
        and all(pfx["token_exact_vs_unshared"].values())
        and pfx["pool_leak_free"]
        and pfx["prefix_hits"] > 0
        and pfx["pages_saved"] > 0
        and pfx["cow_copies"] > 0
        # a cache hit prefills only the unmatched tail
        and pfx["ttft"]["hit_ms"] < pfx["ttft"]["miss_ms"]
        # hits reserve tail pages only: >= 2x admissions at ~50% overlap
        and pfx["effective_admission"]["admission_gain_x"] >= 2.0)
    ok = ok and pfx_ok
    mig = rec.get("migration", {})
    mig_ok = (
        bool(mig)
        and mig["resume_token_exact"]["greedy"]
        and mig["resume_token_exact"]["temperature"]
        and mig["preemptions"] > 0
        and mig["restores"] == mig["preemptions"]
        and mig["pages_migrated"] > 0)
    ok = ok and mig_ok
    mm = rec.get("multimodel", {})
    mm_ok = (
        bool(mm)
        and mm["per_model_token_exact"]["greedy"]
        and mm["per_model_token_exact"]["temperature"]
        and mm["token_counts_budget_invariant"]
        # roomy: exactly one cold load per model; tight: real churn
        and mm["model_swaps"]["roomy"] == len(mm["models"])
        and mm["model_swaps"]["tight"] > mm["model_swaps"]["roomy"]
        and mm["weight_evictions"]["tight"] > 0
        and mm["swap_bytes"]["tight"] > mm["swap_bytes"]["roomy"])
    ok = ok and mm_ok
    tel = rec.get("telemetry", {})
    tel_ok = (
        bool(tel)
        and tel["overhead_budget"]["tracing_neutral"]
        and tel["dispatch_span_count_matches_stats"]
        and tel["well_nested"]
        # sim-to-real drift gate: the scheduling model must fit the
        # measured replay, and a perturbed model must NOT fit
        and tel["calibration"]["ok"]
        and tel["perturbation_check"]["gate_self_test_pass"])
    ok = ok and tel_ok
    flt = rec.get("faults", {})
    oracle = flt.get("engine_oracle", {})
    sim = flt.get("sim", {})
    flt_ok = (
        bool(flt)
        # engine oracle: both recovery paths exercised, both bit-exact
        and oracle["resume_exact"]
        and oracle["replay_exact"]
        and oracle["counts_match"]
        and oracle["crashes"] == 1
        and oracle["recovered_lanes"] >= 1
        and oracle["replayed_from_prompt"] >= 1
        and oracle["retry_attempts"] > 0
        # fleet sim: recovery keeps goodput, no-recovery self-test loses
        and sim["with_recovery"]["crashes"] >= 1
        and sim["with_recovery"]["requests_lost"] == 0
        and sim["with_recovery"]["goodput_vs_base"] >= 0.90
        and sim["with_recovery"]["straggler_flags"] >= 1
        and sim["without_recovery"]["requests_lost"] > 0)
    ok = ok and flt_ok
    san = rec.get("sanitize", {})
    san_ok = (
        bool(san)
        # the sanitizer is a mirror, not a model change
        and san["token_exact_vs_unsanitized"]
        and san["violations"] == 0
        and san["ops_checked"] > 0
        and san["prefix_hits"] > 0           # CoW path actually ran
        and san["pool_leak_free"]
        and san["off_mode_monitor_detached"]
        # steady-state decode overhead sanitize-on stays under 5%
        and san["overhead_frac"] < 0.05)
    ok = ok and san_ok
    slt = rec.get("slo_tracing", {})
    ov = slt.get("overhead", {})
    cr = slt.get("crash_replay", {})
    fs = slt.get("fleet_sim", {})
    slo_ok = (
        bool(slt)
        # the observability stack is a mirror, not a model change
        and ov["token_exact_vs_unobserved"]
        and ov["overhead_frac"] < 0.05
        and cr["token_exact_vs_unobserved"]
        # crash replay: one crash, one flight dump, every request's
        # timeline gap-free, checkpointed lanes spanning both engines,
        # and the tight-objective controller demonstrably escalated
        and cr["crashes"] == 1
        and cr["flight_dumps"] == 1
        and "crash" in cr["flight_dump_reason"]
        and cr["requests"] > 0
        and cr["complete_timelines"] == cr["requests"]
        and cr["migrated_requests"] >= 1
        and cr["controller_escalated"]
        # fleet sim: the burn-rate loop walks the ladder up AND back
        # down to normal, losing nothing, with the crash dumped
        and fs["escalated"]
        and fs["deescalated"]
        and fs["final_level"] == "normal"
        and fs["requests_lost"] == 0
        and fs["timelines"] > 0
        and fs["complete_timelines"] == fs["timelines"]
        and fs["flight_dumps"] == 1)
    ok = ok and slo_ok
    print("BENCH_decode paged section:", "PASS" if paged_ok else "FAIL")
    print("BENCH_decode prefix section:", "PASS" if pfx_ok else "FAIL")
    print("BENCH_decode migration section:", "PASS" if mig_ok else "FAIL")
    print("BENCH_decode multimodel section:", "PASS" if mm_ok else "FAIL")
    print("BENCH_decode telemetry section:", "PASS" if tel_ok else "FAIL")
    print("BENCH_decode faults section:", "PASS" if flt_ok else "FAIL")
    print("BENCH_decode sanitize section:", "PASS" if san_ok else "FAIL")
    print("BENCH_decode slo_tracing section:", "PASS" if slo_ok else "FAIL")

    # -- bench history: append one row per run, gate on regression ----
    hist_path = os.path.join(os.path.dirname(os.path.abspath(args.out)),
                             "BENCH_history.jsonl")
    prev = _last_history_row(hist_path)
    row = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "git_sha": _git_sha(),
        "arch": rec["arch"],
        "tokens_per_s": rec["tokens_per_s"],
        "baseline_tokens_per_s": rec["baseline_tokens_per_s"],
        "dispatch_reduction_x": rec["dispatch_reduction_x"],
        "ttft_hit_ms": rec["prefix"]["ttft"]["hit_ms"],
        "ttft_miss_ms": rec["prefix"]["ttft"]["miss_ms"],
        "decode_dispatch_p50_s": rec["telemetry"]["phase_durations_s"]
        .get("span.decode.dispatch.seconds", {}).get("p50"),
        "decode_dispatch_p99_s": rec["telemetry"]["phase_durations_s"]
        .get("span.decode.dispatch.seconds", {}).get("p99"),
        "slo_overhead_frac": ov.get("overhead_frac"),
        "sections": {"paged": paged_ok, "prefix": pfx_ok,
                     "migration": mig_ok, "multimodel": mm_ok,
                     "telemetry": tel_ok, "faults": flt_ok,
                     "sanitize": san_ok, "slo_tracing": slo_ok},
        "pass": ok,
    }
    if prev is not None and prev.get("tokens_per_s"):
        delta = row["tokens_per_s"] / prev["tokens_per_s"] - 1.0
        print(f"BENCH_history tokens/s: {row['tokens_per_s']:.2f} "
              f"vs {prev['tokens_per_s']:.2f} "
              f"({prev.get('git_sha') or 'prev'}): {delta:+.1%}")
        if delta < -0.10:
            print("BENCH_decode history section: FAIL "
                  "(>10% tokens/s regression)")
            ok = False
        else:
            print("BENCH_decode history section: PASS")
    else:
        print("BENCH_history: first run, no baseline to compare")
    with open(hist_path, "a") as f:
        f.write(json.dumps(row) + "\n")

    print("BENCH_decode:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
