"""Paper Graph 4-2: llama-bench decode speed, Qwen2.5-1.5B x 6 formats.

Decode is bandwidth-bound; the theoretical ceiling is the paper's
A100-measured x (1493/1555) scaling.  Claims checked:

* default build lands in the 39-78% band
* noFMA lands in the 50-78% band
* f32/f16/q8_0 decode is FMA-insensitive
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.device_profile import CMP_170HX, CMP_170HX_NOFMA
from repro.core.perf_model import InferencePerfModel

FMTS = ("f32", "f16", "q8_0", "q6_k", "q4_k", "q2_k")


def rows() -> List[Row]:
    out: List[Row] = []
    md = InferencePerfModel(CMP_170HX)
    mn = InferencePerfModel(CMP_170HX_NOFMA)
    frac_d, frac_n = {}, {}
    for fmt in FMTS:
        dd = md.decode(fmt).tokens_per_s
        dn = mn.decode(fmt).tokens_per_s
        theo = md.theoretical_decode_tps(fmt)
        frac_d[fmt] = dd / theo
        frac_n[fmt] = dn / theo
        out.append(Row(f"decode[cmp-170hx/{fmt}]", 0.0,
                       f"{dd:.0f}t/s frac={dd/theo:.0%} "
                       f"bound={md.decode(fmt).bound}"))
        out.append(Row(f"decode[cmp-170hx-nofma/{fmt}]", 0.0,
                       f"{dn:.0f}t/s frac={dn/theo:.0%} gain={dn/dd:.2f}x"))
    band_d = all(0.35 <= frac_d[f] <= 0.80 for f in FMTS)
    band_n = all(0.50 <= frac_n[f] <= 0.80 for f in FMTS)
    out.append(Row("claim_4-2_default_band_39_78", 0.0,
                   " ".join(f"{f}={frac_d[f]:.0%}" for f in FMTS)
                   + (" (PASS)" if band_d else " (FAIL)")))
    out.append(Row("claim_4-2_nofma_band_50_78", 0.0,
                   " ".join(f"{f}={frac_n[f]:.0%}" for f in FMTS)
                   + (" (PASS)" if band_n else " (FAIL)")))
    stable = all(abs(frac_n[f] / frac_d[f] - 1) < 0.02
                 for f in ("f32", "f16", "q8_0"))
    out.append(Row("claim_4-2_dense_q8_fma_insensitive", 0.0,
                   "PASS" if stable else "FAIL"))
    return out
