"""Paper Graph 3-5: memory bandwidth (+ EX.2 interconnect in interconnect.py).

The mining SKU retains its full HBM2e bandwidth -- the paper's central
asset.  Rows give the per-profile achievable stream bandwidth (GEMV
efficiency included) and run a low-intensity mixbench point (iters=1 ->
0.5 flops/byte, pure streaming) as the functional artifact.
"""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

from benchmarks.common import Row, time_call
from repro.core.device_profile import (A100_40G, CMP_170HX, CMP_170HX_NOFMA,
                                       TPU_V5E)
from repro.kernels.mixbench import mixbench


def rows() -> List[Row]:
    out: List[Row] = []
    x = jnp.linspace(0, 1, 1 << 16, dtype=jnp.float32)
    us = time_call(mixbench, x, iters=1, variant="fma", interpret=True)
    out.append(Row("membw_stream_kernel", us,
                   f"bytes={x.nbytes * 2}"))
    for prof in (CMP_170HX, CMP_170HX_NOFMA, A100_40G, TPU_V5E):
        out.append(Row(f"membw[{prof.name}]", 0.0,
                       f"{prof.hbm_bw_gbps:.0f}GB/s"
                       f"(gemv={prof.hbm_bw_gbps * prof.gemv_efficiency:.0f})"))
    # claim: CMP retains ~A100-class bandwidth (ratio vs 1555)
    ratio = CMP_170HX.hbm_bw_gbps / A100_40G.hbm_bw_gbps
    out.append(Row("claim_3-5", 0.0,
                   f"cmp/a100_bw={ratio:.2f}"
                   f"{'(PASS>0.8)' if ratio > 0.8 else '(FAIL)'}"))
    return out
