"""Fleet simulator tests: determinism, planner agreement, handoff
scaling, routing/autoscaling behavior, and execution-backed token
accounting against the real ServeEngine."""

import jax
import pytest

from repro.core.device_profile import get_profile
from repro.fleet import (CostAwareRouter, FleetSim, NodeSpec,
                         QueueDepthAutoscaler, SLOAwareRouter, bursty_trace,
                         constant_trace, fleet_from_plan, poisson_trace,
                         validate_token_accounting)
from repro.fleet.workload import FleetRequest, LengthDist
from repro.serving import Workload, kv_handoff_seconds, plan_fleet

WL = Workload(prompt_len=512, gen_len=128, fmt="q8_0")
MIXED_POOLS = {"a100-40g": 2, "cmp-170hx-nofma": 8}


@pytest.fixture(scope="module")
def plan():
    return plan_fleet(MIXED_POOLS, WL)


def test_trace_determinism():
    a = bursty_trace(40.0, 30.0, seed=7,
                     prompt=LengthDist(512, cv=0.3),
                     gen=LengthDist(128, cv=0.3))
    b = bursty_trace(40.0, 30.0, seed=7,
                     prompt=LengthDist(512, cv=0.3),
                     gen=LengthDist(128, cv=0.3))
    c = bursty_trace(40.0, 30.0, seed=8,
                     prompt=LengthDist(512, cv=0.3),
                     gen=LengthDist(128, cv=0.3))
    assert a == b
    assert a != c
    assert all(x.arrival_s <= y.arrival_s for x, y in zip(a, a[1:]))


def test_sim_deterministic_under_fixed_seed(plan):
    trace = bursty_trace(40.0, 60.0, seed=7,
                         prompt=LengthDist(512, cv=0.3),
                         gen=LengthDist(128, cv=0.3))
    specs = fleet_from_plan(plan, decode_lanes=4)
    r1 = FleetSim(specs, trace, fmt=WL.fmt,
                  ttft_slo_s=2.0, tpot_slo_s=0.05).run()
    r2 = FleetSim(specs, trace, fmt=WL.fmt,
                  ttft_slo_s=2.0, tpot_slo_s=0.05).run()
    assert r1.metrics() == r2.metrics()
    assert r1.completed == r1.offered


def test_steady_state_matches_planner(plan):
    """Overdriven constant-rate trace: completions/s == planner capacity."""
    trace = constant_trace(plan.requests_per_s * 1.2, 60.0,
                           WL.prompt_len, WL.gen_len)
    rep = FleetSim(fleet_from_plan(plan), trace, fmt=WL.fmt).run()
    assert rep.completed == rep.offered
    assert rep.requests_per_s == pytest.approx(plan.requests_per_s,
                                               rel=0.10)


def test_steady_state_matches_planner_homogeneous():
    """Colocated (role=both) fleets must agree with the planner too --
    neither side charges a KV handoff when decode stays on-board."""
    from repro.serving import homogeneous_baseline

    hplan = homogeneous_baseline("cmp-170hx-nofma", 8, WL)
    trace = constant_trace(hplan.requests_per_s * 1.2, 60.0,
                           WL.prompt_len, WL.gen_len)
    rep = FleetSim([NodeSpec("cmp-170hx-nofma", 8, "both")], trace,
                   fmt=WL.fmt).run()
    assert rep.requests_per_s == pytest.approx(hplan.requests_per_s,
                                               rel=0.10)


def _single_request_sim(prompt_len: int) -> FleetSim:
    trace = [FleetRequest(uid=0, arrival_s=0.0, prompt_len=prompt_len,
                          gen_len=32)]
    specs = [NodeSpec("a100-40g", 1, "prefill"),
             NodeSpec("cmp-170hx-nofma", 1, "decode")]
    sim = FleetSim(specs, trace, fmt=WL.fmt)
    sim.run()
    return sim


def test_kv_handoff_scales_with_prompt_len():
    a100, cmp = get_profile("a100-40g"), get_profile("cmp-170hx-nofma")
    h512 = kv_handoff_seconds(a100, 512, peer=cmp)
    h1024 = kv_handoff_seconds(a100, 1024, peer=cmp)
    assert h1024 == pytest.approx(2.0 * h512)
    # and the simulator charges exactly that delay between phases
    for plen, expect in [(512, h512), (1024, h1024)]:
        rec = _single_request_sim(plen).records[0]
        assert rec.done
        got = rec.t_decode_enter - rec.t_prefill_done
        assert got == pytest.approx(expect, rel=1e-9)
    # the CMP's PCIe-1.1-x4 link dominates the bottleneck handoff
    assert h512 > kv_handoff_seconds(a100, 512)


def test_disaggregated_beats_homogeneous_on_goodput(plan):
    trace = bursty_trace(60.0, 60.0, seed=0)
    slo = dict(ttft_slo_s=2.0, tpot_slo_s=0.05)
    mixed = FleetSim(fleet_from_plan(plan, decode_lanes=4), trace,
                     fmt=WL.fmt, **slo).run()
    homo_a = FleetSim([NodeSpec("a100-40g", 2, "both", 4)], trace,
                      fmt=WL.fmt, **slo).run()
    homo_c = FleetSim([NodeSpec("cmp-170hx-nofma", 8, "both", 4)], trace,
                      fmt=WL.fmt, **slo).run()
    assert mixed.goodput_rps > homo_a.goodput_rps
    assert mixed.goodput_rps > homo_c.goodput_rps


def test_router_policies_complete_workload(plan):
    trace = poisson_trace(20.0, 30.0, seed=1)
    specs = fleet_from_plan(plan, decode_lanes=4)
    for router in (CostAwareRouter(),
                   SLOAwareRouter(ttft_slo_s=2.0, tpot_slo_s=0.05)):
        rep = FleetSim(specs, trace, fmt=WL.fmt, router=router).run()
        assert rep.completed == rep.offered, router.name


def test_autoscaler_grows_pool_and_cuts_tail(plan):
    from repro.fleet import diurnal_trace

    trace = diurnal_trace(base_rps=5.0, peak_rps=60.0, duration_s=120.0,
                          seed=3, period_s=120.0)
    base = [NodeSpec("a100-40g", 2, "prefill", 1),
            NodeSpec("cmp-170hx-nofma", 2, "decode", 4)]
    asc = QueueDepthAutoscaler(
        template=NodeSpec("cmp-170hx-nofma", 1, "decode", 4),
        interval_s=10.0, min_nodes=2, max_nodes=16, cold_start_s=15.0)
    scaled = FleetSim(base, trace, fmt=WL.fmt, autoscaler=asc).run()
    fixed = FleetSim(base, trace, fmt=WL.fmt).run()
    assert any("+1" in ev for ev in scaled.scale_events)
    assert scaled.completed == scaled.offered
    assert scaled.ttft_p99_s < fixed.ttft_p99_s


def test_execution_backed_token_accounting():
    """Simulator token claims must match the real engine's counts."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("qwen2.5-1.5b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    trace = [FleetRequest(uid=i, arrival_s=0.1 * (i + 1),
                          prompt_len=8 + i, gen_len=4 + (i % 3))
             for i in range(5)]
    sim = FleetSim([NodeSpec("a100-40g", 1, "prefill"),
                    NodeSpec("cmp-170hx-nofma", 1, "decode", 2)],
                   trace, fmt=WL.fmt)
    report = sim.run()
    assert report.completed == len(trace)
    result = validate_token_accounting(sim, report, cfg, params,
                                       n_lanes=2, max_len=32)
    assert result["match"], result["mismatches"]
    assert result["sim_gen_tokens"] == result["engine_gen_tokens"]
    assert result["sim_prompt_tokens"] == result["engine_prompt_tokens"]
