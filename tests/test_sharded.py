"""Multi-device integration tests (subprocess-forced host devices).

These run small sharded programs on 8 forced CPU devices in a
subprocess (the main pytest process must keep 1 device), validating:

* FSDP+TP train step == single-device train step numerically,
* the serve-mode decode step compiles + runs under a mesh,
* the int8 compressed all-reduce inside shard_map,
* a reduced end-to-end dry-run cell (lower+compile+cost/memory record).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_matches_single_device():
    print(_run("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.models import build_model
    from repro.parallel.sharding import param_shardings, use_mesh
    from repro.train import TrainConfig, init_train_state, make_train_step

    cfg = get_config("olmo-1b", smoke=True)
    model = build_model(cfg)
    step = make_train_step(cfg, TrainConfig(remat=False, microbatches=1))
    state = init_train_state(model, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32),
                                          0, cfg.vocab_size)}
    # single device reference
    s1, m1 = jax.jit(step)(state, batch)
    # sharded (data=4, model=2)
    mesh = make_test_mesh((4, 2), ("data", "model"))
    sh = param_shardings(mesh, state)
    with use_mesh(mesh):
        sharded = jax.jit(step, in_shardings=(sh, None),
                          out_shardings=(sh, None))
        s2, m2 = sharded(jax.device_put(state, sh), batch)
    l1, l2 = float(m1["loss"]), float(m2["loss"])
    assert abs(l1 - l2) / l1 < 2e-2, (l1, l2)
    import numpy as np
    a = np.asarray(s1.params["embed"]["tok"], dtype=np.float32)
    b = np.asarray(s2.params["embed"]["tok"], dtype=np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 5e-2, err
    print("sharded==single OK", l1, l2)
    """))


def test_sharded_decode_step():
    print(_run("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.models import build_model
    from repro.models.transformer import init_cache, lm_decode_step
    from repro.parallel.sharding import (cache_shardings, param_shardings,
                                         use_mesh)

    cfg = get_config("qwen2.5-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jnp.array([1, 2, 3, 4], jnp.int32)
    cache = init_cache(cfg, 4, 64)
    ref_logits, _ = lm_decode_step(params, cfg, cache, tok)

    mesh = make_test_mesh((4, 2), ("data", "model"))
    p_sh = param_shardings(mesh, params, mode="serve")
    c_sh = cache_shardings(mesh, cache)
    with use_mesh(mesh, mode="serve"):
        f = jax.jit(lambda p, c, t: lm_decode_step(p, cfg, c, t),
                    in_shardings=(p_sh, c_sh, None))
        logits, new_cache = f(jax.device_put(params, p_sh),
                              jax.device_put(cache, c_sh), tok)
    import numpy as np
    err = np.max(np.abs(np.asarray(logits[:, :cfg.vocab_size])
                        - np.asarray(ref_logits[:, :cfg.vocab_size])))
    assert err < 1e-2, err
    assert int(new_cache["len"][0]) == 1
    print("sharded decode OK", float(err))
    """))


def test_compressed_psum_shard_map():
    print(_run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.optim.compression import compressed_psum

    mesh = jax.make_mesh((2, 4), ("pod", "data"),
                         devices=jax.devices()[:8])
    g = jax.random.normal(jax.random.PRNGKey(0), (2, 4096)) * 1e-3

    def f(gl):
        mean, resid = compressed_psum(gl[0], axis="pod")
        return mean[None], resid[None]

    mean, resid = shard_map(f, mesh=mesh, in_specs=P("pod"),
                            out_specs=P("pod"))(g)
    exact = jnp.mean(g, axis=0)
    err = float(jnp.max(jnp.abs(mean[0] - exact)) /
                (jnp.max(jnp.abs(exact)) + 1e-12))
    assert err < 0.02, err
    print("compressed psum OK", err)
    """))


def test_dryrun_single_cell_production_mesh():
    """Full run_cell end to end (512 forced devices, whisper train cell):
    proves the dry-run path lowers, compiles, fits, and records costs."""
    out = _run("""
    import os
    os.environ["REPRO_DRYRUN_DEVICES"] = "512"
    os.environ["REPRO_MICROBATCHES"] = "16"
    from repro.launch import dryrun
    rec = dryrun.run_cell("whisper-base", "train_4k", False,
                          cost_pass=False, verbose=False)
    assert rec["fits_16g"], rec
    assert rec["hlo_flops"] > 0 and rec["collective_bytes"] > 0
    assert rec["chips"] == 256
    print("dryrun cell OK", rec["bytes_per_device"])
    """, devices=1, timeout=560)
    assert "dryrun cell OK" in out
