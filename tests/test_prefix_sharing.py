"""Copy-on-write KV prefix sharing: radix prompt cache over the pool.

Four layers of invariants:

* allocator -- PagePool refcounts: ``share`` adds a holder, ``free``
  drops one (physical return only at the LAST drop), ``cow`` swaps a
  shared reference for a fresh exclusive page drawn from the caller's
  reservation, and every misuse (cow of an exclusive page, cow without
  a reservation, share of a free page) trips an assert;
* radix cache -- ``match`` returns the longest cached prefix in whole
  pages, always leaves >= 1 unmatched tail token, caps full pages at
  ``(plen - 1) // page_size``; eviction is LRU over LEAVES only (an
  interior page never outlives the prefixes extending it) and
  ``flush`` releases every cache reference;
* engine -- serving shared-prefix prompts with ``prefix_sharing=True``
  is bit-exact vs the non-shared paged engine for greedy AND seeded
  temperature, dense AND int8 KV; a consumer's divergent append onto a
  shared partial page copies-on-write without disturbing the donor;
  cache pages are trimmed (not leaked) under admission pressure; and a
  prefix-hit lane survives evict -> restore (same engine AND a fresh
  one) bit-identically -- shared pages are deep-copied at gather and
  re-anchored onto exclusive pages at restore;
* fleet -- the execution replay reproduces non-shared token counts over
  a ``shared_prefix_trace`` while reporting hits / pages saved, and
  preemption exactness holds with sharing enabled; the multi-model
  engine flushes a model's cache on weight unload (cold cache after
  reload, zero phantom page refs) without moving a token.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import PagePool, PrefixCache, Request, ServeEngine
from repro.serving.engine import prefix_sharing_supported

pytestmark = pytest.mark.prefix

PAGE = 8
ENGINE_KW = dict(n_lanes=2, max_len=32, dispatch_n=4, paged=True,
                 page_size=PAGE, rng_seed=7)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2.5-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _head(cfg, n=2 * PAGE, seed=11):
    """A shared prompt head covering ``n // PAGE`` full pages."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, n, dtype=np.int32)


def _family(cfg, head, tail_lens, seed=12):
    """Prompts that OPEN with ``head`` and diverge into unique tails."""
    rng = np.random.default_rng(seed)
    return [np.concatenate(
                [head, rng.integers(0, cfg.vocab_size, t, dtype=np.int32)])
            for t in tail_lens]


def _reqs(prompts, max_new):
    return [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


def _serve(cfg, params, prompts, max_new, **kw):
    reqs = _reqs(prompts, max_new)
    eng = ServeEngine(cfg, params, **kw)
    eng.run(reqs)
    return [tuple(r.generated) for r in reqs], eng


def _drain(*engines):
    for eng in engines:
        while eng.live_lanes():
            eng.decode_n()


def _flush_and_check_empty(*engines):
    """Release cache refs and pin the leak-free postcondition."""
    for eng in engines:
        if eng.prefix_cache is not None:
            eng.prefix_cache.flush()
        eng.pool.check()
        assert eng.pool.n_in_use == 0


# ----------------------------------------------------------------------
# allocator: refcounts, share / free / cow semantics
# ----------------------------------------------------------------------

def test_pagepool_refcount_share_free_cow():
    """A shared page returns to the free list only at the LAST drop,
    and cow exchanges a shared ref for a reserved exclusive page."""
    pool = PagePool(4, PAGE)
    assert pool.reserve(3)
    a, b = pool.alloc(2)
    assert pool.refcount(a) == pool.refcount(b) == 1
    assert not pool.is_shared(a)

    pool.share([a])                      # second holder (e.g. the cache)
    assert pool.refcount(a) == 2 and pool.is_shared(a)
    assert pool.n_refs == 3 and pool.n_shared == 1
    pool.free([a])                       # first drop: page stays in use
    assert pool.refcount(a) == 1 and a not in pool._free
    assert pool.n_in_use == 2

    pool.share([a])                      # re-share, then cow-split it
    new = pool.cow(a)                    # draws the remaining reservation
    assert new != a and pool.refcount(new) == 1
    assert pool.refcount(a) == 1         # caller's ref moved to `new`
    assert pool._reserved == 0
    pool.check()

    pool.free([a, b, new])               # last drops: physical returns
    assert pool.n_in_use == 0 and pool.n_free == pool.n_pages
    assert pool.cow_count == 1 and pool.share_count == 2


def test_pagepool_share_cow_guards():
    """Misuse trips asserts: share of a free page, cow of an exclusive
    page, cow without a reservation, and double physical free."""
    pool = PagePool(4, PAGE)
    assert pool.reserve(2)
    (p,) = pool.alloc(1)
    with pytest.raises(AssertionError):
        pool.share([p + 1])              # not allocated
    with pytest.raises(AssertionError):
        pool.cow(p)                      # refcount 1: nothing shared
    pool.share([p])
    pool.unreserve(1)
    with pytest.raises(AssertionError):
        pool.cow(p)                      # shared, but no reservation
    pool.free([p])
    pool.free([p])                       # drops the second holder
    with pytest.raises(AssertionError):
        pool.free([p])                   # page already free
    pool.check()


# ----------------------------------------------------------------------
# radix cache: match / insert / LRU-leaf eviction / flush
# ----------------------------------------------------------------------

def _cached_pages(pool, n):
    """Allocate ``n`` donor pages and hand their ONLY reference to the
    caller (mimics a prefilled lane about to be cached)."""
    assert pool.reserve(n)
    return pool.alloc(n)


def test_prefix_cache_match_caps_and_partial():
    """Full-page matches cap at ``(plen - 1) // PAGE`` (>= 1 tail token
    always re-runs), and a partial tail page only matches when it fits
    strictly inside the prompt."""
    pool = PagePool(8, PAGE)
    cache = PrefixCache(pool, PAGE)
    prompt = np.arange(20, dtype=np.int32)       # 2 full pages + 4 tail
    pages = _cached_pages(pool, 3)
    assert cache.insert(prompt, 20, pages) == 3  # 2 full + 1 partial
    pool.free(pages)                             # donor retires
    assert pool.n_in_use == 3                    # cache refs keep them

    # identical prompt: both full pages match, but its own partial tail
    # covers tokens [16, 20) and would leave NO tail token to re-run
    # (pos 16 + 4 > plen - 1 = 19), so it must NOT match
    got, matched, partial = cache.match(prompt)
    assert got == pages[:2] and partial is None
    assert matched == 16 <= len(prompt) - 1

    # exactly page-aligned prompt: the cap forfeits the last full page
    aligned = prompt[:16]
    got2, matched2, partial2 = cache.match(aligned)
    assert len(got2) == (16 - 1) // PAGE == 1
    assert matched2 <= 15 and partial2 is None

    # extension prompt: partial now fits inside plen - 1 and matches
    ext = np.concatenate([prompt, np.arange(100, 102, dtype=np.int32)])
    got3, matched3, partial3 = cache.match(ext)
    assert got3 == pages[:2] and partial3 == (pages[2], 4)
    assert matched3 == 20
    assert cache.hits >= 2 and cache.misses >= 0
    cache.flush()
    assert pool.n_in_use == 0


def test_prefix_cache_lru_leaf_eviction_and_flush():
    """Eviction drops the least-recently-matched LEAF: an interior page
    is never dropped while a cached prefix still extends it, and flush
    releases every reference the cache holds."""
    pool = PagePool(8, PAGE)
    cache = PrefixCache(pool, PAGE)
    chain = np.arange(17, dtype=np.int32)        # 2 full pages + 1 tail
    p_chain = _cached_pages(pool, 2)
    cache.insert(chain, 16, p_chain, allow_partial=False)
    other = np.arange(100, 109, dtype=np.int32)  # unrelated, 1 full page
    p_other = _cached_pages(pool, 1)
    cache.insert(other, 8, p_other, allow_partial=False)
    pool.free(p_chain + p_other)
    assert cache.n_pages == 3

    cache.match(chain)                           # chain is now MRU
    assert cache.evict_lru()                     # drops `other`'s leaf
    assert pool.n_in_use == 2
    assert cache.match(chain)[0] == p_chain      # chain intact
    assert cache.evict_lru()                     # leaf of the chain
    assert cache.match(chain)[0] == p_chain[:1]  # interior survives
    assert cache.evictions == 2
    assert cache.flush() == 1
    assert cache.n_pages == 0 and pool.n_in_use == 0
    assert not cache.evict_lru()                 # empty: nothing to drop


def test_prefix_cache_max_pages_budget():
    """A soft page budget evicts LRU leaves at insert time."""
    pool = PagePool(8, PAGE)
    cache = PrefixCache(pool, PAGE, max_pages=2)
    for fam in range(3):
        prompt = np.full(9, 100 * fam, dtype=np.int32)
        pages = _cached_pages(pool, 1)
        cache.insert(prompt, 8, pages, allow_partial=False)
        pool.free(pages)
        assert cache.n_pages <= 2
    assert cache.evictions >= 1
    cache.flush()
    assert pool.n_in_use == 0


# ----------------------------------------------------------------------
# engine: shared-prefix exactness, CoW, cache trim under pressure
# ----------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.9])
@pytest.mark.parametrize("kv_quant", [None, "int8"])
def test_engine_prefix_sharing_token_exact(small_model, temperature,
                                           kv_quant):
    """Serving a shared-prefix family with sharing on reproduces the
    non-shared engine bit for bit (greedy + temperature, dense + int8)
    while actually hitting the cache and saving pages."""
    cfg, params = small_model
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
    head = _head(cfg)                            # 16 tokens, 2 pages
    prompts = _family(cfg, head, [4, 6, 8])      # plen 20 / 22 / 24
    prompts.append(_head(cfg, 12, seed=13))      # unrelated miss
    kw = dict(ENGINE_KW, temperature=temperature)

    base, beng = _serve(cfg, params, prompts, 6, **kw)
    shared, seng = _serve(cfg, params, prompts, 6, prefix_sharing=True,
                          **kw)
    assert shared == base
    assert seng.stats["prefix_hits"] >= 2        # two family followers
    assert seng.stats["prefix_pages_saved"] >= 2
    assert seng.stats["prefix_tokens_matched"] >= 2 * len(head)
    assert beng.stats["prefix_hits"] == 0        # sharing off: inert
    _flush_and_check_empty(beng, seng)


def test_engine_cow_on_divergent_append(small_model):
    """A consumer that maps the donor's partial tail page copies it on
    write: its stream AND the still-decoding donor's stream both match
    the non-shared run."""
    cfg, params = small_model
    head = _head(cfg)
    donor = _family(cfg, head, [4])[0]           # plen 20: 2 full + 4
    ext = np.concatenate(                        # donor prompt + 2 more
        [donor, np.array([3, 5], dtype=np.int32)])
    prompts = [donor, ext]

    base, _ = _serve(cfg, params, prompts, 8, **ENGINE_KW)
    shared, eng = _serve(cfg, params, prompts, 8, prefix_sharing=True,
                         **ENGINE_KW)
    assert shared == base
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefix_tokens_matched"] == 20   # 2 full + partial
    assert eng.stats["prefix_cow_copies"] >= 1        # divergent append
    _flush_and_check_empty(eng)


def test_engine_trims_cache_under_admission_pressure(small_model):
    """When cached pages crowd the pool, admission trims the cache
    (LRU) instead of deadlocking -- every request completes and nothing
    leaks."""
    cfg, params = small_model
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, 9, dtype=np.int32)
               for _ in range(6)]                # 6 distinct families
    shared, eng = _serve(cfg, params, prompts, 4, prefix_sharing=True,
                         n_pages=8, **ENGINE_KW)
    assert all(len(s) == 4 for s in shared)
    assert eng.stats["prefix_evictions"] > 0
    assert eng.stats["kv_pages_hwm"] <= 8
    _flush_and_check_empty(eng)


def test_prefix_sharing_supported_predicate(small_model):
    """Sharing is attention-paged-only: sliding-window and recurrent
    families are refused by the predicate AND the constructor."""
    cfg, params = small_model
    assert prefix_sharing_supported(cfg)
    assert not prefix_sharing_supported(
        dataclasses.replace(cfg, sliding_window=16))
    assert not prefix_sharing_supported(
        dataclasses.replace(cfg, family="ssm"))
    assert not prefix_sharing_supported(
        dataclasses.replace(cfg, family="hybrid"))
    with pytest.raises(AssertionError):
        ServeEngine(dataclasses.replace(cfg, sliding_window=16), params,
                    prefix_sharing=True, **ENGINE_KW)


# ----------------------------------------------------------------------
# engine: evict / restore of a prefix-hit lane
# ----------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.9])
@pytest.mark.parametrize("kv_quant", [None, "int8"])
def test_evict_restore_prefix_hit_lane_cross_engine(small_model,
                                                    temperature,
                                                    kv_quant):
    """A lane admitted ON a cache hit (its head pages shared with the
    radix cache) is evicted mid-decode and restored on a FRESH engine:
    the stream must equal the unpreempted non-shared run -- the gather
    deep-copies shared pages, restore re-anchors them exclusively."""
    cfg, params = small_model
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
    head = _head(cfg)
    donor, consumer = _family(cfg, head, [4, 6])
    kw = dict(ENGINE_KW, temperature=temperature)

    base, _ = _serve(cfg, params, [donor, consumer], 10, **kw)

    skw = dict(kw, prefix_sharing=True)
    src = ServeEngine(cfg, params, **skw)
    dreq = Request(uid=0, prompt=donor.copy(), max_new_tokens=10)
    src.run([dreq])                          # retire donor, warm cache
    creq = Request(uid=1, prompt=consumer.copy(), max_new_tokens=10)
    assert src.admit(creq)
    assert src.stats["prefix_hits"] == 1     # consumer rode the cache
    src.decode_n()                           # a few tokens in
    lane = next(i for i, r in enumerate(src.lane_req) if r is creq)
    ckpt = src.evict(lane)

    dst = ServeEngine(cfg, params, **skw)    # fresh board, cold cache
    assert dst.restore(ckpt)
    _drain(src, dst)

    assert [tuple(dreq.generated), tuple(creq.generated)] == list(base)
    assert dst.stats["pages_migrated"] == ckpt.n_pages > 0
    _flush_and_check_empty(src, dst)


def test_evict_restore_prefix_hit_lane_same_engine(small_model):
    """Same-engine evict -> restore of a prefix-hit lane while the
    donor pages stay pinned by the cache."""
    cfg, params = small_model
    head = _head(cfg)
    donor, consumer = _family(cfg, head, [4, 6])
    kw = dict(ENGINE_KW, temperature=0.9)

    base, _ = _serve(cfg, params, [donor, consumer], 10, **kw)

    eng = ServeEngine(cfg, params, prefix_sharing=True, **kw)
    dreq = Request(uid=0, prompt=donor.copy(), max_new_tokens=10)
    eng.run([dreq])
    creq = Request(uid=1, prompt=consumer.copy(), max_new_tokens=10)
    assert eng.admit(creq)
    eng.decode_n()
    lane = next(i for i, r in enumerate(eng.lane_req) if r is creq)
    ckpt = eng.evict(lane)
    eng.pool.check()                         # cache refs survive evict
    assert eng.restore(ckpt)
    _drain(eng)

    assert [tuple(dreq.generated), tuple(creq.generated)] == list(base)
    assert eng.stats["preemptions"] == eng.stats["restores"] == 1
    _flush_and_check_empty(eng)


# ----------------------------------------------------------------------
# fleet: replay + preemption exactness + multi-model cache invalidation
# ----------------------------------------------------------------------

def test_execution_replay_shared_prefix_trace(small_model):
    """The trace replay over a shared-prefix workload reproduces the
    non-shared token counts and surfaces hits / pages saved."""
    from repro.fleet.execution import run_trace_on_engine
    from repro.fleet.workload import FleetRequest, shared_prefix_trace

    cfg, params = small_model
    trace = shared_prefix_trace(
        [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=18 + i % 3,
                      gen_len=4) for i in range(6)],
        prefix_len=2 * PAGE, n_prefixes=2, seed=1)
    kw = dict(n_lanes=2, max_len=32, dispatch_n=4, paged=True,
              page_size=PAGE)
    plain = run_trace_on_engine(trace, cfg, params, **kw)
    shared = run_trace_on_engine(trace, cfg, params,
                                 prefix_sharing=True, **kw)
    assert shared.gen_by_uid == plain.gen_by_uid
    assert shared.prefix_hits > 0 and shared.prefix_pages_saved > 0
    assert plain.prefix_hits == 0


def test_preemption_exactness_with_sharing(small_model):
    """Evict-and-replay churn over a shared-prefix trace must not move
    a token when both replays share cached prefixes."""
    from repro.fleet.execution import validate_preemption_exactness
    from repro.fleet.workload import FleetRequest, shared_prefix_trace

    cfg, params = small_model
    trace = shared_prefix_trace(
        [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=18,
                      gen_len=6) for i in range(4)],
        prefix_len=2 * PAGE, n_prefixes=1, seed=2)
    result = validate_preemption_exactness(
        trace, cfg, params, preempt_every=1, prefix_sharing=True,
        n_lanes=2, max_len=32, dispatch_n=4, page_size=PAGE,
        temperature=0.8)
    assert result["resume_exact"], result["mismatches"]
    assert result["preemptions"] > 0


def test_modelpool_flushes_cache_on_unload(small_model):
    """Weight unload invalidates the model's radix cache (its pages
    index KV the outgoing weights computed): the page refs drop at
    unload, the reload starts cache-cold, and the full stream still
    equals one uninterrupted single-engine run."""
    from repro.serving import (ModelPool, MultiModelServeEngine,
                               kv_page_bytes, params_nbytes)

    cfg, params = small_model
    hbm = params_nbytes(params) + 12 * kv_page_bytes(cfg, PAGE)
    pool = ModelPool(hbm, page_size=PAGE)
    pool.register("a", cfg, params)
    mm_kw = dict(n_lanes=2, max_len=32, dispatch_n=4, rng_seed=7)
    mm = MultiModelServeEngine(pool, prefix_sharing=True, **mm_kw)

    head = _head(cfg)
    prompts = _family(cfg, head, [4, 6, 8])
    reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=5,
                    model_id="a") for i, p in enumerate(prompts)]
    mm.run(reqs[:2])
    eng = mm.engines["a"]
    assert eng.stats["prefix_hits"] >= 1
    assert eng.prefix_cache.n_pages > 0

    assert mm.unload("a")                    # flush + zero-ref assert
    assert "a" not in mm.engines

    mm.run([reqs[2]])                        # reload: cache starts cold
    eng2 = mm.engines["a"]
    assert eng2.stats["prefix_misses"] >= 1
    assert eng2.stats["prefix_hits"] == 0

    solo, _ = _serve(cfg, params, prompts, 5, **ENGINE_KW)
    assert [tuple(r.generated) for r in reqs] == list(solo)
    _flush_and_check_empty(*mm.engines.values())
