"""Launcher CLIs end to end (subprocess, CPU, smoke configs)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_train_launcher_with_resume(tmp_path):
    ck = str(tmp_path / "ck")
    out1 = _run(["repro.launch.train", "--arch", "olmo-1b", "--smoke",
                 "--steps", "12", "--batch", "2", "--seq", "64",
                 "--ckpt-dir", ck, "--ckpt-every", "6", "--log-every", "6"])
    assert "done" in out1
    out2 = _run(["repro.launch.train", "--arch", "olmo-1b", "--smoke",
                 "--steps", "16", "--batch", "2", "--seq", "64",
                 "--ckpt-dir", ck, "--ckpt-every", "8", "--log-every", "4"])
    assert "resumed from step 12" in out2


@pytest.mark.slow
def test_serve_launcher_quantized():
    out = _run(["repro.launch.serve", "--arch", "qwen2.5-1.5b", "--smoke",
                "--quant", "q8_0", "--requests", "2", "--prompt-len", "8",
                "--gen", "4", "--lanes", "2"])
    assert "served 2 requests" in out
    assert "capability-model prediction" in out
