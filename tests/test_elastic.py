"""Elastic re-mesh end to end: train on an 8-chip mesh, lose hosts,
resume from checkpoint on the surviving 4-chip mesh (TP width preserved,
data axis shrunk) and keep training -- the full elastic-scaling path."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_elastic_resume_smaller_mesh(tmp_path):
    code = f"""
    import jax, jax.numpy as jnp
    from repro.checkpoint import restore_latest, save
    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.models import build_model
    from repro.parallel.sharding import param_shardings, use_mesh
    from repro.train import TrainConfig, init_train_state, make_train_step
    from repro.train.fault_tolerance import elastic_remesh_plan

    ckdir = {str(tmp_path)!r}
    cfg = get_config("olmo-1b", smoke=True)
    model = build_model(cfg)
    step = make_train_step(cfg, TrainConfig(remat=False, microbatches=1))
    batch = {{"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                           0, cfg.vocab_size),
              "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32),
                                           0, cfg.vocab_size)}}

    # phase 1: (data=4, model=2) -- 8 chips
    mesh = make_test_mesh((4, 2), ("data", "model"))
    state = init_train_state(model, jax.random.PRNGKey(0))
    sh = param_shardings(mesh, state)
    with use_mesh(mesh):
        f = jax.jit(step, in_shardings=(sh, None), out_shardings=(sh, None))
        state = jax.device_put(state, sh)
        losses = []
        for i in range(3):
            state, m = f(state, batch)
            losses.append(float(m["loss"]))
    save(ckdir, 3, jax.tree_util.tree_map(lambda x: jax.device_get(x),
                                          state))

    # phase 2: four chips "fail" -> re-mesh plan preserves TP width
    plan = elastic_remesh_plan(n_alive_chips=4, model_parallel=2)
    assert plan == (2, 2), plan
    mesh2 = make_test_mesh(plan, ("data", "model"))
    template = init_train_state(model, jax.random.PRNGKey(0))
    got, restored = restore_latest(ckdir, template)
    assert got == 3
    sh2 = param_shardings(mesh2, restored)
    with use_mesh(mesh2):
        f2 = jax.jit(step, in_shardings=(sh2, None),
                     out_shardings=(sh2, None))
        state2 = jax.device_put(restored, sh2)
        for i in range(3):
            state2, m2 = f2(state2, batch)
            losses.append(float(m2["loss"]))
    # loss continues from where it left off (monotone on a repeated batch)
    assert losses[3] < losses[0], losses
    assert losses[-1] < losses[3], losses
    print("elastic resume OK", [round(x, 3) for x in losses])
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=480,
                         env=env)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "elastic resume OK" in out.stdout
