"""Multi-model serving: shared HBM budget, weight paging, fleet routing.

Five layers of invariants:

* engine -- two models served concurrently by ``MultiModelServeEngine``
  produce token streams BIT-IDENTICAL to each model running alone in a
  single-model ``ServeEngine`` (greedy + temperature, dense + int8 KV),
  and an unload/reload round-trips exactly (the admission counter --
  the sampling lineage -- survives residency churn);
* budget -- weights and KV pages share one byte budget: loading a
  second model shrinks the first pool's FREE pages (never live ones),
  unloading grows them back, and a model serving live lanes is never
  unloaded (LRU eviction considers idle residents only);
* allocator -- ``PagePool`` conservation under randomized
  reserve/alloc/free/unreserve/shrink/grow churn (hypothesis), and
  ``restore`` returns its reservation on the scatter failure path;
* fleet -- multi-model routing weighs swap cost against resident-model
  affinity (a hot node wins over forcing a weight swap over the PCIe
  1.1 x4 link), reports carry ``model_swaps``/``swap_bytes``/per-model
  tpot, and the execution replay's per-model token accounting is budget
  invariant;
* routing -- the preemption-aware SLO router's anticipated
  eviction-cost term avoids migrations the reactive router incurs.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (ModelPool, MultiModelServeEngine, PagePool,
                           Request, ServeEngine, kv_page_bytes,
                           params_nbytes)

pytestmark = pytest.mark.multimodel

ENGINE_KW = dict(n_lanes=2, max_len=32, dispatch_n=4, rng_seed=7)
PAGE = 8


@pytest.fixture(scope="module")
def two_models():
    cfg_a = get_config("qwen2.5-1.5b", smoke=True)
    cfg_b = get_config("olmo-1b", smoke=True)
    params_a = build_model(cfg_a).init(jax.random.PRNGKey(0))
    params_b = build_model(cfg_b).init(jax.random.PRNGKey(1))
    return {"a": (cfg_a, params_a), "b": (cfg_b, params_b)}


def _mk_pool(models, hbm_bytes=None, slack_pages=0):
    """ModelPool over ``models``; default budget = dense no-swap."""
    if hbm_bytes is None:
        hbm_bytes = sum(
            params_nbytes(p) + (ENGINE_KW["n_lanes"]
                                * (ENGINE_KW["max_len"] // PAGE)
                                + 1 + slack_pages) * kv_page_bytes(c, PAGE)
            for c, p in models.values())
    pool = ModelPool(hbm_bytes, page_size=PAGE)
    for mid in sorted(models):
        pool.register(mid, models[mid][0], models[mid][1])
    return pool


def _reqs(models, spec, seed=3):
    """Interleaved request list: spec = [(mid, plen, gen), ...]."""
    rng = np.random.default_rng(seed)
    out = []
    for uid, (mid, plen, gen) in enumerate(spec):
        vocab = models[mid][0].vocab_size
        out.append(Request(uid=uid,
                           prompt=rng.integers(0, vocab, plen,
                                               dtype=np.int32),
                           max_new_tokens=gen, model_id=mid))
    return out


def _solo_streams(models, reqs, mid, **kw):
    cfg, params = models[mid]
    solo = [Request(uid=r.uid, prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens)
            for r in reqs if r.model_id == mid]
    eng = ServeEngine(cfg, params, paged=True, page_size=PAGE,
                      **dict(ENGINE_KW, **kw))
    eng.run(solo)
    return [r.generated for r in solo]


# ----------------------------------------------------------------------
# engine: concurrent multi-model exactness
# ----------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.9])
@pytest.mark.parametrize("kv_quant", [None, "int8"])
def test_two_models_concurrent_token_exact(two_models, temperature,
                                           kv_quant):
    """Two models interleaved on one board reproduce each model's solo
    single-engine streams bit for bit -- streams depend only on
    per-model admission order and token index, never on the co-tenant,
    the pool size, or the swap schedule."""
    models = {
        mid: (dataclasses.replace(cfg, kv_quant=kv_quant), params)
        for mid, (cfg, params) in two_models.items()}
    pool = _mk_pool(models)
    mm = MultiModelServeEngine(pool, temperature=temperature, **ENGINE_KW)
    reqs = _reqs(models, [("a", 5, 8), ("b", 7, 6), ("a", 9, 8),
                          ("b", 4, 6), ("a", 6, 8)])
    mm.run(reqs)
    for mid in ("a", "b"):
        got = [r.generated for r in reqs if r.model_id == mid]
        assert got == _solo_streams(models, reqs, mid,
                                    temperature=temperature), mid
    assert mm.stats["model_swaps"] == 2           # one cold load each
    for eng in mm.engines.values():
        eng.pool.check()
        assert eng.pool.n_in_use == 0


def test_exactness_survives_tight_budget_churn(two_models):
    """A budget too small for both models' dense pools forces shrink +
    LRU weight eviction churn -- and must not move a single token."""
    wa = params_nbytes(two_models["a"][1])
    wb = params_nbytes(two_models["b"][1])
    tight = (wa + wb + 6 * kv_page_bytes(two_models["a"][0], PAGE)
             + 2 * kv_page_bytes(two_models["b"][0], PAGE))
    pool = _mk_pool(two_models, hbm_bytes=tight)
    mm = MultiModelServeEngine(pool, **ENGINE_KW)
    reqs = _reqs(two_models,
                 [("a" if i % 2 == 0 else "b", 5 + i % 3, 6)
                  for i in range(8)])
    mm.run(reqs)
    assert mm.stats["weight_evictions"] > 0       # churn actually happened
    assert mm.stats["model_swaps"] > 2            # reloads, not just colds
    for mid in ("a", "b"):
        got = [r.generated for r in reqs if r.model_id == mid]
        assert got == _solo_streams(two_models, reqs, mid), mid


def test_unload_reload_round_trips_exactly(two_models):
    """Serve A, unload it, serve B, reload A, serve more A: the full A
    stream equals one uninterrupted single-engine run -- the admission
    counter (sampling lineage) survives the round trip."""
    pool = _mk_pool(two_models)
    mm = MultiModelServeEngine(pool, **ENGINE_KW)
    first = _reqs(two_models, [("a", 5, 6), ("a", 7, 6)], seed=5)
    mm.run(first)
    assert mm.unload("a")
    assert "a" not in mm.resident_models
    mm.run(_reqs(two_models, [("b", 6, 6)], seed=6))
    later = _reqs(two_models, [("a", 9, 6)], seed=8)
    mm.run(later)                                  # transparent reload
    all_a = first + later
    assert ([r.generated for r in all_a]
            == _solo_streams(two_models, all_a, "a"))
    entry = pool.entries["a"]
    assert entry.loads == 2
    assert mm.stats["model_swaps"] == 3            # a, b, a-again


def test_live_model_is_pinned_against_unload(two_models):
    """A model serving live lanes is never unloaded: explicit unload is
    refused, and ensure_resident of a competitor that needs its bytes
    returns None instead of evicting it."""
    wa = params_nbytes(two_models["a"][1])
    wb = params_nbytes(two_models["b"][1])
    bt = ENGINE_KW["max_len"] // PAGE
    # room for A's dense pool, but B's minimum cannot coexist with A
    tight = (wa + wb + (2 * bt + 1) * kv_page_bytes(two_models["a"][0],
                                                    PAGE))
    pool = _mk_pool(two_models, hbm_bytes=tight)
    mm = MultiModelServeEngine(pool, **ENGINE_KW)
    req = _reqs(two_models, [("a", 5, 8)])[0]
    assert mm.admit(req)
    assert mm.engines["a"].live_lanes()
    assert not mm.unload("a")                      # pinned: live lanes
    assert mm.ensure_resident("b") is None         # cannot evict A either
    assert "a" in mm.resident_models
    while mm.engines["a"].live_lanes():
        mm.decode_n()
    assert mm.unload("a")                          # idle now: allowed
    assert mm.ensure_resident("b") is not None


def test_weight_residency_trades_off_against_kv_pages(two_models):
    """Loading a second model SHRINKS the first pool's free pages (the
    byte budget is conserved); unloading it GROWS them back toward the
    dense target."""
    wa = params_nbytes(two_models["a"][1])
    wb = params_nbytes(two_models["b"][1])
    pb_a = kv_page_bytes(two_models["a"][0], PAGE)
    pb_b = kv_page_bytes(two_models["b"][0], PAGE)
    bt = ENGINE_KW["max_len"] // PAGE
    dense_a = ENGINE_KW["n_lanes"] * bt
    # A's dense pool fits alone; B's minimum residency is 2 A-pages
    # short, so its arrival must carve exactly those out of A's pool
    budget = (wa + (dense_a + 1) * pb_a + wb + (bt + 1) * pb_b
              - 2 * pb_a)
    pool = _mk_pool(two_models, hbm_bytes=budget)
    mm = MultiModelServeEngine(pool, **ENGINE_KW)
    assert mm.load("a")
    before = mm.kv_pages_active()["a"]
    assert before == dense_a
    assert mm.load("b")
    after = mm.kv_pages_active()["a"]
    assert after < before                          # pages paid for weights
    assert mm.stats["kv_pages_shrunk"] == before - after
    assert pool.free_bytes() >= 0                  # budget conserved
    assert mm.unload("b")
    assert mm.kv_pages_active()["a"] == before     # grown back
    assert mm.stats["kv_pages_grown"] == before - after
    for eng in mm.engines.values():
        eng.pool.check()


def test_register_rejects_model_larger_than_board():
    pool = ModelPool(1024, page_size=PAGE)
    cfg = get_config("qwen2.5-1.5b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(AssertionError, match="exceed the board"):
        pool.register("too-big", cfg, params)


# ----------------------------------------------------------------------
# allocator: restore failure hygiene + randomized shrink/grow churn
# ----------------------------------------------------------------------

def test_restore_unreserves_on_scatter_failure(two_models):
    """A checkpoint whose payload cannot scatter (malformed shape) must
    return BOTH its mapped pages and the rest of its reservation -- the
    reserve/alloc pairing audit of restore()."""
    cfg, params = two_models["a"]
    eng = ServeEngine(cfg, params, paged=True, page_size=PAGE,
                      **ENGINE_KW)
    req = Request(uid=0, prompt=np.arange(9, dtype=np.int32) % 17,
                  max_new_tokens=8)
    assert eng.admit(req)
    eng.decode_n()
    ckpt = eng.evict(0)
    free_before = eng.pool.n_free
    avail_before = eng.pool.available()
    # corrupt the payload: drop an axis so dynamic_update_slice rejects
    ckpt.kv_pages = {k: v[..., 0] for k, v in ckpt.kv_pages.items()}
    with pytest.raises(Exception):
        eng.restore(ckpt)
    eng.pool.check()
    assert eng.pool.n_free == free_before          # nothing leaked
    assert eng.pool.available() == avail_before    # reservation returned
    assert eng.lane_req[0] is None
    scratch = eng._scratch_page
    assert bool(np.all(np.asarray(eng.cache["block_tables"][0]) == scratch))
    # the engine still serves fresh work afterwards
    req2 = Request(uid=1, prompt=np.arange(5, dtype=np.int32),
                   max_new_tokens=4)
    eng.run([req2])
    assert len(req2.generated) == 4
    eng.pool.check()


def test_pagepool_shrink_grow_respects_reservations():
    pool = PagePool(8, PAGE)
    assert pool.reserve(3)
    assert pool.shrink(100) == 5                   # never promised pages
    assert pool.available() == 0
    assert pool.n_disabled == 5 and pool.n_active == 3
    pages = pool.alloc(2)
    assert pool.grow(2) == 2
    pool.free(pages)
    pool.unreserve(1)
    assert pool.grow(100) == 3
    assert pool.n_free == 8 and pool.n_disabled == 0
    pool.check()


def test_pagepool_randomized_invariants():
    """Randomized reserve/alloc/share/cow/free/unreserve/shrink/grow
    sequences: conservation, no double-issue, reservation safety, and
    the refcount invariants hold after every operation (hypothesis).

    ``live`` models outstanding REFERENCES (a shared page appears once
    per holder), so the checks pin exactly the prefix-sharing contract:
    a page is physically freed only when its last reference drops
    (never double-freed, never freed while rc > 0), reference totals
    match the pool's refcounts, and accounting sums to capacity."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(st.tuples(
        st.sampled_from(["reserve", "alloc", "share", "cow", "free",
                         "unreserve", "shrink", "grow"]),
        st.integers(0, 9)), max_size=80)

    @given(ops, st.integers(1, 24))
    @settings(max_examples=60, deadline=None)
    def run(seq, n_pages):
        pool = PagePool(n_pages, PAGE)
        live = []                       # one entry per reference
        for op, n in seq:
            if op == "reserve":
                before = pool.available()
                assert pool.reserve(n) == (n <= before)
            elif op == "alloc":
                k = min(n, pool._reserved, pool.n_free)
                live.extend(pool.alloc(k))
            elif op == "share":
                pages = live[-min(n, len(live)):] if n else []
                pool.share(pages)
                live.extend(pages)
            elif op == "cow":
                shared = sorted(p for p in set(live)
                                if pool.refcount(p) >= 2)
                if shared and pool._reserved >= 1 and pool.n_free >= 1:
                    old = shared[n % len(shared)]
                    new = pool.cow(old)
                    assert new != old and pool.refcount(new) == 1
                    live.remove(old)    # one holder moved to the copy
                    live.append(new)
            elif op == "free":
                k = min(n, len(live))
                pool.free([live.pop() for _ in range(k)])
            elif op == "unreserve":
                pool.unreserve(min(n, pool._reserved))
            elif op == "shrink":
                got = pool.shrink(n)
                assert got <= n
            elif op == "grow":
                got = pool.grow(n)
                assert got <= n
            pool.check()                           # conservation, always
            assert pool.available() >= 0
            # distinct pages in use == distinct live references;
            # refcounts account for every holder exactly once
            assert pool.n_in_use == len(set(live))
            assert pool.n_refs == len(live)
            assert all(pool.refcount(p) == live.count(p)
                       for p in set(live))
        pool.free(live)
        pool.grow(pool.n_pages)
        pool.unreserve(pool._reserved)
        pool.check()
        assert pool.n_free == pool.n_pages         # drains clean

    run()


# ----------------------------------------------------------------------
# fleet: swap-cost vs resident-affinity routing
# ----------------------------------------------------------------------

def _mm_fleet(hbm_gb):
    from repro.fleet import NodeSpec
    return [NodeSpec("a100-40g", 1, "prefill",
                     model_ids=("big", "small"), hbm_gb=40.0),
            NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                     model_ids=("big", "small"), resident=("big",),
                     hbm_gb=hbm_gb, page_size=16),
            NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                     model_ids=("big", "small"), resident=("small",),
                     hbm_gb=hbm_gb, page_size=16)]


def _mm_specs():
    from repro.core.perf_model import QWEN25_0P5B, QWEN25_1P5B
    return {"big": QWEN25_1P5B, "small": QWEN25_0P5B}


def _mm_sim_trace():
    from repro.fleet import multimodel_trace, poisson_trace
    from repro.fleet.workload import LengthDist
    return multimodel_trace(
        poisson_trace(2.0, 60.0, seed=3, prompt=LengthDist(256, cv=0.3),
                      gen=LengthDist(128, cv=0.4)),
        {"big": 1, "small": 1}, seed=1)


def test_fleet_affinity_routing_beats_weight_thrash():
    """On boards too small to co-host both models' weights, the
    affinity-aware router serves each model on its hot board (zero
    swaps); the affinity-blind baseline thrashes weights over the host
    link and its page pools shrink under the swapped-in weights --
    visible as swaps, swap bytes, and a far worse decode tail."""
    from repro.fleet import FleetSim, LeastLoadedRouter

    trace = _mm_sim_trace()
    aware = FleetSim(_mm_fleet(2.0), trace, fmt="q8_0",
                     model_specs=_mm_specs(),
                     router=LeastLoadedRouter()).run()
    blind = FleetSim(_mm_fleet(2.0), trace, fmt="q8_0",
                     model_specs=_mm_specs(),
                     router=LeastLoadedRouter(model_aware=False)).run()
    assert aware.completed == aware.offered
    assert blind.completed == blind.offered
    assert aware.model_swaps == 0                  # both models stay hot
    assert blind.model_swaps > 0 and blind.swap_bytes > 0
    assert len(blind.swap_events) == blind.model_swaps
    assert aware.tpot_p99_s < blind.tpot_p99_s
    # per-model report rows: tpot + tokens/joule for both tenants
    assert [m for m, *_ in aware.per_model] == ["big", "small"]
    for _, tpot_p50, toks, tpj in aware.per_model:
        assert tpot_p50 > 0 and toks > 0 and tpj > 0


def test_fleet_multimodel_deterministic():
    from repro.fleet import FleetSim, LeastLoadedRouter

    trace = _mm_sim_trace()
    runs = [FleetSim(_mm_fleet(2.5), trace, fmt="q8_0",
                     model_specs=_mm_specs(),
                     router=LeastLoadedRouter()).run() for _ in range(2)]
    assert runs[0].metrics() == runs[1].metrics()
    assert runs[0].swap_events == runs[1].swap_events
    assert runs[0].per_model == runs[1].per_model


def test_simnode_swap_evicts_lru_idle_only():
    """Direct SimNode residency semantics: swap_in charges the weight
    transfer once, evicts the LRU *idle* resident when the budget
    over-commits, and kv_pool_pages tracks the resident weights."""
    from repro.core.device_profile import get_profile
    from repro.fleet import SimNode

    specs = _mm_specs()
    node = SimNode("n0", get_profile("cmp-170hx-nofma"), "decode",
                   "q8_0", decode_lanes=4, page_size=16,
                   models=specs, resident_models=("big",), hbm_gb=2.0)
    pages_solo = node.kv_pool_pages
    assert pages_solo > 0
    t = node.swap_in("small", now=1.0)
    assert t > 0                                   # paid the link
    # 2 GB cannot hold both: the idle LRU resident (big) was evicted
    assert set(node.resident_models) == {"small"}
    assert node.model_evictions == 1
    assert node.swap_in("small", now=2.0) == 0.0   # hot: free
    # a live slot pins its model against eviction
    slot = node.make_slot(0, 256, 64, model_id="small")
    node.decode_admit(slot, 2.0)
    node.swap_in("big", now=3.0)
    assert "small" in node.resident_models         # in use: not evicted
    assert node.kv_pages_free() < 0                # over-committed instead
    assert node.model_swaps == 2


def test_multimodel_trace_mix_deterministic():
    from repro.fleet import multimodel_trace, poisson_trace

    base = poisson_trace(5.0, 40.0, seed=0)
    t1 = multimodel_trace(base, {"x": 3, "y": 1}, seed=2)
    t2 = multimodel_trace(base, {"x": 3, "y": 1}, seed=2)
    assert t1 == t2
    counts = {m: sum(1 for r in t1 if r.model_id == m) for m in ("x", "y")}
    assert counts["x"] > counts["y"] > 0           # mix roughly honored
    assert [r.uid for r in t1] == [r.uid for r in base]  # arrivals kept


# ----------------------------------------------------------------------
# routing: anticipated eviction cost (preemption-aware SLO routing)
# ----------------------------------------------------------------------

def test_preemption_aware_router_avoids_reactive_migrations():
    """The eviction-cost term steers load off the near-capacity board
    BEFORE its pool exhausts: the reactive router incurs migrations the
    anticipatory one never needs, at no completion or tail cost."""
    from repro.fleet import (FleetSim, NodeSpec, PreemptionPolicy,
                             PreemptionAwareSLORouter, SLOAwareRouter,
                             poisson_trace)
    from repro.fleet.workload import LengthDist

    def fleet():
        return [NodeSpec("a100-40g", 1, "prefill"),
                NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                         kv_pool_pages=40, page_size=16),
                NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                         kv_pool_pages=512, page_size=16)]

    trace = poisson_trace(3.0, 40.0, seed=2,
                          prompt=LengthDist(256, cv=0.3),
                          gen=LengthDist(128, cv=0.5))
    reactive = FleetSim(fleet(), trace, fmt="q8_0",
                        router=SLOAwareRouter(tpot_slo_s=0.05),
                        preemption=PreemptionPolicy()).run()
    anticip = FleetSim(fleet(), trace, fmt="q8_0",
                       router=PreemptionAwareSLORouter(tpot_slo_s=0.05),
                       preemption=PreemptionPolicy()).run()
    assert reactive.preemptions > 0                # pays migrations
    assert anticip.preemptions == 0                # never needs one
    assert anticip.pages_migrated == 0
    assert anticip.completed == anticip.offered == reactive.completed
    assert anticip.tpot_p99_s <= reactive.tpot_p99_s * 1.05


# ----------------------------------------------------------------------
# execution replay: budget-invariant per-model accounting
# ----------------------------------------------------------------------

def test_execution_multimodel_exactness_and_budget_invariance(two_models):
    """The real-engine replay of a two-model trace is token-exact vs
    per-model solo runs, and token counts are invariant to the HBM
    budget -- only the swap counters change when weights must page."""
    from repro.fleet import FleetRequest
    from repro.fleet.execution import (dense_hbm_bytes,
                                       run_multimodel_trace_on_engine,
                                       validate_multimodel_exactness)

    trace = [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=5 + i % 4,
                          gen_len=6, model_id="a" if i % 2 == 0 else "b")
             for i in range(6)]
    kw = dict(n_lanes=2, max_len=32, dispatch_n=4, page_size=PAGE)
    roomy = run_multimodel_trace_on_engine(trace, two_models, **kw)
    assert roomy.model_swaps == 2 and roomy.weight_evictions == 0
    assert set(roomy.gen_by_model) == {"a", "b"}
    assert roomy.gen_tokens == 6 * 6

    wa = params_nbytes(two_models["a"][1])
    wb = params_nbytes(two_models["b"][1])
    tight = (wa + wb + 6 * kv_page_bytes(two_models["a"][0], PAGE)
             + 2 * kv_page_bytes(two_models["b"][0], PAGE))
    assert tight < dense_hbm_bytes(two_models, n_lanes=2, max_len=32,
                                   page_size=PAGE)
    squeezed = run_multimodel_trace_on_engine(trace, two_models,
                                              hbm_bytes=tight, **kw)
    assert squeezed.gen_by_uid == roomy.gen_by_uid  # tokens: invariant
    assert squeezed.model_swaps > roomy.model_swaps  # swaps: not
    assert squeezed.swap_bytes > roomy.swap_bytes

    result = validate_multimodel_exactness(trace, two_models,
                                           hbm_bytes=tight,
                                           temperature=0.8, **kw)
    assert result["exact"], result["mismatches"]
    assert result["model_swaps"] > 2
